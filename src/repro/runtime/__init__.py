from repro.runtime.fault_tolerance import (  # noqa: F401
    HeartbeatMonitor, ResilientLoopConfig, ResilientTrainLoop,
    StragglerDetector)
