"""Fault-tolerance runtime: failure detection, straggler mitigation,
elastic restart.

At thousand-node scale the mean time between failures is shorter than a
training run, so the loop must (a) detect dead/slow workers, (b) restore
from the latest checkpoint, and (c) continue on a *different* device count
when spares are unavailable. This module provides those mechanics; on this
CPU container the "cluster" is simulated (heartbeats are injected by tests
/ the elastic driver re-creates meshes of different sizes), but every code
path — detection thresholds, EWMA straggler scoring, resumable data
streams, reshard-on-restore — is the real logic.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import jax

from repro.checkpoint.manager import CheckpointManager


# ------------------------------ heartbeats --------------------------------
@dataclasses.dataclass
class WorkerState:
    last_beat: float
    alive: bool = True


class HeartbeatMonitor:
    """Deadline-based failure detector over worker heartbeats."""

    def __init__(self, workers: list[str], timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self.workers = {w: WorkerState(last_beat=now) for w in workers}

    def beat(self, worker: str, at: float | None = None) -> None:
        st = self.workers[worker]
        st.last_beat = self._clock() if at is None else at
        st.alive = True

    def check(self, at: float | None = None) -> list[str]:
        """Returns newly-failed workers (missed deadline)."""
        now = self._clock() if at is None else at
        failed = []
        for name, st in self.workers.items():
            if st.alive and now - st.last_beat > self.timeout_s:
                st.alive = False
                failed.append(name)
        return failed

    def alive(self) -> list[str]:
        return [w for w, st in self.workers.items() if st.alive]


# --------------------------- straggler mitigation ---------------------------
class StragglerDetector:
    """EWMA step-time tracker; flags steps slower than ``factor`` × median.

    Mitigation at scale = re-dispatch the work or drop the slow participant
    from the synchronous group; the hook receives the decision.
    """

    def __init__(self, window: int = 32, factor: float = 3.0):
        self.window = window
        self.factor = factor
        self.history: deque[float] = deque(maxlen=window)
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, duration_s: float) -> bool:
        is_straggler = False
        if len(self.history) >= max(4, self.window // 4):
            med = sorted(self.history)[len(self.history) // 2]
            is_straggler = duration_s > self.factor * med
            if is_straggler:
                self.flagged.append((step, duration_s))
        self.history.append(duration_s)
        return is_straggler

    @property
    def median_s(self) -> float:
        if not self.history:
            return 0.0
        return sorted(self.history)[len(self.history) // 2]


# ------------------------------ elastic loop --------------------------------
@dataclasses.dataclass
class ResilientLoopConfig:
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    max_restarts: int = 5


class ResilientTrainLoop:
    """Checkpointed training loop with crash/elastic restart.

    ``build_fn(num_devices)`` must return ``(step_fn, state, loader)`` for a
    mesh over ``num_devices`` devices, restoring from the latest checkpoint
    if one exists (the CheckpointManager is passed in). The loop catches
    worker failures (exceptions from ``step_fn`` or injected via the
    monitor), re-builds at the surviving device count, and resumes from the
    checkpointed step — the data pipeline is deterministic in step, so the
    stream is replayed exactly.
    """

    def __init__(self, ckpt: CheckpointManager,
                 cfg: ResilientLoopConfig | None = None,
                 comm=None):
        self.ckpt = ckpt
        self.cfg = cfg or ResilientLoopConfig()
        self.straggler = StragglerDetector()
        self.events: list[dict] = []
        #: Optional CommSession: when attached, the loop drains its
        #: health event log (link faults, retries, quarantines,
        #: re-admissions — DESIGN §4.6) into ``self.events`` each step,
        #: so one timeline interleaves training failures with comm
        #: degradation.
        self.comm = comm

    def _drain_comm_events(self, step: int) -> None:
        """Fold the comm session's pending health events into the loop's
        event stream, stamped with the training step. Draining clears
        the session's log (no double-reporting) and preserves its
        counters — the ``stats()['health']`` window contract."""
        if self.comm is None:
            return
        for ev in self.comm.drain_health_events():
            self.events.append({"kind": "comm_health", "step": step,
                                "event": ev})

    def run(self, build_fn, total_steps: int,
            fail_at: dict[int, int] | None = None):
        """``fail_at``: {step: new_device_count} injected failures (tests).

        Returns (final_state, losses, events).
        """
        import numpy as np
        fail_at = fail_at or {}
        num_devices = len(jax.devices())
        restarts = 0
        losses = []
        step_fn, state, loader = build_fn(num_devices, self.ckpt)
        step = int(jax.device_get(state["opt"]["step"]))
        while step < total_steps:
            if step in fail_at and fail_at[step] is not None:
                # injected failure: shrink the cluster and restart
                new_n = fail_at.pop(step)
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    # Terminal path must not lose state: flush pending
                    # checkpoint writes and record the exhaustion BEFORE
                    # raising, so post-mortem tooling sees a complete
                    # event log and a consistent checkpoint directory.
                    self.events.append({"kind": "exhausted", "step": step,
                                        "restarts": restarts,
                                        "budget": self.cfg.max_restarts})
                    self._drain_comm_events(step)
                    self.ckpt.wait()
                    raise RuntimeError("restart budget exhausted")
                self.events.append({"kind": "failure", "step": step,
                                    "devices": new_n})
                self.ckpt.wait()
                num_devices = new_n
                step_fn, state, loader = build_fn(num_devices, self.ckpt)
                step = int(jax.device_get(state["opt"]["step"]))
                continue
            batch = loader(step)
            t0 = time.monotonic()
            state, metrics = step_fn(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.monotonic() - t0
            if self.straggler.observe(step, dt):
                self.events.append({"kind": "straggler", "step": step,
                                    "duration_s": dt})
            self._drain_comm_events(step)
            losses.append(loss)
            step += 1
            if step % self.cfg.checkpoint_every == 0 or step == total_steps:
                self.ckpt.save(step, state, metadata={"loss": loss})
                self.events.append({"kind": "checkpoint", "step": step})
        self.ckpt.wait()
        return state, losses, self.events
