"""JAX version compatibility for the comm package.

The repo targets the current ``jax.shard_map`` API (``check_vma``), but the
pinned container jax (0.4.x) still exposes ``shard_map`` under
``jax.experimental.shard_map`` with the older ``check_rep`` spelling, and
``jax.make_mesh`` without ``axis_types``. Every shard_map/make_mesh call in
the repo goes through these wrappers so the suite runs on both.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax


def shard_map(f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: bool = True) -> Callable:
    """``jax.shard_map`` across jax versions (``check_vma``/``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


def axis_size(axis_name) -> int:
    """``lax.axis_size`` across jax versions (falls back to the static
    ``psum(1, axis)`` idiom, which older jax constant-folds to an int)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def set_mesh(mesh):
    """``jax.set_mesh`` context across jax versions.

    Old jax has no ``set_mesh``; a concrete ``Mesh`` is itself a context
    manager installing the global mesh (the legacy spelling), and an
    ``AbstractMesh`` needs no installation there (shardings are resolved
    from the NamedShardings already attached to the jit arguments).
    """
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    if isinstance(mesh, jax.sharding.Mesh):
        return mesh
    return contextlib.nullcontext(mesh)


def abstract_mesh(axis_shapes, axis_names) -> "jax.sharding.AbstractMesh":
    """``AbstractMesh`` across jax versions: new jax takes
    ``(axis_sizes, axis_names)``, old jax a tuple of (name, size) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def get_abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` across versions.

    Old jax tracks the (legacy) ``with mesh:`` context in thread resources;
    return that concrete mesh — it quacks like an AbstractMesh
    (``axis_names`` / ``shape``) for sharding-constraint resolution.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    if m is None or m.empty:
        return None
    return m


def pallas_tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` across jax versions (``TPUCompilerParams``
    before the rename)."""
    import jax.experimental.pallas.tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def has_pallas_tpu_interpret_mode() -> bool:
    """True when jax ships the typed TPU interpret mode
    (``pltpu.InterpretParams``), which simulates cross-device remote DMA.
    Older jax's plain ``interpret=True`` cannot execute
    ``make_async_remote_copy`` across devices."""
    import jax.experimental.pallas.tpu as pltpu
    return hasattr(pltpu, "InterpretParams")


def pallas_interpret_flag(interpret: bool = True):
    """Value for ``pallas_call(interpret=...)``: ``InterpretParams()`` on
    new jax (typed TPU-interpret mode), plain ``True`` on old jax."""
    if not interpret:
        return False
    import jax.experimental.pallas.tpu as pltpu
    cls = getattr(pltpu, "InterpretParams", None)
    return cls() if cls is not None else True


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with ``axis_types=Auto`` where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
