"""Kimi K2 — trillion-parameter MoE, 384 experts top-8, 32B active.

[arXiv:2501.kimi2 paper-table; unverified] d_ff=2048 is the per-expert
width; one shared expert per layer as in the DeepSeek-V3-style recipe.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="kimi_k2_1t_a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163840,
    attention="full",
    mlp="swiglu",
    num_experts=384,
    top_k=8,
    num_shared_experts=1,
    rope_theta=50_000.0,
    fsdp=True,
    remat="full",
    optimizer_dtype="int8",
    multi_pod=True,
    notes="1T total / ~32B active; EP(model) x FSDP(data) 2-D expert "
          "sharding; int8 Adam moments required to fit 16GB/chip at 256 "
          "chips (see EXPERIMENTS.md §Perf memory iteration); 1T params "
          "+ moments exceed one pod's HBM, so launch resolves the "
          "2-pod island-aware mesh/topology.",
))
