"""Gemma-3 27B — dense, 5:1 local:global attention, 128k-class context.

[hf:google/gemma-3-1b-pt scaled per released 27B card; unverified]
Local layers use 1024-token sliding windows; every 6th layer is global.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3_27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    attention="local_global",
    window=1024,
    local_global_ratio=5,
    mlp="geglu",
    rope_theta=1_000_000.0,
    fsdp=True,
    remat="full",
    optimizer_dtype="bfloat16",
    notes="5 local (SWA-1024) layers per 1 global layer; GeGLU MLP; "
          "long_500k decode keeps full KV on the 1/6 global layers "
          "(linear per-token cost) and windowed KV semantics on local.",
))
