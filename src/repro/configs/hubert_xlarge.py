"""HuBERT X-Large — encoder-only audio transformer. [arXiv:2106.07447; unverified]

The conv waveform frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, T, 512) projected into d_model. No decode shapes.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert_xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    attention="full",
    causal=False,
    mlp="gelu",
    frontend="audio",
    frontend_dim=512,
    remat="full",
))
