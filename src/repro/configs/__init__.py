from repro.configs.base import (  # noqa: F401
    ARCH_IDS, ArchConfig, REGISTRY, get_config, load_all, register)
from repro.configs.shapes import SHAPES, ShapeConfig, cells, skip_reason  # noqa: F401
