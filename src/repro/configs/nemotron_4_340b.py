"""Nemotron-4 340B — dense GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron_4_340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    attention="full",
    mlp="relu2",
    rope_theta=10_000.0,
    fsdp=True,
    remat="full",
    optimizer_dtype="bfloat16",
    multi_pod=True,
    notes="squared-ReLU MLP (2 matrices); params+moments require "
          "FSDP(data)xTP(model) 2-D sharding to fit 16GB/chip; 340B "
          "params + bf16 moments exceed one pod's HBM, so launch "
          "resolves the 2-pod island-aware mesh/topology.",
))
