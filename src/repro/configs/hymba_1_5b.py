"""Hymba 1.5B — hybrid: parallel attention + mamba heads per layer.

[arXiv:2411.13676; hf] SWA on the attention branch (global on none —
meta-token mechanism omitted, noted in DESIGN.md); ssm_state=16.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba_1_5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attention="swa",
    window=1024,
    mlp="swiglu",
    ssm_state=16,
    rope_theta=10_000.0,
    remat="full",
))
