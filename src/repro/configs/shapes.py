"""Assigned input-shape set (identical across the 10 LM-family archs).

``decode_32k``/``long_500k`` lower ``serve_step`` (one new token against a
KV/state cache of ``seq_len``); the others lower ``train_step`` /
``prefill``. The skip rules implement the pool's instructions and are
recorded in DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def skip_reason(arch: ArchConfig, shape: ShapeConfig) -> str | None:
    """None if the (arch × shape) cell runs; otherwise why it is skipped."""
    if not arch.decoder and shape.kind == "decode":
        return "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return "pure full-attention arch: long_500k requires sub-quadratic"
    return None


def cells(archs) -> list[tuple[ArchConfig, ShapeConfig, str | None]]:
    """All 40 (arch × shape) cells with their skip status."""
    out = []
    for a in archs:
        for s in SHAPES.values():
            out.append((a, s, skip_reason(a, s)))
    return out
