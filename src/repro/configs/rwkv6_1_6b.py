"""RWKV-6 (Finch) 1.6B — attention-free, data-dependent decay.

[arXiv:2404.05892; unverified] 32 heads x 64; O(1) decode state.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6_1_6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    attention="none",
    mlp="relu2",              # rwkv channel-mix is a squared-relu 2-matrix FFN
    rwkv_head_dim=64,
    remat="full",
))
