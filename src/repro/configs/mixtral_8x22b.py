"""Mixtral 8x22B — MoE 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf] SWA window 4096 per the Mistral lineage.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral_8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    attention="swa",
    window=4096,
    mlp="swiglu",
    num_experts=8,
    top_k=2,
    rope_theta=1_000_000.0,
    fsdp=True,
    remat="full",
    optimizer_dtype="bfloat16",
    notes="experts sharded over the model axis (EP); SWA makes long_500k "
          "decode eligible.",
))
