"""Chameleon 34B — early-fusion VLM; VQ image tokens share the text vocab.

[arXiv:2405.09818; unverified] The modality frontend is a STUB per the
pool rules: image patches arrive as precomputed VQ token ids inside the
unified 65536 vocab, so the backbone is a standard decoder.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon_34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    attention="full",
    mlp="swiglu",
    rope_theta=10_000.0,
    fsdp=True,
    remat="full",
    optimizer_dtype="bfloat16",
    frontend="vq_tokens",
))
