"""Architecture configuration system.

``ArchConfig`` is the single source of truth consumed by the model builders,
the launcher, the dry-run, and the roofline analysis. One module per assigned
architecture lives next to this file; each registers itself in ``REGISTRY``.

``reduced()`` produces the CPU smoke-test configuration of the same family
(small widths/layers/experts, tiny vocab) — the full configs are exercised
only through the AOT dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

Family = Literal["dense", "moe", "vlm", "hybrid", "ssm", "audio"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int            # query heads; 0 for attention-free
    num_kv_heads: int
    d_ff: int                 # dense FFN width, or per-expert width for MoE
    vocab_size: int
    head_dim: int | None = None

    # -- attention pattern --------------------------------------------------
    attention: str = "full"   # full | swa | local_global | none
    window: int | None = None
    local_global_ratio: int = 0   # gemma3: 5 local layers per 1 global
    causal: bool = True           # False → encoder-only (no decode shapes)

    # -- mixer/FFN variants ---------------------------------------------------
    mlp: str = "swiglu"       # swiglu | geglu | gelu | relu2
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25

    # -- SSM / hybrid ----------------------------------------------------------
    ssm_state: int = 0        # mamba state size N (hymba)
    rwkv_head_dim: int = 64   # rwkv6 head size

    # -- modality frontend stub (audio/vlm: precomputed embeddings) -----------
    frontend: str | None = None   # "audio" → (B, T, frontend_dim) features
    frontend_dim: int = 512

    # -- numerics / distribution hints ------------------------------------------
    rope_theta: float = 500000.0
    dtype: str = "bfloat16"
    fsdp: bool = False            # shard params/optimizer over data axis too
    multi_pod: bool = False       # needs >1 pod: launch resolves the pod-axis
                                  # mesh + hierarchical (island-aware) topology
    remat: str = "none"           # none | full  (activation checkpointing)
    optimizer_dtype: str = "float32"   # adam moment dtype (bf16/int8 for huge)
    scan_layers: bool = True
    notes: str = ""

    # -- derived -----------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)

    @property
    def attention_free(self) -> bool:
        return self.attention == "none"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4 skip table)."""
        return self.family in ("ssm", "hybrid") or self.attention in (
            "swa", "local_global")

    @property
    def decoder(self) -> bool:
        return self.causal

    def param_count(self) -> int:
        """Analytic parameter count (embedding + stacked blocks + head)."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim_
        n = self.vocab_size * d           # embed
        if self.decoder:
            n += self.vocab_size * d      # untied lm head
        per_layer = 0
        if not self.attention_free:
            per_layer += d * self.num_heads * hd * 2        # wq, wo
            per_layer += d * self.num_kv_heads * hd * 2     # wk, wv
        if self.family == "ssm":  # rwkv6 mixer
            per_layer += 5 * d * d + 2 * d * d              # r,k,v,w,g + out
        if self.family == "hybrid" and self.ssm_state:
            d_i = d
            per_layer += d * 2 * d_i + d_i * d              # in/out proj
            per_layer += d_i * (2 * self.ssm_state + d // 16)  # B,C,dt
        mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        if self.num_experts:
            per_layer += d * self.num_experts               # router
            per_layer += self.num_experts * mats * d * ff
            per_layer += self.num_shared_experts * mats * d * ff
        else:
            per_layer += mats * d * ff
        per_layer += 2 * d                                   # norms
        return n + L * per_layer + d

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: only routed experts count)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        expert_p = self.num_experts * mats * self.d_model * self.d_ff
        active_p = self.top_k * mats * self.d_model * self.d_ff
        return full - self.num_layers * (expert_p - active_p)

    def reduced(self) -> "ArchConfig":
        """Same-family smoke config: tiny but structurally identical."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=2 if self.num_kv_heads else 0,
            head_dim=16 if not self.attention_free else None,
            d_ff=128,
            vocab_size=256,
            window=8 if self.window else None,
            num_experts=4 if self.num_experts else 0,
            top_k=min(2, self.top_k) if self.top_k else 0,
            num_shared_experts=min(1, self.num_shared_experts),
            ssm_state=8 if self.ssm_state else 0,
            rwkv_head_dim=16,
            frontend_dim=32 if self.frontend else 512,
            dtype="float32",
            remat="none",
            fsdp=False,
            multi_pod=False,
        )


# ---------------------------------------------------------------------------
REGISTRY: dict[str, ArchConfig] = {}

ARCH_IDS = (
    "gemma3_27b", "nemotron_4_340b", "llama3_8b", "smollm_360m",
    "mixtral_8x22b", "kimi_k2_1t_a32b", "chameleon_34b", "hymba_1_5b",
    "rwkv6_1_6b", "hubert_xlarge",
)


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    """Look up an architecture by id (dashes and underscores equivalent)."""
    key = name.replace("-", "_")
    if not REGISTRY:
        load_all()
    for cand in (name, key):
        if cand in REGISTRY:
            return REGISTRY[cand]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")


def load_all() -> dict[str, ArchConfig]:
    for mod in ARCH_IDS:
        importlib.import_module(f"repro.configs.{mod}")
    return REGISTRY
