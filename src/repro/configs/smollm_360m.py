"""SmolLM 360M — llama-architecture small model. [hf:HuggingFaceTB/SmolLM-360M; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="smollm_360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    attention="full",
    mlp="swiglu",
    rope_theta=10_000.0,
    remat="full",
))
