"""Graph-pass pipeline: pluggable chunk-interleaving schedulers.

The paper's Algorithm 1 distributes chunks across paths in one fixed
round-robin order; its CUDA-Graph formulation makes dispatch order a
property of the *captured graph*. In this repo that property is the
node-index order of the :class:`~repro.comm.graph.TransferGraph`, so a
scheduler is a pure ``TransferGraph -> TransferGraph`` rewrite applied
between :func:`repro.comm.graph.lower` and the emitter
(:func:`repro.comm.engine.emit_graph`) — a *graph pass*.

**The pass contract (DESIGN.md §2.2).** A pass may renumber node indices
(the dispatch order, and with it the derived per-link serialization
edges); it must NOT change anything else:

* the node multiset is fixed — byte cover, hop chains, flows, chunking
  are §4.5 invariants the pass inherits and must preserve,
* the stored edge *set* (hop dataflow + window replay + buffer def-use,
  identified by the node content at each endpoint) is fixed; only
  endpoint indices are remapped,
* index order must remain a valid topological order (every stored edge
  points forward), so the emitter's walk IS the schedule,
* the scheduled graph must still pass
  :meth:`~repro.comm.graph.TransferGraph.validate`, and its
  :meth:`~repro.comm.graph.TransferGraph.digest` is recomputed from the
  new node order — cache keys (``GroupKey``) therefore distinguish
  schedules and can never cross-serve executables.

**The ``allows_rewrite`` capability flag.** A pass that sets a truthy
``allows_rewrite`` attribute opts out of the node-multiset and edge-set
freezes — it may rewrite node *content* (e.g. the ROADMAP host-staged
pricing pass replacing host hops with a simulated stage). The rest of
the contract still binds: metadata fixed, every stored edge forward, and
the §4.5 validation re-run on the output. :func:`check_pass` reads the
flag; passes that don't declare it get the full freeze.

Graphs may be **heterogeneous** (whole-iteration capture): the shipped
schedulers are compute-aware — :class:`~repro.comm.graph.ComputeNode`
entries serialize on one shared compute slot while ready copies are dispatched
ahead of ready computes, so copies slot into compute gaps and the
emitter overlaps communication with kernel execution.

:func:`apply_schedule` enforces all of this after every pass
(:func:`check_pass`), so a buggy custom pass fails loudly at schedule
time rather than corrupting a compiled program.

Shipped schedulers (:data:`repro.comm.config.SCHEDULE_NAMES`):

* ``round_robin`` — the paper's Alg. 1 order, i.e. today's lowering
  emission (chunk waves interleaved across paths). Identity on a fresh
  lowering: same nodes, same digest.
* ``depth_first`` — drain each path's whole chunk chain before switching
  to the next path (minimizes per-link switchover at the cost of late
  path starts).
* ``critical_path`` — greedy list scheduling under the §4.4 weighted
  model (:func:`repro.core.pipelining.scheduled_time_s` semantics):
  repeatedly dispatch the ready node that finishes earliest, ties to the
  node with the most downstream work. Reorders serialization edges to
  shorten the DAG's modeled critical path (remainder chunks really are
  bigger, so order matters on staged paths).
* ``overlap`` — list scheduling over the resource-lane makespan model
  (:func:`repro.core.pipelining.lane_intervals_s`): link-exclusive
  transfer lanes plus one SPMD compute lane, copies issued as early as
  their deps allow so they run *behind* compute on the modeled
  timeline. Falls back to the input order whenever its greedy order
  does not model strictly faster (list-scheduling anomaly guard), so
  ``overlap(g)`` never models worse than ``g``.
* ``auto`` — scores every candidate order with
  :func:`~repro.core.pipelining.scheduled_time_s` and picks the winner
  before compiling; ties (and any tie with the baseline) resolve to
  ``round_robin``, so ``auto`` never selects a schedule the model scores
  worse than ``round_robin``. Candidate scores are memoized on
  ``(graph digest, topology epoch)`` — the same keying the engine's
  schedule memo uses — surfaced as the ``schedule_scores`` stat.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import OrderedDict
from typing import Iterable, Protocol, Sequence, runtime_checkable

from repro.comm.config import SCHEDULE_NAMES
from repro.comm.graph import ComputeNode, DepEdge, TransferGraph
from repro.core.topology import Topology


@runtime_checkable
class GraphPass(Protocol):
    """Protocol for a transfer-graph pass: a named pure rewrite.

    Implementations must honor the §2.2 pass contract (module docstring):
    preserve the node multiset and edge set — the §4.5 invariants ride on
    them — keep index order topologically valid, and return a graph whose
    ``digest()`` reflects the new dispatch order. ``__call__`` must be
    deterministic (same input graph → same output graph) or compiled-plan
    cache keys would churn.
    """

    name: str

    def __call__(self, graph: TransferGraph) -> TransferGraph:
        ...


def _node_id(node) -> tuple:
    """Content identity of a node — what a non-rewriting pass may never
    change. Type-tagged so heterogeneous node kinds cannot collide."""
    return (type(node).__name__,) + dataclasses.astuple(node)


def reindex(graph: TransferGraph, order: Sequence[int]) -> TransferGraph:
    """Rebuild ``graph`` with nodes renumbered into dispatch order
    ``order`` (``order[k]`` = old index of the node dispatched k-th).

    The §2.2 mechanical core every scheduler shares: nodes are permuted,
    stored edges are endpoint-remapped and canonically sorted (edge
    storage order is not semantic — ``digest()`` sorts it anyway), and
    the result is returned unchanged (same object, same digest) when
    ``order`` is the identity. Raises ``ValueError`` if ``order`` is not
    a permutation or breaks topological validity (a stored edge would
    point backward) — such an order is not a schedule of this DAG.
    """
    n = graph.num_nodes
    if sorted(order) != list(range(n)):
        raise ValueError("order is not a permutation of node indices")
    if list(order) == list(range(n)):
        return graph
    old_to_new = {old: new for new, old in enumerate(order)}
    nodes = tuple(graph.nodes[old] for old in order)
    for e in graph.edges:
        src, dst = old_to_new[e.src], old_to_new[e.dst]
        if src >= dst:
            raise ValueError(
                f"schedule violates dependency {e.kind} edge "
                f"{e.src}->{e.dst}: dispatch order must stay topological")
    edges = tuple(sorted(
        (DepEdge(old_to_new[e.src], old_to_new[e.dst], e.kind)
         for e in graph.edges),
        key=lambda e: (e.src, e.dst, e.kind)))
    return TransferGraph(nodes, edges, graph.window, graph.num_messages,
                         graph.topology_name, graph.messages)


def check_pass(before: TransferGraph, after: TransferGraph,
               *, allows_rewrite: bool = False) -> None:
    """Assert the §2.2 pass contract between a pass's input and output.

    Raises ``ValueError`` if the pass changed anything beyond dispatch
    order: node multiset (byte cover / hop chains / chunking), the edge
    set (by node content), graph metadata, or topological validity of the
    index order. Also re-runs the §4.5 graph invariants
    (:meth:`TransferGraph.validate`) on the output.
    ``apply_schedule`` calls this after every pass; pass authors get it
    for free in tests via the hypothesis property suite.

    ``allows_rewrite=True`` is the §2.2 capability flag: the node-multiset
    and edge-set freezes are waived for passes that declare node
    *rewrites* (e.g. host-staged pricing), while metadata, forward-edge
    topology, and the §4.5 validation still apply.
    """
    if (after.window != before.window
            or after.num_messages != before.num_messages
            or after.topology_name != before.topology_name):
        raise ValueError("pass changed graph metadata "
                         "(window/num_messages/topology)")
    if not allows_rewrite:
        if after.messages != before.messages:
            raise ValueError(
                "pass changed the buffer messages table — def-use "
                "semantics are fixed by the §2.2 contract")
        if sorted(map(_node_id, after.nodes)) != sorted(map(
                _node_id, before.nodes)):
            raise ValueError(
                "pass changed the node multiset — byte cover and hop "
                "chains are fixed by the §2.2 contract; only dispatch "
                "order is free (declare allows_rewrite to opt out)")
        def edge_set(g: TransferGraph) -> set:
            return {(_node_id(g.nodes[e.src]), _node_id(g.nodes[e.dst]),
                     e.kind) for e in g.edges}
        if edge_set(after) != edge_set(before):
            raise ValueError(
                "pass changed the dependency-edge set — passes may only "
                "renumber edge endpoints (declare allows_rewrite to opt "
                "out)")
    for e in after.edges:
        if e.src >= e.dst:
            raise ValueError("pass broke topological index order "
                             f"({e.kind} edge {e.src}->{e.dst})")
    # §4.5 on the scheduled graph itself. Cross-flow exclusivity is a
    # planner-level property (the shared fallback trades it away on
    # purpose), so the scheduled graph is held to the same per-message
    # standard the lowering was.
    after.validate(cross_flow_exclusive=False)


def _constrained_order(graph: TransferGraph, key) -> list[int]:
    """Min-key Kahn's algorithm: dispatch the ready node with the
    smallest ``key(node, index)``.

    On a pure-comm lowering whose sort order is already topological
    (both shipped sort keys are monotone along hop/window edges) this
    yields exactly the globally sorted order, so ``round_robin`` stays
    the identity on a fresh lowering. On heterogeneous graphs the buffer
    edges gate compute nodes behind their operands while ready copies
    keep flowing — the compute-aware interleave.
    """
    n = graph.num_nodes
    succs: dict[int, list[int]] = {}
    indeg = [0] * n
    for e in graph.edges:
        succs.setdefault(e.src, []).append(e.dst)
        indeg[e.dst] += 1
    ready = [(key(graph.nodes[i], i), i)
             for i in range(n) if indeg[i] == 0]
    heapq.heapify(ready)
    order: list[int] = []
    while ready:
        _, i = heapq.heappop(ready)
        order.append(i)
        for j in succs.get(i, ()):
            indeg[j] -= 1
            if indeg[j] == 0:
                heapq.heappush(ready, (key(graph.nodes[j], j), j))
    if len(order) != n:
        raise ValueError("dependency cycle in transfer graph")
    return order


def _rr_key(n, i: int) -> tuple:
    """Round-robin priority: chunk waves across paths; ready copies
    dispatch before ready computes (class marker 0 < 1) so copies slot
    into compute gaps — part of the §2.2 compute-aware contract."""
    if isinstance(n, ComputeNode):
        return (n.window, 1, i, 0, 0, 0)
    return (n.window, 0, n.msg_idx, n.chunk_idx, n.path_idx, n.hop_idx)


def _serialization_slot(nd) -> tuple:
    """The resource a node serializes on: its per-link slot for copies,
    the one shared compute stream for kernels (mirrors
    :meth:`TransferGraph.serialization_edges` — the two must agree or
    the greedy would optimize a different objective than the validator
    derives)."""
    if isinstance(nd, ComputeNode):
        return ("compute",)
    return (nd.msg_idx, nd.path_idx, nd.window, nd.hop_idx)


def _lane_key(nd) -> tuple:
    """The resource lane a node occupies in the lane makespan model: its
    directional link for copies (link-exclusive transfer engine), the
    shared SPMD compute lane for kernels (mirrors
    :func:`repro.core.pipelining.lane_intervals_s` — the ``overlap``
    greedy and the ``auto`` scorer must price the same objective)."""
    if isinstance(nd, ComputeNode):
        return ("compute",)
    return ("link",) + tuple(nd.link)


def _df_key(n, i: int) -> tuple:
    """Depth-first priority: drain each path's chunk chain; compute
    nodes follow ready copies in original index order (same §2.2
    compute-aware rule as :func:`_rr_key`)."""
    if isinstance(n, ComputeNode):
        return (n.window, 1, i, 0, 0, 0)
    return (n.window, 0, n.msg_idx, n.path_idx, n.chunk_idx, n.hop_idx)


class RoundRobinSchedule:
    """The paper's Algorithm 1 dispatch order — chunk waves interleaved
    across paths — which is exactly the lowering's emission order.

    Identity on a fresh lowering (same graph object, same digest): this
    pass exists so the ordering is *owned by the pipeline* rather than
    baked into the emitter, and so other passes have a baseline to be
    scored against. Compute-aware on heterogeneous graphs: ready copies
    dispatch before ready compute nodes, which serialize in program
    order. Preserves every §4.5 invariant trivially.
    """

    name = "round_robin"

    def __call__(self, graph: TransferGraph) -> TransferGraph:
        """Renumber into round-robin order (identity on a fresh
        pure-comm lowering — same object, same digest; §2.2)."""
        return reindex(graph, _constrained_order(graph, _rr_key))


class DepthFirstSchedule:
    """Drain each path's entire chunk chain before switching paths.

    Minimizes per-link switchover (each directional link is serviced in
    one contiguous burst per window round) at the cost of starting path
    *k* only after all of path *k−1*'s copies have been issued — the
    modeled issue chain prices that delay, which is why ``auto`` rarely
    picks it on multi-path plans. Compute-aware like ``round_robin``.
    Preserves the §4.5 invariants: only node indices (and thus
    serialization-edge order) change.
    """

    name = "depth_first"

    def __call__(self, graph: TransferGraph) -> TransferGraph:
        """Renumber into depth-first order under the stored-edge
        constraints (§2.2: content untouched, digest reflects order)."""
        return reindex(graph, _constrained_order(graph, _df_key))


class CriticalPathSchedule:
    """Greedy list scheduling: dispatch the ready node that finishes
    earliest under the §4.4 weighted model, ties to the most downstream
    work (longest-remaining-chain first).

    Reorders serialization edges — the only §2.2 freedom — to shorten
    the scheduled DAG's modeled critical path
    (:func:`repro.core.pipelining.scheduled_time_s`): e.g. a remainder
    chunk on a staged path is dispatched where its extra bytes overlap
    other paths' steady state instead of tailing the pipeline.
    Construct with the :class:`~repro.core.topology.Topology` to weight
    nodes by contended link bandwidth; without one, weights fall back to
    raw chunk bytes (uniform links). Deterministic; preserves the node
    multiset, edge set, and §4.5 invariants (enforced by ``check_pass``).
    """

    name = "critical_path"

    def __init__(self, topology: Topology | None = None):
        self.topology = topology

    def _weights(self, graph: TransferGraph) -> tuple[list[float], float]:
        """(per-node seconds, per-issue-slot seconds) — the §4.4 model.

        With a topology this is exactly
        :func:`repro.core.pipelining.graph_node_weights_s` plus the
        compiled per-node launch cost, so the greedy optimizes the same
        objective :func:`~repro.core.pipelining.scheduled_time_s` (the
        ``auto`` arbiter) scores it on — and when the topology carries a
        live calibration profile (DESIGN §4.4c) both terms are the
        *fitted* ones: bandwidths via the topology's calibrated link
        overlay, the issue slot via
        :func:`~repro.core.pipelining.launch_model_for`. Without a
        topology, weights degrade to raw chunk bytes on uniform links
        (compute nodes to their declared cost) and the issue term
        vanishes — invariants are preserved either way, only the
        heuristic's objective coarsens.
        """
        if self.topology is not None:
            from repro.core.pipelining import (graph_node_weights_s,
                                               launch_model_for)
            launch = launch_model_for(self.topology)
            return (graph_node_weights_s(graph, self.topology),
                    launch.graph_launch_per_node_ns / 1e9)
        return [float(n.cost_ns or n.flops)
                if isinstance(n, ComputeNode) else float(n.nbytes)
                for n in graph.nodes], 0.0

    def __call__(self, graph: TransferGraph) -> TransferGraph:
        n = graph.num_nodes
        if n == 0:
            return graph
        weight, issue_s = self._weights(graph)
        succs: dict[int, list[int]] = {}
        indeg = [0] * n
        for e in graph.edges:
            succs.setdefault(e.src, []).append(e.dst)
            indeg[e.dst] += 1
        # downstream work along stored edges (each node has at most one
        # hop successor and one window successor), for tie-breaking
        down = list(weight)
        for i in reversed(graph.topological_order()):
            for j in succs.get(i, ()):
                down[i] = max(down[i], weight[i] + down[j])
        canonical = {
            i: ((nd.window, 1, i, 0, 0, 0)
                if isinstance(nd, ComputeNode) else
                (nd.window, 0, nd.msg_idx, nd.chunk_idx, nd.path_idx,
                 nd.hop_idx))
            for i, nd in enumerate(graph.nodes)}
        slot_free: dict[tuple, float] = {}   # per-link serialization slot
        finish: dict[int, float] = {}
        preds: dict[int, list[int]] = {}
        for e in graph.edges:
            preds.setdefault(e.dst, []).append(e.src)
        ready = {i for i in range(n) if indeg[i] == 0}
        order: list[int] = []
        while ready:
            k = len(order)
            best, best_key = None, None
            for i in ready:
                nd = graph.nodes[i]
                slot = _serialization_slot(nd)
                start = max((finish[p] for p in preds.get(i, ())),
                            default=0.0)
                start = max(start, slot_free.get(slot, 0.0), k * issue_s)
                key = (start + weight[i], -down[i], canonical[i])
                if best_key is None or key < best_key:
                    best, best_key = i, key
            i = best
            nd = graph.nodes[i]
            slot = _serialization_slot(nd)
            start = max((finish[p] for p in preds.get(i, ())), default=0.0)
            start = max(start, slot_free.get(slot, 0.0), k * issue_s)
            finish[i] = slot_free[slot] = start + weight[i]
            order.append(i)
            ready.remove(i)
            for j in succs.get(i, ()):
                indeg[j] -= 1
                if indeg[j] == 0:
                    ready.add(j)
        return reindex(graph, order)


class OverlapSchedule(CriticalPathSchedule):
    """List scheduling over the resource-lane makespan model: hide
    copies behind compute (§2.2 reorder-only pass, no ``allows_rewrite``).

    Simulates the lane model of
    :func:`repro.core.pipelining.lane_intervals_s` — each directional
    link an exclusive FIFO transfer lane, all kernels one SPMD compute
    lane, per-node launch cost charged to the executing lane — and
    repeatedly dispatches the ready node with the earliest feasible
    start (ties to earliest finish, then most downstream work). Copies
    whose deps are satisfied are therefore issued *before* later compute
    and make progress behind it on the modeled timeline. If the greedy
    order does not model strictly faster than the input order (list-
    scheduling anomalies are real), the input order is returned
    unchanged — ``overlap`` never models worse than its input, which is
    what keeps ``auto`` never-worse-than-``round_robin`` under the lane
    objective. Deterministic; preserves the node multiset, edge set, and
    §4.5 invariants (enforced by ``check_pass``). Construct with a
    :class:`~repro.core.topology.Topology` for §4.4-priced (and
    calibrated, §4.4c/§4.4d) durations; without one, weights degrade to
    raw bytes / declared compute cost.
    """

    name = "overlap"

    def _lane_makespan(self, graph: TransferGraph, order: Sequence[int],
                       weight: Sequence[float], issue_s: float,
                       preds: dict[int, list[int]]) -> float:
        """Lane-model makespan of dispatching ``graph`` in ``order``
        (must be topological); mirrors
        :func:`repro.core.pipelining.lane_intervals_s` so the pass
        optimizes exactly the objective ``auto`` scores it on."""
        lane_free: dict[tuple, float] = {}
        finish: dict[int, float] = {}
        makespan = 0.0
        for old in order:
            lane = _lane_key(graph.nodes[old])
            start = max((finish[p] for p in preds.get(old, ())),
                        default=0.0)
            start = max(start, lane_free.get(lane, 0.0))
            finish[old] = lane_free[lane] = start + weight[old] + issue_s
            makespan = max(makespan, finish[old])
        return makespan

    def __call__(self, graph: TransferGraph) -> TransferGraph:
        """Renumber into the greedy lane-model order when it models
        strictly faster; identity otherwise (§2.2 contract either way)."""
        n = graph.num_nodes
        if n == 0:
            return graph
        weight, issue_s = self._weights(graph)
        succs: dict[int, list[int]] = {}
        indeg = [0] * n
        preds: dict[int, list[int]] = {}
        for e in graph.edges:
            succs.setdefault(e.src, []).append(e.dst)
            preds.setdefault(e.dst, []).append(e.src)
            indeg[e.dst] += 1
        down = list(weight)
        for i in reversed(graph.topological_order()):
            for j in succs.get(i, ()):
                down[i] = max(down[i], weight[i] + down[j])
        canonical = {
            i: ((nd.window, 1, i, 0, 0, 0)
                if isinstance(nd, ComputeNode) else
                (nd.window, 0, nd.msg_idx, nd.chunk_idx, nd.path_idx,
                 nd.hop_idx))
            for i, nd in enumerate(graph.nodes)}
        lane_free: dict[tuple, float] = {}
        finish: dict[int, float] = {}
        ready = {i for i in range(n) if indeg[i] == 0}
        order: list[int] = []
        while ready:
            best, best_key = None, None
            for i in ready:
                start = max((finish[p] for p in preds.get(i, ())),
                            default=0.0)
                start = max(start,
                            lane_free.get(_lane_key(graph.nodes[i]), 0.0))
                key = (start, start + weight[i], -down[i], canonical[i])
                if best_key is None or key < best_key:
                    best, best_key = i, key
            i = best
            lane = _lane_key(graph.nodes[i])
            start = max((finish[p] for p in preds.get(i, ())), default=0.0)
            start = max(start, lane_free.get(lane, 0.0))
            finish[i] = lane_free[lane] = start + weight[i] + issue_s
            order.append(i)
            ready.remove(i)
            for j in succs.get(i, ()):
                indeg[j] -= 1
                if indeg[j] == 0:
                    ready.add(j)
        greedy = self._lane_makespan(graph, order, weight, issue_s, preds)
        identity = self._lane_makespan(graph, range(n), weight, issue_s,
                                       preds)
        if greedy >= identity:          # anomaly guard: never model worse
            return graph
        return reindex(graph, order)


class AutoSchedule:
    """Score every candidate dispatch order with the scheduled-DAG model
    and pick the winner BEFORE compiling.

    Candidates are the shipped concrete schedulers (``round_robin``
    first, ``overlap`` last); :func:`repro.core.pipelining.scheduled_time_s`
    arbitrates — the serialized chain on pure-comm graphs, the lane
    makespan on heterogeneous ones — and a strict improvement is
    required to displace an earlier candidate, so ``auto`` can never
    select a schedule the model scores worse than ``round_robin``.
    Requires a :class:`~repro.core.topology.Topology` (the model needs
    link bandwidths). The §4.5 invariants hold because every candidate
    is a contract-checked pass output. Candidate scores are memoized on
    ``(graph digest, topology epoch)`` — any topology mutation or
    calibration (re)attachment bumps the epoch and re-scores — with
    hit/miss counters surfaced via :meth:`score_stats` (the engine's
    ``schedule_scores`` stat).
    """

    name = "auto"

    #: Class-level score memo shared by every instance (mirrors the
    #: engine's schedule memo keying); bounded LRU.
    _memo: OrderedDict = OrderedDict()
    _memo_capacity = 256
    _stats = {"hits": 0, "misses": 0}

    def __init__(self, topology: Topology):
        self.topology = topology
        self.candidates: tuple[GraphPass, ...] = (
            RoundRobinSchedule(), DepthFirstSchedule(),
            CriticalPathSchedule(topology), OverlapSchedule(topology))

    @classmethod
    def score_stats(cls, reset: bool = False) -> dict[str, int]:
        """Hit/miss counters of the candidate-score memo (the
        ``schedule_scores`` stat); measurements only — never feed cache
        keys. ``reset=True`` zeroes them after reading."""
        out = dict(cls._stats)
        if reset:
            cls._stats.update(hits=0, misses=0)
        return out

    def select(self, graph: TransferGraph
               ) -> tuple[str, TransferGraph, dict[str, float]]:
        """(winner name, scheduled graph, per-candidate modeled seconds).

        Memoized on ``(graph.digest(), topology.epoch)`` — re-scoring
        every candidate on every miss is pure waste when neither the
        graph content nor the model terms changed."""
        from repro.core.pipelining import scheduled_time_s

        epoch = getattr(self.topology, "epoch", None)
        key = (graph.digest(), epoch) if epoch is not None else None
        if key is not None:
            hit = AutoSchedule._memo.get(key)
            if hit is not None:
                AutoSchedule._memo.move_to_end(key)
                AutoSchedule._stats["hits"] += 1
                return hit
            AutoSchedule._stats["misses"] += 1
        scores: dict[str, float] = {}
        best_name, best_graph, best_t = None, None, float("inf")
        for cand in self.candidates:
            scheduled = cand(graph)
            check_pass(graph, scheduled)
            t = scheduled_time_s(scheduled, self.topology)
            scores[cand.name] = t
            if t < best_t:                      # strict: ties keep earlier
                best_name, best_graph, best_t = cand.name, scheduled, t
        assert best_graph is not None
        result = (best_name, best_graph, scores)
        if key is not None:
            AutoSchedule._memo[key] = result
            while len(AutoSchedule._memo) > AutoSchedule._memo_capacity:
                AutoSchedule._memo.popitem(last=False)
        return result

    def __call__(self, graph: TransferGraph) -> TransferGraph:
        """Apply the winning candidate (see :meth:`select`); the result
        is a contract-checked §2.2 pass output."""
        return self.select(graph)[1]


def make_schedule(name: str, topology: Topology | None = None) -> GraphPass:
    """Resolve a scheduler name from :data:`SCHEDULE_NAMES` to a pass.

    ``topology`` feeds the model-weighted passes (``critical_path``
    weights, ``auto`` scoring) and is required for ``auto``. The returned
    object satisfies :class:`GraphPass` and the §2.2 contract.
    """
    if name == RoundRobinSchedule.name:
        return RoundRobinSchedule()
    if name == DepthFirstSchedule.name:
        return DepthFirstSchedule()
    if name == CriticalPathSchedule.name:
        return CriticalPathSchedule(topology)
    if name == OverlapSchedule.name:
        return OverlapSchedule(topology)
    if name == AutoSchedule.name:
        if topology is None:
            raise ValueError("schedule 'auto' needs a topology to score "
                             "candidate orders")
        return AutoSchedule(topology)
    raise ValueError(f"unknown schedule {name!r}; expected one of "
                     f"{SCHEDULE_NAMES}")


def apply_schedule(graph: TransferGraph,
                   schedule: str | GraphPass = "round_robin",
                   topology: Topology | None = None
                   ) -> tuple[TransferGraph, str]:
    """Apply one scheduler between ``lower()`` and the emitter.

    The ONE entry point the engine, ``session.describe``, the dry-run,
    and the benchmarks share: resolves ``schedule`` (name or pass
    object), applies it, enforces the §2.2 contract (:func:`check_pass`)
    so §4.5 invariants and digest semantics cannot be silently broken,
    and returns ``(scheduled graph, concrete schedule name)`` — for
    ``auto`` the name of the candidate the model actually picked. A pass
    declaring the ``allows_rewrite`` capability is checked under the
    relaxed contract (node rewrites allowed, §4.5 still enforced).
    """
    sched = (make_schedule(schedule, topology)
             if isinstance(schedule, str) else schedule)
    if isinstance(sched, AutoSchedule):
        name, scheduled, _ = sched.select(graph)   # candidates pre-checked
        return scheduled, name
    scheduled = sched(graph)
    if scheduled is not graph:     # identity (e.g. default round_robin on
        check_pass(graph, scheduled,  # a fresh lowering) is a provable no-op
                   allows_rewrite=bool(getattr(sched, "allows_rewrite",
                                               False)))
    return scheduled, sched.name


def run_pipeline(graph: TransferGraph,
                 passes: Iterable[str | GraphPass],
                 topology: Topology | None = None) -> TransferGraph:
    """Run a sequence of passes, contract-checked after each stage.

    The general pass-pipeline hook (future passes — e.g. the host-staged
    pricing rewrite on the ROADMAP — chain here ahead of a scheduler);
    every stage is held to the §2.2 contract via :func:`apply_schedule`,
    so invariants are re-validated and the final digest reflects the
    composed schedule.
    """
    for p in passes:
        graph, _ = apply_schedule(graph, p, topology)
    return graph
