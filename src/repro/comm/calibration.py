"""Online calibration of the §4.4 model from recorded dispatches (§4.4c).

The analytic model ships with nominal constants (per-link bandwidths from
the topology, :data:`~repro.core.pipelining.DEFAULT_LAUNCH_MODEL` for
launch overheads). Real machines diverge — De Sensi et al. measure
per-link effective bandwidth far off nominal — so this module closes the
loop: it regresses the model's terms from the
:class:`~repro.comm.telemetry.DispatchSample` stream and persists them as
a :class:`CalibrationProfile` keyed by the topology's structural digest.

Fitting contract (robustness gates, DESIGN §4.4c):

* **warmup** — the first ``warmup`` samples of every distinct sample
  signature are dropped (first dispatches pay compilation/alloc noise);
* **minimum samples** — a per-link bandwidth (or the launch model) is
  only emitted once backed by ``min_samples`` observations, so a single
  outlier can never flip an arbitration;
* **exponential decay** — bandwidth estimates update multiplicatively in
  log space with per-sample gain ``decay``, so drift is tracked while
  old evidence decays geometrically;
* **ratio clamp** — one sample can move an estimate by at most a factor
  of ``max_ratio``, bounding the damage of a mis-attributed stall.

Consumption contract: a profile attaches via
:meth:`repro.core.topology.Topology.set_calibration`, which *validates*
the digest match (wrong-machine profiles are refused) and bumps the plan
epoch so every cached arbitration is re-derived from fitted terms. The
profile file is versioned (:data:`PROFILE_VERSION`); loading a payload
with a different version raises rather than misinterpreting fields.
"""

from __future__ import annotations

import dataclasses
import json
import os
import statistics
from collections import defaultdict
from typing import TYPE_CHECKING, Iterable, Sequence

from repro.core.pipelining import DEFAULT_LAUNCH_MODEL, LaunchModel
from repro.core.topology import HOST, Topology

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.comm.telemetry import DispatchSample

#: On-disk schema version. Bump on any incompatible payload change; the
#: loader validates it and refuses (raises) on mismatch — a stale file
#: must never be silently reinterpreted.
PROFILE_VERSION = 1

_LinkKey = tuple  # (src, dst)


def _wire_model_s(routes, window: int,
                  bw_gbps: dict[_LinkKey, float]
                  ) -> tuple[float, tuple[_LinkKey, ...]]:
    """Closed-form §4.4 wire time of a sample's recorded routes under a
    bandwidth map, plus the critical path's links (for attribution)."""
    counts: dict[_LinkKey, int] = defaultdict(int)
    host_paths = 0
    for msg in routes:
        for (links, _nbytes, _nchunks) in msg:
            for ln in links:
                counts[ln] += 1
            if any(HOST in ln for ln in links):
                host_paths += 1
    best, crit = 0.0, ()
    for msg in routes:
        for (links, nbytes, nchunks) in msg:
            n = max(1, nchunks)
            chunk_bytes = nbytes / n
            hop_times = []
            for ln in links:
                bw = bw_gbps.get(ln)
                if not bw or bw <= 0:
                    return 0.0, ()  # unknown link: cannot model
                share = max(1, counts[ln])
                if HOST in ln and host_paths > 1:
                    share = max(share, host_paths)
                hop_times.append(chunk_bytes / (bw * 1e9 / share))
            t = sum(hop_times) + (n - 1) * max(hop_times)
            if t > best:
                best, crit = t, links
    return best * max(1, window), crit


def _wls_line(points: Sequence[tuple[float, float, float]]
              ) -> tuple[float, float]:
    """Weighted least-squares line fit ``y = slope*x + intercept`` over
    ``(x, y, weight)`` triples (>= 2 distinct x assumed)."""
    wsum = sum(w for _, _, w in points)
    xbar = sum(w * x for x, _, w in points) / wsum
    ybar = sum(w * y for _, y, w in points) / wsum
    den = sum(w * (x - xbar) ** 2 for x, _, w in points)
    if den <= 0:
        return 0.0, ybar
    slope = sum(w * (x - xbar) * (y - ybar) for x, y, w in points) / den
    return slope, ybar - slope * xbar


def _fit_line_ns(pairs: Sequence[tuple[int, float]],
                 default_slope: float) -> tuple[float, float]:
    """Robust per-node-count regression: median ns per distinct node
    count, then a weighted line, clamped to non-negative terms."""
    by_n: dict[int, list[float]] = defaultdict(list)
    for n, v in pairs:
        by_n[n].append(v)
    meds = [(float(n), statistics.median(vs), float(len(vs)))
            for n, vs in sorted(by_n.items())]
    if len(meds) >= 2:
        slope, intercept = _wls_line(meds)
        if slope < 0:
            slope = 0.0
            intercept = (sum(m[1] * m[2] for m in meds)
                         / sum(m[2] for m in meds))
    else:
        (x0, y0, _), = meds
        slope = default_slope
        intercept = y0 - x0 * slope
    return max(0.0, slope), max(0.0, intercept)


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """Fitted §4.4 model terms for ONE topology shape, persistable as JSON.

    The identity invariant: :attr:`topology_digest` is the structural
    digest (:meth:`repro.core.topology.Topology.digest`) of the machine
    the samples came from; :meth:`~repro.core.topology.Topology.\
    set_calibration` validates it and refuses a mismatch, so fitted
    terms can never be applied to a different link graph. ``link_bandwidth_gbps``
    holds only links that passed the fitter's minimum-sample gate;
    ``launch`` is ``None`` when launch terms did not (consumers fall
    back to :data:`~repro.core.pipelining.DEFAULT_LAUNCH_MODEL`).
    ``kernel_cost_ns`` maps kernel names to fitted median execute ns —
    the per-kernel compute term that replaces the ``COMPUTE_GFLOPS``
    constant in :func:`~repro.core.pipelining.compute_time_s` when the
    profile is attached; empty when no kernel evidence passed the gate.
    """

    topology_digest: str
    link_bandwidth_gbps: dict[_LinkKey, float] = dataclasses.field(
        default_factory=dict)
    launch: LaunchModel | None = None
    link_samples: dict[_LinkKey, int] = dataclasses.field(
        default_factory=dict)
    launch_samples: int = 0
    kernel_cost_ns: dict[str, float] = dataclasses.field(
        default_factory=dict)
    kernel_samples: dict[str, int] = dataclasses.field(
        default_factory=dict)
    version: int = PROFILE_VERSION

    def summary(self) -> dict:
        """Compact schema-stable dict for ``session.describe()``:
        digest, fitted-link count, whether launch terms are live,
        fitted-kernel count — enough to audit which terms an
        arbitration consumed."""
        return {"topology_digest": self.topology_digest,
                "version": self.version,
                "links_fitted": len(self.link_bandwidth_gbps),
                "launch_fitted": self.launch is not None,
                "launch_samples": self.launch_samples,
                "kernels_fitted": len(self.kernel_cost_ns)}

    def to_payload(self) -> dict:
        """Versioned JSON-safe payload (the inverse of
        :meth:`from_payload`; round-trip is validated by the test
        suite). Link keys serialize as ``"src,dst"`` strings."""
        return {
            "version": self.version,
            "topology_digest": self.topology_digest,
            "links": {f"{s},{d}": {"bandwidth_gbps": bw,
                                   "samples": self.link_samples.get(
                                       (s, d), 0)}
                      for (s, d), bw in sorted(
                          self.link_bandwidth_gbps.items())},
            "launch": (dataclasses.asdict(self.launch)
                       if self.launch is not None else None),
            "launch_samples": self.launch_samples,
            "kernels": {name: {"cost_ns": cost,
                               "samples": self.kernel_samples.get(name, 0)}
                        for name, cost in sorted(
                            self.kernel_cost_ns.items())},
        }

    @classmethod
    def from_payload(cls, payload: dict) -> "CalibrationProfile":
        """Parse a payload produced by :meth:`to_payload`, validating
        the schema version — a mismatched :data:`PROFILE_VERSION`
        raises ``ValueError`` instead of misreading fields."""
        version = payload.get("version")
        if version != PROFILE_VERSION:
            raise ValueError(
                f"calibration profile version {version!r} != supported "
                f"{PROFILE_VERSION} — refusing to reinterpret")
        links, counts = {}, {}
        for key, entry in payload.get("links", {}).items():
            s, d = (int(x) for x in key.split(","))
            links[(s, d)] = float(entry["bandwidth_gbps"])
            counts[(s, d)] = int(entry.get("samples", 0))
        raw = payload.get("launch")
        launch = LaunchModel(**raw) if raw is not None else None
        kernels, kcounts = {}, {}
        for name, entry in payload.get("kernels", {}).items():
            kernels[name] = float(entry["cost_ns"])
            kcounts[name] = int(entry.get("samples", 0))
        return cls(topology_digest=str(payload["topology_digest"]),
                   link_bandwidth_gbps=links, launch=launch,
                   link_samples=counts,
                   launch_samples=int(payload.get("launch_samples", 0)),
                   kernel_cost_ns=kernels, kernel_samples=kcounts)

    def filename(self) -> str:
        """Canonical per-digest file name — one profile per machine
        shape in a profiles dir, so load-on-init can key lookup by the
        session topology's digest."""
        return f"profile-{self.topology_digest}.json"

    def save(self, profiles_dir: str) -> str:
        """Persist under ``profiles_dir`` (created if missing) at the
        digest-keyed :meth:`filename`; returns the written path. The
        payload is the versioned :meth:`to_payload` schema."""
        os.makedirs(profiles_dir, exist_ok=True)
        path = os.path.join(profiles_dir, self.filename())
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.to_payload(), fh, indent=2, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationProfile":
        """Read one profile file; raises ``ValueError`` on a version
        mismatch (see :meth:`from_payload`) and ``OSError`` if
        unreadable."""
        with open(path, encoding="utf-8") as fh:
            return cls.from_payload(json.load(fh))

    @classmethod
    def load_for(cls, topology: Topology,
                 profiles_dir: str) -> "CalibrationProfile | None":
        """Load the profile matching ``topology.digest()`` from a
        profiles dir, or ``None`` when absent. A file whose recorded
        digest contradicts its digest-keyed name raises ``ValueError``
        — the wrong-machine refusal invariant."""
        digest = topology.digest()
        path = os.path.join(profiles_dir, f"profile-{digest}.json")
        if not os.path.exists(path):
            return None
        profile = cls.load(path)
        if profile.topology_digest != digest:
            raise ValueError(
                f"profile at {path} carries digest "
                f"{profile.topology_digest!r} but topology digest is "
                f"{digest!r}")
        return profile


class CalibrationFitter:
    """Regress §4.4 model terms from a chronological sample stream.

    Implements the §4.4c fitting contract documented in the module
    docstring: warmup dropping per sample signature, minimum-sample
    gating before any term is emitted, multiplicative exponential-decay
    bandwidth updates clamped to ``max_ratio`` per observation, and a
    median-based robust line fit for the launch/instantiate terms.
    """

    def __init__(self, topology: Topology, *, min_samples: int = 3,
                 warmup: int = 1, decay: float = 0.5,
                 max_ratio: float = 16.0):
        if min_samples < 1:
            raise ValueError(f"min_samples must be >= 1, got {min_samples}")
        if warmup < 0:
            raise ValueError(f"warmup must be >= 0, got {warmup}")
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        if max_ratio <= 1.0:
            raise ValueError(f"max_ratio must be > 1, got {max_ratio}")
        self.topology = topology
        self.min_samples = min_samples
        self.warmup = warmup
        self.decay = decay
        self.max_ratio = max_ratio

    def _drop_warmup(self, samples: Iterable["DispatchSample"]
                     ) -> list["DispatchSample"]:
        """Drop the first ``warmup`` samples per signature (outlier
        robustness: first dispatches carry compile/alloc noise that
        would otherwise contaminate every fitted term)."""
        seen: dict[tuple, int] = defaultdict(int)
        out = []
        for s in samples:
            seen[s.signature] += 1
            if seen[s.signature] > self.warmup:
                out.append(s)
        return out

    def _fit_launch(self, samples: Sequence["DispatchSample"]
                    ) -> tuple[LaunchModel | None, int]:
        """Fit graph launch + instantiate terms from (node count,
        measured ns) pairs — median per node count then a weighted
        line, gated by ``min_samples`` (else ``None``). Captured-step
        samples (non-empty ``compute``) are excluded — a kernel node's
        launch cost is not a copy node's, and pooling them would bend
        the fitted per-node slope (§4.4c signature invariant)."""
        samples = [s for s in samples if not getattr(s, "compute", ())]
        launch_pts = [(s.num_nodes, float(s.stages.launch_ns))
                      for s in samples if s.stages.launch_ns > 0]
        if len(launch_pts) < self.min_samples:
            return None, 0
        slope, base = _fit_line_ns(
            launch_pts, DEFAULT_LAUNCH_MODEL.graph_launch_per_node_ns)
        fitted = dataclasses.replace(
            DEFAULT_LAUNCH_MODEL,
            graph_launch_base_ns=base, graph_launch_per_node_ns=slope)
        inst_pts = [(s.num_nodes, float(s.stages.compile_ns))
                    for s in samples if s.stages.compile_ns > 0]
        if len(inst_pts) >= self.min_samples:
            islope, ibase = _fit_line_ns(
                inst_pts,
                DEFAULT_LAUNCH_MODEL.graph_instantiate_per_node_ns)
            fitted = dataclasses.replace(
                fitted, graph_instantiate_base_ns=ibase,
                graph_instantiate_per_node_ns=islope)
        return fitted, len(launch_pts)

    def _fit_bandwidth(self, samples: Sequence["DispatchSample"]
                       ) -> tuple[dict[_LinkKey, float],
                                  dict[_LinkKey, int]]:
        """Chronological multiplicative EMA over critical-path links:
        each sample moves its bottleneck links' estimates by
        ``ratio**-decay`` (ratio = measured/modeled, clamped to
        ``max_ratio``) — time scales as 1/bandwidth, so a slow link is
        attributed a proportionally lower fitted bandwidth.

        Captured-step samples (non-empty ``compute`` identity) are
        excluded: their execute time includes kernel work the wire model
        cannot attribute to links, so pooling them would corrupt the
        fitted bandwidths — the §4.4c signature invariant."""
        est = {k: ln.bandwidth_gbps
               for k, ln in self.topology.links.items()}
        counts: dict[_LinkKey, int] = defaultdict(int)
        for s in samples:
            if getattr(s, "compute", ()):
                continue
            measured = s.stages.execute_ns / 1e9
            if measured <= 0:
                continue
            modeled, crit = _wire_model_s(s.routes, s.window, est)
            if modeled <= 0 or not crit:
                continue
            ratio = min(self.max_ratio,
                        max(1.0 / self.max_ratio, measured / modeled))
            step = ratio ** (-self.decay)
            for ln in crit:
                est[ln] *= step
                counts[ln] += 1
        fitted = {k: round(est[k], 6) for k, c in counts.items()
                  if c >= self.min_samples}
        return fitted, {k: counts[k] for k in fitted}

    def _fit_kernels(self, kernels: dict[str, Sequence[float]]
                     ) -> tuple[dict[str, float], dict[str, int]]:
        """Fit per-kernel execute costs from the recorder's kernel
        channel (``{name: chronological execute_ns}``): the first
        ``warmup`` measurements per kernel are dropped (compile noise),
        the remainder must clear ``min_samples``, and the fitted term
        is the median — the same robustness gates the wire terms get.
        Non-positive medians are discarded: a fitted compute term of
        zero would silently hide a kernel from the lane model."""
        fitted: dict[str, float] = {}
        counts: dict[str, int] = {}
        for name, values in kernels.items():
            usable = [float(v) for v in list(values)[self.warmup:]
                      if v > 0]
            if len(usable) < self.min_samples:
                continue
            med = statistics.median(usable)
            if med <= 0:
                continue
            fitted[name] = round(med, 3)
            counts[name] = len(usable)
        return fitted, counts

    def fit(self, samples: Iterable["DispatchSample"],
            kernels: dict[str, Sequence[float]] | None = None
            ) -> CalibrationProfile:
        """Produce a :class:`CalibrationProfile` for the fitter's
        topology digest. Applies every §4.4c gate; with too little
        evidence the profile is simply sparse (no fitted links and/or
        ``launch=None``) — it never invents terms to preserve the
        constants-as-fallback contract. ``kernels`` is the *separate*
        per-kernel execute channel from
        :meth:`~repro.comm.telemetry.TimelineRecorder.kernel_samples`;
        keeping it apart from ``samples`` preserves the invariant that
        captured-step dispatch samples never pool with pure-comm wire
        evidence."""
        usable = self._drop_warmup(samples)
        launch, n_launch = self._fit_launch(usable)
        bw, counts = self._fit_bandwidth(usable)
        kcost, kcounts = self._fit_kernels(kernels or {})
        return CalibrationProfile(
            topology_digest=self.topology.digest(),
            link_bandwidth_gbps=bw, launch=launch,
            link_samples=counts, launch_samples=n_launch,
            kernel_cost_ns=kcost, kernel_samples=kcounts)


def modeled_sample_time_s(sample: "DispatchSample", topology: Topology,
                          profile: CalibrationProfile | None = None
                          ) -> float:
    """Re-price one recorded dispatch with the §4.4 model: closed-form
    wire time over the sample's recorded routes plus graph launch
    overhead. ``profile=None`` prices nominal topology bandwidths and
    the constant launch model; passing a profile overlays its fitted
    terms — the same substitution the live model performs, so the
    residuals this enables validate exactly what arbitration consumes."""
    bw = {k: ln.bandwidth_gbps for k, ln in topology.links.items()}
    launch = DEFAULT_LAUNCH_MODEL
    if profile is not None:
        bw.update(profile.link_bandwidth_gbps)
        if profile.launch is not None:
            launch = profile.launch
    wire, _ = _wire_model_s(sample.routes, sample.window, bw)
    overhead_ns = (launch.graph_launch_base_ns
                   + sample.num_nodes * launch.graph_launch_per_node_ns)
    return wire + overhead_ns / 1e9


def modeled_vs_measured(samples: Iterable["DispatchSample"],
                        topology: Topology,
                        profile: CalibrationProfile | None = None) -> dict:
    """Residual report: constant-model vs fitted-model relative error
    against measured dispatch time, aggregated over ``samples``.

    The drift-visibility contract behind ``session.describe()``'s
    ``calibration.residuals`` section: ``constant`` is always present;
    ``fitted`` appears when a profile is supplied. Each side reports
    ``{mean_rel_err, median_rel_err}`` of ``|modeled - measured| /
    measured`` — a fitted profile that stops beating the constants is
    visible drift."""
    const_errs, fitted_errs = [], []
    n = 0
    for s in samples:
        measured = s.measured_s
        if measured <= 0:
            continue
        n += 1
        const_t = modeled_sample_time_s(s, topology, None)
        const_errs.append(abs(const_t - measured) / measured)
        if profile is not None:
            fit_t = modeled_sample_time_s(s, topology, profile)
            fitted_errs.append(abs(fit_t - measured) / measured)

    def _agg(errs):
        if not errs:
            return None
        return {"mean_rel_err": sum(errs) / len(errs),
                "median_rel_err": statistics.median(errs)}

    return {"num_samples": n, "constant": _agg(const_errs),
            "fitted": _agg(fitted_errs) if profile is not None else None}
