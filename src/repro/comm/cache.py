"""TransferPlanCache — the CUDA-Graph-cache analogue (paper §4.2).

The paper caches instantiated ``cudaGraphExec_t`` objects in a fixed-size
LRU hash table keyed on (src, dst, size, path config). In JAX the analogue
of the CUDA-Graph lifecycle is the AOT pipeline (DESIGN.md §2):

=================  =========================================
paper (CUDA)       this repo (JAX/XLA)
=================  =========================================
creation           building the python callable / jaxpr trace
construction       ``jit(f).trace(...)`` → ``.lower()`` (StableHLO)
instantiation      ``lowered.compile()`` (expensive, one-time)
launch             dispatch of the compiled executable (cheap)
=================  =========================================

Every stage is timed so the lifecycle benchmark (paper Fig. 13/14) can report
first-iteration vs steady-state costs as a function of plan node count.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Callable, Hashable

import jax

from repro.comm.config import _env_int


@dataclasses.dataclass
class PlanLifecycle:
    """Nanosecond timings of each lifecycle stage for one cached plan."""

    trace_ns: int = 0        # python trace → jaxpr ("construction" part 1)
    lower_ns: int = 0        # jaxpr → StableHLO ("construction" part 2)
    compile_ns: int = 0      # XLA compile ("instantiation")
    launches: int = 0
    total_launch_ns: int = 0
    num_nodes: int = 0       # copy-node count (chunks × hops)

    @property
    def build_ns(self) -> int:
        return self.trace_ns + self.lower_ns + self.compile_ns

    @property
    def mean_launch_ns(self) -> float:
        return self.total_launch_ns / self.launches if self.launches else 0.0


@dataclasses.dataclass
class CompiledPlan:
    """An instantiated transfer graph: XLA executable + lifecycle stats."""

    key: Hashable
    compiled: Any            # jax.stages.Compiled
    lifecycle: PlanLifecycle

    def __call__(self, *args):
        t0 = time.perf_counter_ns()
        out = self.compiled(*args)
        # Block so the timing covers execution, not just dispatch; dispatch
        # cost alone is measured by the lifecycle benchmark via donated runs.
        jax.block_until_ready(out)
        self.lifecycle.launches += 1
        self.lifecycle.total_launch_ns += time.perf_counter_ns() - t0
        return out

    def dispatch(self, *args):
        """Launch without blocking (pure launch-overhead measurement)."""
        t0 = time.perf_counter_ns()
        out = self.compiled(*args)
        self.lifecycle.launches += 1
        self.lifecycle.total_launch_ns += time.perf_counter_ns() - t0
        return out


def compile_plan(key: Hashable, fn: Callable, abstract_args: tuple,
                 num_nodes: int = 0, **jit_kwargs) -> CompiledPlan:
    """Run the full trace→lower→compile pipeline with per-stage timing."""
    life = PlanLifecycle(num_nodes=num_nodes)
    jitted = jax.jit(fn, **jit_kwargs)
    t0 = time.perf_counter_ns()
    traced = jitted.trace(*abstract_args)
    t1 = time.perf_counter_ns()
    lowered = traced.lower()
    t2 = time.perf_counter_ns()
    compiled = lowered.compile()
    t3 = time.perf_counter_ns()
    life.trace_ns, life.lower_ns, life.compile_ns = t1 - t0, t2 - t1, t3 - t2
    return CompiledPlan(key, compiled, life)


class TransferPlanCache:
    """Fixed-capacity LRU cache of :class:`CompiledPlan` objects.

    Capacity defaults to ``REPRO_PLAN_CACHE_SIZE`` (paper: tunable via
    environment variables). Eviction counts are exposed for the overhead
    analysis: an eviction forces a re-instantiation on the next use, the
    dominant first-iteration cost.
    """

    def __init__(self, capacity: int | None = None):
        self.capacity = capacity if capacity is not None else _env_int(
            "REPRO_PLAN_CACHE_SIZE", 64)
        if self.capacity <= 0:
            raise ValueError("cache capacity must be positive")
        self._store: OrderedDict[Hashable, CompiledPlan] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._store

    def get(self, key: Hashable) -> CompiledPlan | None:
        plan = self._store.get(key)
        if plan is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return plan

    def put(self, key: Hashable, plan: CompiledPlan) -> None:
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = plan
        if len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def get_or_build(self, key: Hashable,
                     builder: Callable[[], CompiledPlan]) -> CompiledPlan:
        """LaunchGraph's lookup-or-create (Algorithm 1 lines 25–28)."""
        plan = self.get(key)
        if plan is None:
            plan = builder()
            self.put(key, plan)
        return plan

    def keys(self) -> list[Hashable]:
        """Current keys, least-recently-used first (eviction order)."""
        return list(self._store)

    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._store),
                "capacity": self.capacity}

    def clear(self) -> None:
        self._store.clear()
