"""TransferPlanCache — the CUDA-Graph-cache analogue (paper §4.2).

The paper caches instantiated ``cudaGraphExec_t`` objects in a fixed-size
LRU hash table keyed on (src, dst, size, path config). In JAX the analogue
of the CUDA-Graph lifecycle is the AOT pipeline (DESIGN.md §2):

=================  =========================================
paper (CUDA)       this repo (JAX/XLA)
=================  =========================================
creation           building the python callable / jaxpr trace
construction       ``jit(f).trace(...)`` → ``.lower()`` (StableHLO)
instantiation      ``lowered.compile()`` (expensive, one-time)
launch             dispatch of the compiled executable (cheap)
=================  =========================================

Every stage is timed so the lifecycle benchmark (paper Fig. 13/14) can report
first-iteration vs steady-state costs as a function of plan node count.

Steady-state dispatch additionally fronts this cache with a
:class:`FastPathCache` (DESIGN.md §2.3): entries memoize the *entire*
plan→lower→schedule→digest pipeline keyed on the request signature and an
explicit planner/topology epoch, so a repeat transfer is one dict lookup +
one staging write + one executable launch.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Callable, Hashable

import jax

from repro.comm.config import _env_int


@dataclasses.dataclass
class PlanLifecycle:
    """Nanosecond timings of each lifecycle stage for one cached plan.

    The per-stage attribution the paper's Fig. 13/14 overhead analysis
    needs (and ucTrace-style layered profiling motivates): build stages
    are one-time, ``launches``/``total_launch_ns`` accumulate steady
    state, ``staging_ns`` isolates the host-side *dispatch* of operand
    staging (staging execution overlaps the launch via dataflow and is
    accounted in the launch timings), and ``fastpath_hits`` counts
    dispatches that skipped the whole plan→lower→digest pipeline.
    Timings are measurements, not semantics — they carry no §4.5
    invariant obligations and must never feed cache keys (digest-derived
    keys only).
    """

    trace_ns: int = 0        # python trace → jaxpr ("construction" part 1)
    lower_ns: int = 0        # jaxpr → StableHLO ("construction" part 2)
    compile_ns: int = 0      # XLA compile ("instantiation")
    launches: int = 0
    total_launch_ns: int = 0
    num_nodes: int = 0       # copy-node count (chunks × hops)
    #: Dispatches of this executable served by the FastPathCache — the
    #: launches whose setup cost was one dict lookup.
    fastpath_hits: int = 0
    #: Cumulative nanoseconds spent dispatching operand staging (host-
    #: side enqueue) across every launch of this executable.
    staging_ns: int = 0
    #: Launch attempts of this executable that raised a link fault and
    #: were retried on a re-planned route (DESIGN §4.6). Windowed like
    #: ``launches``; a healthy window reports 0.
    retries: int = 0

    @property
    def build_ns(self) -> int:
        """One-time cost: trace + lower + compile (the paper's graph
        creation/construction/instantiation, amortized over launches)."""
        return self.trace_ns + self.lower_ns + self.compile_ns

    @property
    def mean_launch_ns(self) -> float:
        """Steady-state cost per launch (0.0 before the first launch)."""
        return self.total_launch_ns / self.launches if self.launches else 0.0

    def reset_window(self) -> None:
        """Zero the *per-window* accumulators (launches,
        ``total_launch_ns``, ``staging_ns``, ``fastpath_hits``,
        ``retries``) so
        long-running sessions can report rates instead of lifetime sums
        — the ``stats(reset=True)`` windowed-counter contract. The
        one-time build timings (trace/lower/compile) are preserved:
        they are identity facts of the executable, not a window."""
        self.launches = 0
        self.total_launch_ns = 0
        self.staging_ns = 0
        self.fastpath_hits = 0
        self.retries = 0


@dataclasses.dataclass
class CompiledPlan:
    """An instantiated transfer graph: XLA executable + lifecycle stats.

    The ``cudaGraphExec_t`` analogue. ``key`` must be digest-derived
    (:class:`~repro.comm.engine.GroupKey` /
    :class:`~repro.comm.session.CollectiveKey`) so the executable can
    never outlive the graph identity it was compiled for; callers must
    preserve the operand shapes/shardings the plan was compiled with —
    and, when the plan was compiled with donation
    (:func:`compile_plan` ``donate_argnums``), must not reuse operand
    arrays after a launch consumed them.
    """

    key: Hashable
    compiled: Any            # jax.stages.Compiled
    lifecycle: PlanLifecycle

    def __call__(self, *args):
        t0 = time.perf_counter_ns()
        out = self.compiled(*args)
        # Block so the timing covers execution, not just dispatch; dispatch
        # cost alone is measured by the lifecycle benchmark via donated runs.
        jax.block_until_ready(out)
        self.lifecycle.launches += 1
        self.lifecycle.total_launch_ns += time.perf_counter_ns() - t0
        return out

    def dispatch(self, *args):
        """Launch without blocking (pure launch-overhead measurement)."""
        t0 = time.perf_counter_ns()
        out = self.compiled(*args)
        self.lifecycle.launches += 1
        self.lifecycle.total_launch_ns += time.perf_counter_ns() - t0
        return out

    def timed_call(self, *args) -> tuple[Any, int, int]:
        """Blocking launch that splits the wall time into ``(out,
        launch_ns, execute_ns)`` for telemetry attribution (§4.4c):
        launch is dispatch-until-control-returns, execute is the
        ``block_until_ready`` tail. Lifecycle accounting is preserved
        identically to ``__call__`` (one launch, total = launch +
        execute), so the two entry points are interchangeable for every
        stats invariant."""
        t0 = time.perf_counter_ns()
        out = self.compiled(*args)
        t1 = time.perf_counter_ns()
        jax.block_until_ready(out)
        t2 = time.perf_counter_ns()
        self.lifecycle.launches += 1
        self.lifecycle.total_launch_ns += t2 - t0
        return out, t1 - t0, t2 - t1


def compile_plan(key: Hashable, fn: Callable, abstract_args: tuple,
                 num_nodes: int = 0, **jit_kwargs) -> CompiledPlan:
    """Run the full trace→lower→compile pipeline with per-stage timing.

    ``jit_kwargs`` pass straight through to ``jax.jit`` — in particular
    ``donate_argnums``, which the engine uses so XLA reuses staging
    buffers launch-to-launch (a donated executable's contract obligates
    the caller never to reuse a consumed operand; the engine's pooled
    staging preserves that by rebuilding operands every launch).
    """
    life = PlanLifecycle(num_nodes=num_nodes)
    jitted = jax.jit(fn, **jit_kwargs)
    t0 = time.perf_counter_ns()
    traced = jitted.trace(*abstract_args)
    t1 = time.perf_counter_ns()
    lowered = traced.lower()
    t2 = time.perf_counter_ns()
    compiled = lowered.compile()
    t3 = time.perf_counter_ns()
    life.trace_ns, life.lower_ns, life.compile_ns = t1 - t0, t2 - t1, t3 - t2
    return CompiledPlan(key, compiled, life)


class TransferPlanCache:
    """Fixed-capacity LRU cache of :class:`CompiledPlan` objects.

    Capacity defaults to ``REPRO_PLAN_CACHE_SIZE`` (paper: tunable via
    environment variables). Eviction counts are exposed for the overhead
    analysis: an eviction forces a re-instantiation on the next use, the
    dominant first-iteration cost. Keys must be digest-derived
    (§2.2: schedules digest apart, so two dispatch orders of one plan can
    never cross-serve executables); the cache itself never inspects
    them.
    """

    def __init__(self, capacity: int | None = None):
        self.capacity = capacity if capacity is not None else _env_int(
            "REPRO_PLAN_CACHE_SIZE", 64)
        if self.capacity <= 0:
            raise ValueError("cache capacity must be positive")
        self._store: OrderedDict[Hashable, CompiledPlan] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._store

    def get(self, key: Hashable) -> CompiledPlan | None:
        """Look up a compiled plan, counting the hit/miss and refreshing
        LRU recency."""
        plan = self._store.get(key)
        if plan is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return plan

    def put(self, key: Hashable, plan: CompiledPlan) -> None:
        """Insert (or refresh) an entry, evicting the LRU tail past
        capacity."""
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = plan
        if len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def get_or_build(self, key: Hashable,
                     builder: Callable[[], CompiledPlan]) -> CompiledPlan:
        """LaunchGraph's lookup-or-create (Algorithm 1 lines 25–28)."""
        plan = self.get(key)
        if plan is None:
            plan = builder()
            self.put(key, plan)
        return plan

    def keys(self) -> list[Hashable]:
        """Current keys, least-recently-used first (eviction order)."""
        return list(self._store)

    def stats(self, reset: bool = False) -> dict[str, int]:
        """Hit/miss/eviction counters plus current size and capacity.

        ``reset=True`` returns the snapshot then zeroes the counters and
        every cached plan's windowed lifecycle accumulators
        (:meth:`PlanLifecycle.reset_window`) — the windowed-stats
        contract for long-running sessions. Entries themselves are
        preserved: resetting a window must never force a rebuild."""
        out = {"hits": self.hits, "misses": self.misses,
               "evictions": self.evictions, "size": len(self._store),
               "capacity": self.capacity}
        if reset:
            self.hits = self.misses = self.evictions = 0
            for plan in self._store.values():
                plan.lifecycle.reset_window()
        return out

    def clear(self) -> None:
        """Drop every entry (counters are kept; they are cumulative —
        use ``stats(reset=True)`` for windowed counters)."""
        self._store.clear()


@dataclasses.dataclass
class FastPathEntry:
    """One memoized resolution of the plan→lower→schedule→digest pipeline.

    Everything steady-state dispatch needs without re-running any setup
    stage: the resolved plans, the SCHEDULED transfer graph (kept so
    ``REPRO_MP_VALIDATE=always`` can re-run ``graph.validate()`` on
    hits), its post-pass digest, the digest-derived plan-cache key, the
    compiled executable, and the concrete schedule name that was chosen.
    The §4.5 invariants were checked when the entry was built; the epoch
    stamp in :class:`FastPathCache` is what keeps that check valid —
    served entries are byte-identical to what the slow path would
    rebuild, or they are invalidated.
    """

    plans: tuple            # tuple[TransferPlan, ...]
    graph: Any              # the scheduled TransferGraph
    digest: str             # post-pass graph digest (cache-key ingredient)
    key: Hashable           # the GroupKey the executable is cached under
    compiled: CompiledPlan
    schedule: str           # concrete scheduler name resolved at build


class FastPathCache:
    """Front cache for steady-state dispatch (DESIGN.md §2.3).

    Maps a *request signature* — ``(mode, (src, dst, nelems, dtype)…,
    window, schedule name, planner knobs, device count)`` — to a
    :class:`FastPathEntry`, each stamped with the
    :attr:`~repro.comm.planner.PathPlanner.epoch` in force when it was
    built. Lookups compare the stamp against the live epoch: a mismatch
    (any planner/topology mutation since) drops the entry and counts an
    ``invalidation``, so a stale plan can never be served — the §4.5
    validity of a served entry is exactly the validity of its epoch.
    LRU-bounded like the plan cache; entries hold strong references to
    their executables, so eviction order follows use order.
    """

    def __init__(self, capacity: int = 256):
        if capacity <= 0:
            raise ValueError("fast-path cache capacity must be positive")
        self.capacity = capacity
        self._store: OrderedDict[Hashable,
                                 tuple[tuple, FastPathEntry]] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, signature: Hashable) -> bool:
        return signature in self._store

    def get(self, signature: Hashable, epoch: tuple) -> FastPathEntry | None:
        """Return the entry for ``signature`` iff its epoch stamp matches
        the live ``epoch``; a stale stamp is dropped and counted as an
        invalidation (plus a miss — the caller re-plans)."""
        rec = self._store.get(signature)
        if rec is None:
            self.misses += 1
            return None
        stamped, entry = rec
        if stamped != epoch:
            del self._store[signature]
            self.invalidations += 1
            self.misses += 1
            return None
        self._store.move_to_end(signature)
        self.hits += 1
        return entry

    def put(self, signature: Hashable, epoch: tuple,
            entry: FastPathEntry) -> None:
        """Memoize a freshly-built resolution under its epoch stamp,
        evicting the LRU tail past capacity."""
        if signature in self._store:
            self._store.move_to_end(signature)
        self._store[signature] = (epoch, entry)
        if len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def stats(self, reset: bool = False) -> dict[str, int]:
        """Hit/miss/invalidation/eviction counters plus size and
        capacity — surfaced as ``session.stats()["fastpath"]``.
        ``reset=True`` snapshots then zeroes the counters (windowed
        semantics; entries and their epoch stamps are preserved, so the
        §4.5 staleness check is unaffected)."""
        out = {"hits": self.hits, "misses": self.misses,
               "invalidations": self.invalidations,
               "evictions": self.evictions, "size": len(self._store),
               "capacity": self.capacity}
        if reset:
            self.hits = self.misses = 0
            self.invalidations = self.evictions = 0
        return out

    def clear(self) -> None:
        """Drop every entry (counters are kept; they are cumulative —
        use ``stats(reset=True)`` for windowed counters)."""
        self._store.clear()
