"""PathPlanner: route enumeration + per-message path configuration.

Implements the paper's Multi-Path Communication Handler + ``GetPathConfig``
(Algorithm 1, lines 4–11) and the offline topology tuner (§4.4):

* enumerate the direct route and all 2-hop staged routes (via idle peer
  devices, and optionally via the host),
* delegate route *selection* and share assignment to a pluggable
  :class:`~repro.comm.policy.PathPolicy` (greedy bandwidth-proportional by
  default — the paper's behavior),
* split each share into pipeline chunks (vertical split — chunk count is the
  tunable the paper fixes via offline tuning; default target chunk 1 MB,
  capped at ``max_chunks``).

Configuration comes from a :class:`~repro.comm.config.CommConfig`
(constructor keyword arguments override individual fields); the legacy
``REPRO_MP_*`` environment variables are honored through
``CommConfig.from_env()``, which is the default when no config is given.

Measured feedback (DESIGN §4.4c): every bandwidth the planner reads —
route enumeration via :meth:`Topology.link`, policy shares via
``Route.bottleneck_gbps``, and the §4.4 arbitration of candidate path
counts / exclusive-vs-shared groups via ``estimate_transfer_time_s`` /
``estimate_group_time_s`` — flows through the topology's calibrated link
overlay when a :class:`~repro.comm.calibration.CalibrationProfile` is
attached, so the contention derate prices fitted terms, not nominal
constants. Attaching a profile bumps the topology epoch, which bumps the
planner :attr:`PathPlanner.epoch`, so no pre-calibration plan survives.

Hierarchy (DESIGN §3.1): on multi-island topologies the planner preserves
the island-routing invariants — intra-island plans never touch an
inter-node link, and every cross-island route stages through exactly one
inter-node hop (fan-out / inter-hop / fan-in), with §4.5 link-disjointness
claimed across both tiers.
"""

from __future__ import annotations

from typing import Sequence

from repro.comm.config import CommConfig
from repro.comm.plan import (PathAssignment, TransferGroup, TransferPlan,
                             TransferRequest)
from repro.comm.policy import (GreedyBandwidthPolicy, PathPolicy,
                               contention_scaled, make_policy)
from repro.core.topology import HOST, Route, Topology
from repro.core.topology import _UID_SOURCE

_GREEDY = GreedyBandwidthPolicy()

#: Planner attributes whose reassignment changes what :meth:`PathPlanner.plan`
#: would return for an identical request — each bump invalidates every
#: fast-path entry stamped with an older epoch.
_EPOCH_ATTRS = frozenset({
    "topology", "config", "max_paths", "chunk_bytes", "max_chunks",
    "include_host", "multipath_threshold", "policy", "quarantined"})


class PathPlanner:
    """Selects routes and builds :class:`TransferPlan` objects.

    Mutating any planning input after construction (``max_paths``,
    ``policy``, ``topology``, …) bumps the planner's :attr:`epoch`, the
    plan-validity token the dispatch fast path
    (:class:`repro.comm.cache.FastPathCache`) stamps its entries with —
    so a policy change always forces a re-plan instead of serving a stale
    executable. Every plan preserves the §4.5 invariants (disjoint byte
    coverage, link-disjoint routes), island-aware on hierarchical
    topologies: intra-island traffic never crosses an inter-node link and
    cross-island routes carry exactly one inter-node hop each.
    """

    def __init__(self, topology: Topology, *,
                 max_paths: int | None = None,
                 chunk_bytes: int | None = None,
                 max_chunks: int | None = None,
                 include_host: bool | None = None,
                 multipath_threshold: int | None = None,
                 policy: PathPolicy | None = None,
                 config: CommConfig | None = None):
        self._uid = next(_UID_SOURCE)
        self._epoch = 0
        if config is None:
            config = CommConfig.from_env()
        self.topology = topology
        self.config = config
        self.max_paths = (config.max_paths if max_paths is None
                          else max_paths)
        self.chunk_bytes = (config.chunk_bytes if chunk_bytes is None
                            else chunk_bytes)
        self.max_chunks = (config.max_chunks if max_chunks is None
                           else max_chunks)
        self.include_host = (config.include_host if include_host is None
                             else include_host)
        # Paper §5.3: multi-pathing engages at 2 MB; below that the single
        # direct path wins (launch overhead dominates).
        self.multipath_threshold = (
            config.multipath_threshold if multipath_threshold is None
            else multipath_threshold)
        self.policy = policy if policy is not None else make_policy(
            config.policy)
        #: Directional links excluded from route admission (DESIGN §4.6):
        #: the health monitor quarantines suspect links here; reassignment
        #: bumps :attr:`epoch`, so every fast-path entry routed over a
        #: newly-quarantined link is invalidated on the next lookup.
        self.quarantined: frozenset[tuple[int, int]] = frozenset()
        self._track_mutations = True

    def __setattr__(self, name: str, value) -> None:
        object.__setattr__(self, name, value)
        if name in _EPOCH_ATTRS and getattr(self, "_track_mutations", False):
            self._epoch += 1

    @property
    def epoch(self) -> tuple[int, ...]:
        """Plan-validity token: ``(planner uid, planner mutations,
        topology uid, topology mutations)``.

        Changes whenever a planning input is reassigned on this planner or
        the topology's link set mutates
        (:meth:`repro.core.topology.Topology.bump_epoch`) — the dispatch
        fast path compares it on every lookup, so a stale plan can never
        be served. Mutating the *internals* of an attached policy object
        is not observable; swap the ``policy`` attribute (or call
        ``topology.bump_epoch()``) to invalidate explicitly.
        """
        return (self._uid, self._epoch, *self.topology.epoch)

    # -- quarantine (link health, DESIGN §4.6) ------------------------------
    def quarantine(self, *links: tuple[int, int]) -> None:
        """Exclude directional links from route admission.

        Quarantine is planner-level suspicion, distinct from a topology
        ``fail_link`` (the link still physically exists — health probes
        may traverse it via ``admit_quarantined=True``). Reassigning the
        set bumps :attr:`epoch`, invalidating every cached plan routed
        over a newly-quarantined link; a no-op call (links already
        quarantined) preserves the epoch.
        """
        add = frozenset(tuple(link) for link in links)
        if add - self.quarantined:
            self.quarantined = self.quarantined | add

    def readmit(self, *links: tuple[int, int]) -> None:
        """Re-admit quarantined links into route admission.

        The inverse of :meth:`quarantine` — called by the health
        monitor after the probe contract is met (consecutive healthy
        probes). Bumps :attr:`epoch` when the set actually shrinks, so
        degraded-mode plans are invalidated and steady-state traffic
        returns to the full route set (and its pre-fault plan digest).
        """
        drop = frozenset(tuple(link) for link in links)
        if drop & self.quarantined:
            self.quarantined = self.quarantined - drop

    # -- route enumeration --------------------------------------------------
    def enumerate_routes(self, src: int, dst: int,
                         include_host: bool | None = None, *,
                         admit_quarantined: bool = False) -> list[Route]:
        """All 1- and 2-hop routes src→dst, best (direct, then by bw) first.

        Staged routes never reuse a directional link of the direct route, so
        per-link exclusivity (§4.5 contention avoidance) holds by construction.

        Island-aware (DESIGN §3.1): when the topology reports more than
        one island, intra-island requests only ever stage through
        same-island devices (and optionally the host) — no intra plan
        touches an inter-node link — while cross-island requests delegate
        to the staged enumeration (fan-out to an egress device, exactly
        one inter-node hop, fan-in), see :meth:`cross_island_routes`.

        Quarantined links (DESIGN §4.6) are treated as absent — no
        admitted route crosses one, the degraded-mode exclusion
        invariant — unless ``admit_quarantined=True`` (health probes
        must be able to traverse the very link under suspicion).
        """
        if src == dst:
            raise ValueError("src == dst")
        topo = self.topology
        include_host = (self.include_host if include_host is None
                        else include_host)
        quarantined = (frozenset() if admit_quarantined
                       else self.quarantined)

        def usable(a: int, b: int):
            return None if (a, b) in quarantined else topo.link(a, b)

        hierarchical = topo.num_islands > 1
        if hierarchical and topo.node_of(src) != topo.node_of(dst):
            return self.cross_island_routes(
                src, dst, admit_quarantined=admit_quarantined)
        island = topo.node_of(src) if hierarchical else None

        def in_island(dev: int) -> bool:
            return (not hierarchical or dev == HOST
                    or topo.node_of(dev) == island)

        routes: list[Route] = []
        direct = usable(src, dst)
        if direct is not None:
            routes.append(Route(src, dst, None, (direct,),
                                direct.bandwidth_gbps))
        vias = [d for d in topo.devices()
                if d not in (src, dst) and in_island(d)]
        if include_host:
            vias.append(HOST)
        for via in vias:
            h1, h2 = usable(src, via), usable(via, dst)
            if h1 is None or h2 is None:
                continue
            routes.append(Route(src, dst, via, (h1, h2),
                                min(h1.bandwidth_gbps, h2.bandwidth_gbps)))
        if len(routes) < self.max_paths:
            # Torus case: adjacent chips share no common neighbour (girth
            # 4), so alternative routes are 3-hop detours through a
            # perpendicular axis (src→v1→v2→dst) — the TPU analogue of the
            # paper's staged-GPU path (DESIGN.md §2). Only link-disjoint
            # detours (vs routes found so far) are admitted.
            used = {l for r in routes for l in r.directional_links()}
            for v1 in topo.neighbors(src):
                if v1 in (dst, src) or not in_island(v1):
                    continue
                if v1 == HOST and not include_host:
                    # neighbors() includes the PCIe host node; a detour
                    # staged through it must honor the caller's host
                    # constraint just like the 2-hop host route does.
                    continue
                for v2 in topo.neighbors(dst):
                    if v2 in (src, dst, v1) or not in_island(v2):
                        continue
                    if v2 == HOST and not include_host:
                        continue
                    h1, h2, h3 = (usable(src, v1), usable(v1, v2),
                                  usable(v2, dst))
                    if h1 is None or h2 is None or h3 is None:
                        continue
                    links = {(src, v1), (v1, v2), (v2, dst)}
                    if links & used:
                        continue
                    used |= links
                    routes.append(Route(
                        src, dst, v1, (h1, h2, h3),
                        min(h.bandwidth_gbps for h in (h1, h2, h3))))
        # direct first, then staged by hop count and bandwidth, host last
        # (paper: the host path is the marginal contributor).
        routes.sort(key=lambda r: (r.via is not None,
                                   r.via == HOST,
                                   r.num_hops,
                                   -r.bottleneck_gbps))
        return routes

    def cross_island_routes(self, src: int, dst: int, *,
                            admit_quarantined: bool = False) -> list[Route]:
        """Staged routes across a node boundary, best-first (§4.4/§3.1).

        One candidate per inter-node link whose endpoints sit in the
        source/destination islands: an optional intra-island hop to the
        egress device, the inter-node hop, and an optional intra-island
        hop from the ingress device — so every route crosses **exactly
        one** inter-node link (the hierarchical-routing invariant the
        property suite validates). Candidates are filtered best-first to
        a link-disjoint set, preserving the §4.5 exclusivity contract
        policies assume of their route lists. Quarantined links are
        excluded like failed ones (DESIGN §4.6) unless
        ``admit_quarantined=True``.
        """
        topo = self.topology
        src_island, dst_island = topo.node_of(src), topo.node_of(dst)
        if src_island == dst_island:
            raise ValueError(f"{src}->{dst} is intra-island "
                             f"(island {src_island})")
        quarantined = (frozenset() if admit_quarantined
                       else self.quarantined)

        def usable(a: int, b: int):
            return None if (a, b) in quarantined else topo.link(a, b)

        cands: list[Route] = []
        for (a, b) in topo.links:
            if a == HOST or b == HOST:
                continue
            if topo.node_of(a) != src_island or topo.node_of(b) != dst_island:
                continue
            inter = usable(a, b)
            if inter is None:
                continue
            hops = []
            if a != src:
                fan_out = usable(src, a)
                if fan_out is None:
                    continue
                hops.append(fan_out)
            hops.append(inter)
            if b != dst:
                fan_in = usable(b, dst)
                if fan_in is None:
                    continue
                hops.append(fan_in)
            via = a if a != src else (b if b != dst else None)
            cands.append(Route(src, dst, via, tuple(hops),
                               min(h.bandwidth_gbps for h in hops)))
        cands.sort(key=lambda r: (-r.bottleneck_gbps, r.num_hops))
        routes: list[Route] = []
        used: set[tuple[int, int]] = set()
        for route in cands:
            links = set(route.directional_links())
            if links & used:
                continue
            used |= links
            routes.append(route)
        return routes

    # -- plan construction ---------------------------------------------------
    def compose(self, src: int, dst: int, nbytes: int,
                shares: Sequence[tuple[Route, int]], *,
                num_chunks: int | None = None,
                granularity: int = 1) -> TransferPlan:
        """Turn policy-assigned (route, share) pairs into a checked plan.

        Zero shares are dropped; offsets are assigned cumulatively so the
        byte ranges are disjoint and cover ``[0, nbytes)`` (§4.5); chunking
        follows the planner's ``chunk_bytes``/``max_chunks`` unless an
        explicit ``num_chunks`` is forced.
        """
        paths: list[PathAssignment] = []
        offset = 0
        for route, share in shares:
            if share <= 0:
                continue
            if num_chunks is not None:
                chunks = num_chunks
            else:
                chunks = max(1, min(self.max_chunks,
                                    -(-share // self.chunk_bytes)))
            chunks = min(chunks, max(1, share // granularity))
            paths.append(PathAssignment(route, offset, share, chunks,
                                        granularity))
            offset += share
        return TransferPlan(src, dst, nbytes, tuple(paths),
                            self.topology.name)

    def plan(self, src: int, dst: int, nbytes: int, *,
             max_paths: int | None = None,
             include_host: bool | None = None,
             num_chunks: int | None = None,
             granularity: int = 1,
             policy: PathPolicy | None = None,
             admit_quarantined: bool = False) -> TransferPlan:
        """Build the 2-D transfer plan (Algorithm 1 lines 4–11).

        ``policy`` overrides the planner's strategy for this call only
        (used by the tuner to score greedy candidates without recursing).
        ``admit_quarantined=True`` lifts the §4.6 quarantine exclusion
        for this call — the health-probe escape hatch; every other plan
        preserves the invariant that no route crosses a quarantined
        link.
        """
        if nbytes <= 0:
            raise ValueError("nbytes must be positive")
        if nbytes % granularity:
            raise ValueError(f"nbytes {nbytes} not a multiple of "
                             f"granularity {granularity}")
        if max_paths is not None and max_paths < 1:
            raise ValueError(f"max_paths must be >= 1, got {max_paths}")
        if max_paths is None:
            max_paths = self.max_paths
        include_host = (self.include_host if include_host is None
                        else include_host)
        routes = self.enumerate_routes(src, dst, include_host=include_host,
                                       admit_quarantined=admit_quarantined)
        if not routes:
            raise ValueError(
                f"no route {src}->{dst} in topology {self.topology.name}")
        if nbytes < self.multipath_threshold:
            routes = routes[:1]
        policy = policy if policy is not None else self.policy
        return policy.build(self, src, dst, nbytes, routes=routes,
                            max_paths=max_paths, num_chunks=num_chunks,
                            granularity=granularity,
                            include_host=include_host)

    # -- group planning (concurrent messages) ---------------------------------
    def plan_group(self, requests: Sequence[TransferRequest | tuple], *,
                   max_paths: int | None = None,
                   include_host: bool | None = None,
                   num_chunks: int | None = None,
                   exclusive: bool = False) -> TransferGroup:
        """Jointly plan a set of concurrent messages (a transfer group).

        ``requests`` are :class:`TransferRequest` objects or plain
        ``(src, dst, nbytes)`` tuples. Unlike N independent ``plan()``
        calls, the group planner prices cross-message link sharing. Two
        candidate groups are built and the §4.4 analytic model picks:

        * **exclusive** — distinct flows claim routes round-robin
          (best-first), a route only while all of its directional links
          are unclaimed, so flows end up link-disjoint whenever the
          topology has the capacity (the group-level §4.5 invariant,
          ``TransferGroup.exclusive``). Optimal for exchange patterns
          (bidirectional, halo) where full disjointness exists.
        * **shared** — every flow keeps its full route set with bandwidths
          derated by the traffic already planned
          (:func:`~repro.comm.policy.contention_scaled`), so shares
          reflect the capacity each path will actually see. Optimal when
          flows converge (fan-in) and partitioning links would starve
          someone.

        In both candidates, each message's path count is chosen by scoring
        plans under :func:`~repro.core.pipelining.estimate_transfer_time_s`
        with every previously-planned group member as ``concurrent_plans``
        — never in isolation. ``exclusive=True`` forces the exclusive
        candidate and raises if some flow has no link-disjoint route.

        Messages of the same flow share that flow's routes — they ride one
        fused program and serialize per link, which the model prices as
        contention.
        """
        reqs = [r if isinstance(r, TransferRequest) else TransferRequest(*r)
                for r in requests]
        if not reqs:
            return TransferGroup((), self.topology.name)
        for r in reqs:
            if r.src == r.dst:
                raise ValueError(f"src == dst in group request {r}")
            if r.nbytes <= 0:
                raise ValueError(f"nbytes must be positive in {r}")
            if r.nbytes % r.granularity:
                raise ValueError(f"nbytes {r.nbytes} not a multiple of "
                                 f"granularity {r.granularity} in {r}")
        max_paths = self.max_paths if max_paths is None else max_paths
        if max_paths < 1:
            raise ValueError(f"max_paths must be >= 1, got {max_paths}")
        include_host = (self.include_host if include_host is None
                        else include_host)

        # Phase 1: round-robin route claiming per distinct flow.
        flows = list(dict.fromkeys(r.flow for r in reqs))
        largest = {f: max(r.nbytes for r in reqs if r.flow == f)
                   for f in flows}
        candidates = {f: self.enumerate_routes(*f, include_host=include_host)
                      for f in flows}
        for f in flows:
            if not candidates[f]:
                raise ValueError(f"no route {f[0]}->{f[1]} in topology "
                                 f"{self.topology.name}")
        want = {f: (1 if largest[f] < self.multipath_threshold else max_paths)
                for f in flows}
        claimed: dict[tuple[int, int], list[Route]] = {f: [] for f in flows}
        used_links: set[tuple[int, int]] = set()
        progress = True
        while progress:
            progress = False
            for f in flows:
                if len(claimed[f]) >= want[f]:
                    continue
                for route in candidates[f]:
                    links = set(route.directional_links())
                    if links & used_links:
                        continue
                    claimed[f].append(route)
                    used_links |= links
                    progress = True
                    break
        starved = [f for f in flows if not claimed[f]]
        if starved and exclusive:
            raise ValueError(
                f"cannot plan link-exclusive group: flows {starved} have no "
                f"route disjoint from the rest of the group on topology "
                f"{self.topology.name}; drop exclusive=True to share links "
                f"with contention-aware splitting")
        link_flow_count = {l: 1 for l in used_links}

        # Phase 2: per-message configuration, scored under the §4.4 model
        # with the rest of the group as concurrent traffic.
        from repro.core.pipelining import (estimate_group_time_s,
                                           estimate_transfer_time_s)

        policy = (self.policy if getattr(self.policy, "honors_routes", False)
                  else _GREEDY)

        def build_message(r: TransferRequest, routes: Sequence[Route],
                          prior: list[TransferPlan]) -> TransferPlan:
            if r.nbytes < self.multipath_threshold:
                routes = routes[:1]
            best, best_t = None, float("inf")
            for k in range(1, min(max_paths, len(routes)) + 1):
                cand = policy.build(
                    self, r.src, r.dst, r.nbytes, routes=routes[:k],
                    max_paths=k, num_chunks=num_chunks,
                    granularity=r.granularity, include_host=include_host)
                t = estimate_transfer_time_s(cand, self.topology,
                                             concurrent_plans=prior)
                if t < best_t:
                    best, best_t = cand, t
            assert best is not None
            return best

        def link_counts(plans: Sequence[TransferPlan]
                        ) -> dict[tuple[int, int], int]:
            counts: dict[tuple[int, int], int] = {}
            for p in plans:
                for link in p.directional_links():
                    counts[link] = counts.get(link, 0) + 1
            return counts

        # Candidate A: link-exclusive flows (starved flows fall back to
        # contention-derated sharing so the candidate is always complete).
        plans_ex: list[TransferPlan] = []
        for r in reqs:
            routes = claimed[r.flow] or contention_scaled(
                candidates[r.flow], link_flow_count)
            plans_ex.append(build_message(r, routes, plans_ex))
        group_ex = TransferGroup(tuple(plans_ex), self.topology.name)
        if exclusive:
            return group_ex

        # Candidate B: shared routes with contention-derated shares.
        plans_sh: list[TransferPlan] = []
        for r in reqs:
            routes = contention_scaled(candidates[r.flow],
                                       link_counts(plans_sh))
            plans_sh.append(build_message(r, routes, plans_sh))
        group_sh = TransferGroup(tuple(plans_sh), self.topology.name)

        # The model arbitrates; ties prefer the exclusive candidate (a
        # contention-free wire is the paper's §4.5 default).
        t_ex = estimate_group_time_s(group_ex, self.topology)
        t_sh = estimate_group_time_s(group_sh, self.topology)
        return group_ex if t_ex <= t_sh else group_sh

    # -- offline tuner (paper §4.4) -------------------------------------------
    def tune(self, src: int, dst: int, nbytes: int, *,
             path_counts: tuple[int, ...] = (1, 2, 3, 4),
             chunk_counts: tuple[int, ...] = (1, 2, 4, 8, 16),
             include_host_options: tuple[bool, ...] = (False, True),
             use_compiled_plans: bool = True,
             granularity: int = 1) -> TransferPlan:
        """Exhaustive offline search for the best (paths × chunks × host)
        configuration under the analytic pipeline model.

        The paper tunes separately for CUDA-Graph and non-graph modes because
        launch overheads differ; ``use_compiled_plans`` toggles which launch
        overhead model is applied. Candidates are greedy plans regardless of
        the planner's own policy (the tuner searches the paper handler's
        configuration space).
        """
        from repro.core.pipelining import estimate_transfer_time_s

        best_plan, best_t = None, float("inf")
        for host in include_host_options:
            if host and not any(l.src == HOST or l.dst == HOST
                                for l in self.topology.links.values()):
                continue
            for npaths in path_counts:
                for nchunks in chunk_counts:
                    plan = self.plan(src, dst, nbytes, max_paths=npaths,
                                     include_host=host, num_chunks=nchunks,
                                     granularity=granularity,
                                     policy=_GREEDY)
                    t = estimate_transfer_time_s(
                        plan, self.topology,
                        compiled_plan=use_compiled_plans)
                    if t < best_t:
                        best_plan, best_t = plan, t
        assert best_plan is not None
        return best_plan
