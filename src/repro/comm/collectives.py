"""Multipath-striped collectives (BEYOND-PAPER — the paper's §6 future work).

The paper stripes *point-to-point* messages across idle links. The same
insight applies to collectives on a torus/ring: a unidirectional ring
all-gather uses only one direction of each bidirectional ICI link, leaving
half the injection bandwidth idle. These implementations stripe the payload
across **both ring directions** (2 paths), which halves the bytes crossing
any single directional link — the collective-roofline term drops ~2×.

All functions are written for use inside ``shard_map`` over a named mesh
axis, and are validated against ``jax.lax`` references in
``tests/test_collectives.py``. For axis-bound access (and driver-level
compiled launches that share a session's plan cache) see
:class:`repro.comm.session.CommSession`.

Hierarchy (DESIGN §3.1): on topologies with more than one island the flat
ring's bottleneck is the inter-node tier. :func:`two_level_all_reduce`
decomposes the all-reduce into an intra-island multipath reduce-scatter,
an inter-island ring over the shards, and an intra-island multipath
all-gather; :func:`modeled_all_reduce_s` prices both layouts under the
§4.4 tier model and :func:`select_all_reduce_strategy` arbitrates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size
from repro.core.topology import HOST, Topology


def _ring_perms(n: int):
    cw = [(i, (i + 1) % n) for i in range(n)]
    ccw = [(i, (i - 1) % n) for i in range(n)]
    return cw, ccw


def bidir_ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """All-gather along ``axis_name`` using both ring directions.

    ``x`` is the local shard ``(s, ...)``; returns ``(N*s, ...)`` in device
    order — equivalent to ``lax.all_gather(x, axis_name, tiled=True)``
    (validated against it in ``tests/test_collectives.py``).
    Half the features travel clockwise, half counter-clockwise, so each of
    the N-1 steps uses both directional links of the ring simultaneously.
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    i = lax.axis_index(axis_name)
    cw, ccw = _ring_perms(n)

    f = x.shape[-1]
    f0 = f // 2
    if f0 == 0:  # nothing to split — degrade to single direction
        f0 = f
    h0, h1 = x[..., :f0], x[..., f0:]

    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_slice_in_dim(out, x[None], i, axis=0)
    cur0, cur1 = h0, h1
    for step in range(1, n):
        cur0 = lax.ppermute(cur0, axis_name, cw)
        src0 = jnp.mod(i - step, n)
        out = lax.dynamic_update_slice(
            out, cur0[None], (src0,) + (0,) * x.ndim)
        if h1.shape[-1]:
            cur1 = lax.ppermute(cur1, axis_name, ccw)
            src1 = jnp.mod(i + step, n)
            out = lax.dynamic_update_slice(
                out, cur1[None], (src1,) + (0,) * (x.ndim - 1) + (f0,))
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def bidir_ring_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Reduce-scatter (sum) along ``axis_name`` using both ring directions.

    ``x`` is the full local operand ``(N*s, ...)``; returns the reduced shard
    ``(s, ...)`` owned by this device — equivalent to
    ``lax.psum_scatter(x, axis_name, tiled=True)`` (validated against it
    in ``tests/test_collectives.py``).
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    i = lax.axis_index(axis_name)
    cw, ccw = _ring_perms(n)
    s = x.shape[0] // n
    blocks = x.reshape((n, s) + x.shape[1:])

    f = x.shape[-1] if x.ndim > 1 else 1
    f0 = f // 2 if x.ndim > 1 else 0

    def blk(idx, lo, hi):
        b = lax.dynamic_index_in_dim(blocks, jnp.mod(idx, n), axis=0,
                                     keepdims=False)
        if x.ndim > 1 and hi is not None:
            b = b[..., lo:hi]
        return b

    if f0 == 0:
        # Single-direction fallback (narrow features).
        acc = blk(i - 1, 0, None)
        for t in range(1, n):
            acc = lax.ppermute(acc, axis_name, cw)
            acc = acc + blk(i - t - 1, 0, None)
        return acc

    acc0 = lax.dynamic_index_in_dim(
        blocks[..., :f0], jnp.mod(i - 1, n), axis=0, keepdims=False)
    acc1 = lax.dynamic_index_in_dim(
        blocks[..., f0:], jnp.mod(i + 1, n), axis=0, keepdims=False)
    for t in range(1, n):
        acc0 = lax.ppermute(acc0, axis_name, cw)
        acc0 = acc0 + lax.dynamic_index_in_dim(
            blocks[..., :f0], jnp.mod(i - t - 1, n), axis=0, keepdims=False)
        acc1 = lax.ppermute(acc1, axis_name, ccw)
        acc1 = acc1 + lax.dynamic_index_in_dim(
            blocks[..., f0:], jnp.mod(i + t + 1, n), axis=0, keepdims=False)
    return jnp.concatenate([acc0, acc1], axis=-1)


def multipath_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce = bidirectional reduce-scatter + bidirectional all-gather.

    Equivalent to ``lax.psum(x, axis_name)`` (validated against it in
    ``tests/test_collectives.py``). Requires ``x.shape[0]`` to be
    divisible by the axis size (pad upstream otherwise).
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    shard = bidir_ring_reduce_scatter(x, axis_name)
    return bidir_ring_all_gather(shard, axis_name)


def multipath_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """All-to-all along ``axis_name`` with opposite-direction step pairing.

    ``x`` has leading dim ``N`` (one block per destination); returns the same
    shape with block ``j`` received from device ``j`` — equivalent to
    ``lax.all_to_all(x, axis_name, 0, 0, tiled=False)`` on a block-indexed
    operand (validated against it in ``tests/test_collectives.py``).
    Shift ``+s`` and ``+(N-s)`` travel opposite directions on the
    physical ring, so pairing them stripes each step across both directions
    (the MoE expert-parallel application of the paper's idea).
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    i = lax.axis_index(axis_name)
    out = jnp.zeros_like(x)
    # keep own block
    own = lax.dynamic_index_in_dim(x, i, axis=0, keepdims=True)
    out = lax.dynamic_update_slice_in_dim(out, own, i, axis=0)
    for s in range(1, n):
        # send block destined to (i+s) — a single full permutation; shifts s
        # and n-s are emitted adjacently so the scheduler can overlap the two
        # opposite ring directions.
        perm = [(j, (j + s) % n) for j in range(n)]
        block = lax.dynamic_index_in_dim(x, jnp.mod(i + s, n), axis=0,
                                         keepdims=True)
        recv = lax.ppermute(block, axis_name, perm)
        out = lax.dynamic_update_slice_in_dim(
            out, recv, jnp.mod(i - s, n), axis=0)
    return out


def psum_via_multipath(x: jax.Array, axis_name: str) -> jax.Array:
    """Drop-in ``psum`` for arbitrary-shape operands.

    Flattens, pads to a multiple of ``2 * axis_size``, multipath-all-reduces,
    and restores the shape (validated against ``lax.psum`` in
    ``tests/test_collectives.py``). Used by the manual-collectives
    training mode.

    The operand is reshaped to two feature columns — NOT a column vector:
    the ring algorithms split the last dim across the two ring directions,
    and a single-column operand (f0 = 0) would silently fall back to the
    one-directional ring, forfeiting the multipath striping.
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % (2 * n)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    red = multipath_all_reduce(flat.reshape(-1, 2), axis_name)
    red = red.reshape(-1)[:x.size]
    return red.reshape(x.shape)


def two_level_all_reduce(x: jax.Array, inter_axis: str,
                         intra_axis: str) -> jax.Array:
    """Hierarchical all-reduce: intra-island multipath reduce-scatter,
    inter-island ring all-reduce over the shards, intra-island multipath
    all-gather (DESIGN §3.1).

    ``intra_axis`` names the fast (intra-node) mesh axis, ``inter_axis``
    the slow (inter-node) one. Equivalent to
    ``lax.psum(x, (inter_axis, intra_axis))`` — validated against that
    reference in ``tests/test_collectives.py``. Only ``nbytes / M``
    (M = island size) crosses the slow tier, which is why the §4.4 model
    prices it below the flat ring whenever the inter tier is the
    bottleneck. Requires ``x.shape[0]`` divisible by the ``intra_axis``
    size (pad upstream otherwise).
    """
    shard = bidir_ring_reduce_scatter(x, intra_axis)
    shard = psum_via_multipath(shard, inter_axis)
    return bidir_ring_all_gather(shard, intra_axis)


# -- §4.4 tier model: flat ring vs two-level decomposition -------------------

def tier_bandwidths_gbps(topo: Topology) -> tuple[float, float | None]:
    """Bottleneck bandwidth per tier: ``(intra_gbps, inter_gbps)``.

    Minimum directional-link bandwidth inside islands and across them
    (``None`` when the topology has no inter-island links). Host links
    are excluded — host staging is not a collective tier. Bandwidths are
    read through :meth:`~repro.core.topology.Topology.link`, so a live
    calibration profile's fitted terms (keyed by the topology digest)
    flow into the collective model automatically.
    """
    intra: list[float] = []
    inter: list[float] = []
    for key in topo.links:
        if HOST in key:
            continue
        link = topo.link(*key)
        (inter if topo.is_inter_island(*key) else intra).append(
            link.bandwidth_gbps)
    if not intra:
        raise ValueError(f"topology {topo.name} has no device links")
    return min(intra), (min(inter) if inter else None)


def modeled_all_reduce_s(topo: Topology, nbytes: int,
                         strategy: str = "flat") -> float:
    """Modeled seconds for an ``nbytes`` all-reduce over all devices.

    ``strategy="flat"`` prices the bidirectional ring over every device:
    ``2(N-1)`` steps of ``nbytes / 2N`` each, bottlenecked by the slowest
    tier the ring must cross (the inter-node tier on hierarchical
    topologies, plus :data:`~repro.core.pipelining.INTER_NODE_LATENCY_NS`
    per step). ``strategy="two_level"`` prices the
    :func:`two_level_all_reduce` decomposition — intra steps at the intra
    tier, only the ``nbytes / M`` shard crossing islands — and is
    ``inf`` when islands are disconnected. Both use the same per-tier
    bandwidths (:func:`tier_bandwidths_gbps`), so the comparison the
    selection contract rests on is apples-to-apples; validated in
    ``tests/test_collectives.py`` and gated in CI's bench-smoke.
    """
    from repro.core.pipelining import INTER_NODE_LATENCY_NS

    if nbytes <= 0:
        raise ValueError("nbytes must be positive")
    n = topo.num_devices
    if n <= 1:
        return 0.0
    bw_intra, bw_inter = tier_bandwidths_gbps(topo)
    islands = topo.islands()
    num_islands = len(islands)
    lat = INTER_NODE_LATENCY_NS / 1e9 if num_islands > 1 else 0.0
    if strategy == "flat":
        bottleneck = bw_inter if (num_islands > 1 and bw_inter) else bw_intra
        steps = 2 * (n - 1)
        return steps * ((nbytes / (2 * n)) / (bottleneck * 1e9) + lat)
    if strategy != "two_level":
        raise ValueError(f"unknown all-reduce strategy {strategy!r}")
    if num_islands == 1:
        return modeled_all_reduce_s(topo, nbytes, "flat")
    if bw_inter is None:
        return float("inf")
    m = max(len(devs) for devs in islands)
    t_intra = 2 * (m - 1) * (nbytes / (2 * m)) / (bw_intra * 1e9)
    shard = nbytes / m
    t_inter = 2 * (num_islands - 1) * (
        (shard / (2 * num_islands)) / (bw_inter * 1e9) + lat)
    return t_intra + t_inter


def select_all_reduce_strategy(topo: Topology, nbytes: int,
                               strategy: str = "auto"
                               ) -> tuple[str, dict[str, float]]:
    """Pick the all-reduce layout for ``topo``: ``(chosen, times_s)``.

    ``strategy="auto"`` (the selection contract): flat on single-island
    topologies; on hierarchical ones the two-level decomposition wins iff
    it models strictly faster under :func:`modeled_all_reduce_s`.
    ``"flat"`` / ``"two_level"`` force the layout but still return both
    modeled times, so ``session.describe()`` and the benchmarks can
    report the flat-vs-hierarchical delta either way.

    Degradation invariant (DESIGN §4.6): a forced ``"two_level"``
    falls back to ``"flat"`` when the two-level decomposition models
    infinite time — every egress link of some island has failed, so
    the inter-island exchange phase cannot run. The fault model feeds
    this automatically: failed links vanish from ``topo.links`` and
    degraded links price at their scaled bandwidth, so the modeled
    times here already reflect the surviving fabric.
    """
    times = {"flat": modeled_all_reduce_s(topo, nbytes, "flat"),
             "two_level": modeled_all_reduce_s(topo, nbytes, "two_level")}
    if strategy == "two_level" and times["two_level"] == float("inf"):
        # Egress fabric gone — serve the reduction on the flat ring
        # rather than raising mid-collective.
        return "flat", times
    if strategy in ("flat", "two_level"):
        return strategy, times
    if strategy != "auto":
        raise ValueError(f"unknown all-reduce strategy {strategy!r}")
    if topo.num_islands > 1 and times["two_level"] < times["flat"]:
        return "two_level", times
    return "flat", times
