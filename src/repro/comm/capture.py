"""Whole-iteration step capture: one heterogeneous graph per iteration.

The paper's CUDA-graph thesis is "capture once, launch many"; the rest of
:mod:`repro.comm` applies it to *communication* only — each transfer is
one fused launch, but an iteration is still a chain of separate compute
launches with transfer dispatches between them. This module closes the
gap: a :class:`StepCapture` records a full step (kernel invocations +
multipath exchanges) against declared buffers, :func:`lower_step` lowers
the recording to ONE heterogeneous
:class:`~repro.comm.graph.TransferGraph` — :class:`~repro.comm.graph
.CopyNode` per chunk per hop plus :class:`~repro.comm.graph.ComputeNode`
per kernel, coupled by ``"buffer"`` def-use edges — and the engine
schedules it with the ordinary §2.2 passes, compiles it as ONE SPMD
program, and launches the whole iteration as ONE dispatch.

Contract highlights (the invariant obligations the §4.5 validator and
the cache layer rely on):

* **Buffers are SSA** — every buffer id is written exactly once (a step
  input, one kernel's result, or one exchange's reception); the lowering
  derives the ``"buffer"`` dependency edges from that def-use relation
  and :meth:`~repro.comm.graph.TransferGraph.validate` re-checks them.
* **Kernel name is identity** — digests, ``GroupKey`` entries, and
  telemetry signatures all key compute work by its registered kernel
  name; registering a different function under a used name raises at
  capture time, because a silently swapped kernel would be served a
  stale executable.
* **Reception values are exact** — inside the SPMD program a reception
  buffer holds the message on its destination device and *zeros*
  elsewhere (``ppermute`` semantics), so summing the per-message
  reception buffers of a ring exchange reconstructs each device's
  received value exactly (adding zeros is exact in IEEE-754 up to the
  sign of zero) — the idiom :func:`captured_psum` and the captured
  Jacobi step build on.
* **Capture signature** — :meth:`StepCapture.signature` is the hashable
  request identity the engine's fast path memoizes resolutions under
  (together with the schedule name and planner epoch), and the scheduled
  graph's :meth:`~repro.comm.graph.TransferGraph.digest` keys the
  compiled executable — two schedules of one captured step digest apart
  and can never cross-serve.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.comm.graph import (BUFFER_EDGE, HOP_EDGE, ComputeNode, CopyNode,
                              DepEdge, TransferGraph)


@dataclasses.dataclass(frozen=True)
class BufferSpec:
    """Static identity of one step buffer: per-device local shape, dtype
    (canonical string), and whether the step *input* arrives replicated.

    Part of the capture signature, so it must stay hashable and
    canonical (the contract :func:`repro.comm.graph.canonical_digest`
    inherits): two captures with equal specs and ops resolve to the same
    fast-path entry. ``replicated`` only affects input staging — results
    and receptions are always per-device local values.
    """

    shape: tuple[int, ...]
    dtype: str
    replicated: bool = False


@dataclasses.dataclass(frozen=True)
class BufferRef:
    """Opaque handle to a capture buffer (its id in the buffer table).

    Refs are how a step's dataflow is declared — the lowering turns the
    def-use relation over refs into the graph's validated ``"buffer"``
    edges, so holding a ref across captures (or forging ids) breaks the
    SSA contract and fails validation.
    """

    buf_id: int


def _dtype_str(dtype) -> str:
    return str(jnp.dtype(dtype))


class StepCapture:
    """Recorder for one iteration: inputs, kernels, exchanges.

    The builder half of ``session.capture(build_fn)``: ``build_fn``
    receives the capture, declares buffers/ops through the methods
    below, and returns the output ref(s). Nothing executes at capture
    time — the recording is lowered (:func:`lower_step`), scheduled, and
    compiled by the engine on first launch, then memoized by
    :meth:`signature` + planner epoch.

    Invariant obligations: buffers are SSA (each id written once),
    kernel names are identities (re-registering a different function
    under a used name raises), and exchanged payloads must be 1-D
    buffers produced by an input or a kernel (never a raw reception —
    pass receptions through a kernel first, which also gives the §4.5
    validator a compute producer for the next round's buffer edges).
    """

    def __init__(self):
        self.buffers: list[BufferSpec] = []
        self.inputs: list[int] = []
        self.ops: list[tuple] = []
        self.kernels: dict[str, Callable] = {}
        self._receptions: set[int] = set()

    def _new_buffer(self, spec: BufferSpec) -> int:
        self.buffers.append(spec)
        return len(self.buffers) - 1

    def _resolve(self, ref: BufferRef) -> int:
        if not isinstance(ref, BufferRef):
            raise TypeError(f"expected a BufferRef, got {type(ref)!r}")
        if not 0 <= ref.buf_id < len(self.buffers):
            raise ValueError(f"unknown buffer id {ref.buf_id} (refs are "
                             "capture-local; the SSA contract forbids "
                             "sharing them across captures)")
        return ref.buf_id

    def input(self, shape: Sequence[int], dtype=jnp.float32, *,
              replicated: bool = False) -> BufferRef:
        """Declare one step input buffer and return its ref.

        ``shape`` is the per-device *local* shape. ``replicated=False``
        (default) means the caller passes a ``(num_devices, *shape)``
        array sharded on the leading axis; ``replicated=True`` means one
        ``shape``-shaped array every device sees whole. Input order is
        call order — the launch contract aligns positional arrays with
        it.
        """
        bid = self._new_buffer(BufferSpec(tuple(int(s) for s in shape),
                                          _dtype_str(dtype),
                                          bool(replicated)))
        self.inputs.append(bid)
        self.ops.append(("input", bid))
        return BufferRef(bid)

    def kernel(self, fn: Callable, *operands: BufferRef,
               out: BufferSpec | Sequence[BufferSpec] | None = None,
               name: str | None = None, flops: int = 0,
               cost_ns: int = 0):
        """Record one SPMD kernel invocation; returns the result ref(s).

        ``fn`` maps the operands' local values to one array (or a tuple
        of arrays) — it runs on every device inside the compiled
        program. Result specs come from ``jax.eval_shape`` unless ``out``
        is given explicitly (required when ``fn`` uses
        ``jax.lax.axis_index``, which cannot be abstractly evaluated
        outside the mesh). ``name`` (default ``fn.__name__``) is the
        kernel's *identity* — it reaches digests, cache keys, and
        telemetry signatures, so registering a different function under
        a used name raises (the §2.2 identity contract). ``flops`` /
        ``cost_ns`` feed the cost model's
        :class:`~repro.comm.graph.ComputeNode` pricing so ``auto``
        arbitration prices compute honestly.
        """
        kname = name if name is not None else getattr(fn, "__name__",
                                                      "kernel")
        if kname == "<lambda>":
            raise ValueError("anonymous kernels need an explicit name= "
                             "(the name is the cache identity)")
        prior = self.kernels.get(kname)
        if prior is not None and prior is not fn:
            raise ValueError(
                f"kernel name {kname!r} already registered with a "
                f"different function — the name is the digest/cache "
                f"identity and must not be reused")
        ops = tuple(self._resolve(r) for r in operands)
        if out is None:
            args = [jax.ShapeDtypeStruct(self.buffers[b].shape,
                                         jnp.dtype(self.buffers[b].dtype))
                    for b in ops]
            try:
                res = jax.eval_shape(fn, *args)
            except Exception as exc:  # axis_index etc.
                raise ValueError(
                    f"could not infer result specs for kernel {kname!r} "
                    f"(kernels using lax.axis_index must pass out=): "
                    f"{exc}") from exc
            single = not isinstance(res, (tuple, list))
            specs = [BufferSpec(tuple(r.shape), _dtype_str(r.dtype))
                     for r in ((res,) if single else res)]
        else:
            single = isinstance(out, BufferSpec)
            specs = [out] if single else list(out)
        results = tuple(self._new_buffer(s) for s in specs)
        self.kernels[kname] = fn
        self.ops.append(("kernel", kname, ops, results,
                         int(flops), int(cost_ns)))
        refs = tuple(BufferRef(b) for b in results)
        return refs[0] if single else refs

    def exchange(self, sends: Sequence[tuple[BufferRef, int, int]], *,
                 max_paths: int | None = None,
                 num_chunks: int | None = None) -> list[BufferRef]:
        """Record one fused multipath exchange; returns reception refs.

        ``sends`` is one ``(payload_ref, src, dst)`` per message; the
        exchange is planned *jointly* (the engine's ``plan_group``) and
        lowers to the group's copy nodes inside the step graph. Each
        message gets a fresh reception buffer: inside the program it
        holds the full payload on ``dst`` and exact zeros elsewhere (the
        summable-receptions contract in the module docstring). Payloads
        must be 1-D and must not themselves be raw receptions (route
        them through a kernel first — preserves the SSA/def-use
        validation). ``max_paths`` / ``num_chunks`` pass through to the
        planner and are part of the capture signature.
        """
        if not sends:
            raise ValueError("exchange needs at least one message")
        rec: list[tuple[int, int, int]] = []
        results = []
        for (ref, src, dst) in sends:
            bid = self._resolve(ref)
            spec = self.buffers[bid]
            if len(spec.shape) != 1:
                raise ValueError(
                    f"exchange payloads must be 1-D buffers, got shape "
                    f"{spec.shape} (reshape inside a kernel first)")
            if bid in self._receptions:
                raise ValueError(
                    "cannot exchange a raw reception buffer — pass it "
                    "through a kernel first (def-use contract)")
            if src == dst:
                raise ValueError(f"self-send {src}->{dst} in exchange")
            rec.append((bid, int(src), int(dst)))
            rbuf = self._new_buffer(BufferSpec(spec.shape, spec.dtype))
            self._receptions.add(rbuf)
            results.append(rbuf)
        self.ops.append(("exchange", tuple(rec), max_paths, num_chunks,
                         tuple(results)))
        return [BufferRef(b) for b in results]

    def signature(self) -> tuple:
        """Hashable request identity of the recording — buffer table +
        op list (kernel *names*, not functions: the name-is-identity
        contract). Together with the schedule name and the planner
        epoch this keys the engine's fast-path memo, exactly like a
        transfer-group request signature.
        """
        return ("capture",
                tuple(dataclasses.astuple(b) for b in self.buffers),
                tuple(self.ops))


def lower_step(capture: StepCapture, plan_group_fn,
               topology_name: str) -> tuple[TransferGraph, tuple]:
    """Lower a recording to ONE heterogeneous transfer graph.

    Emits nodes in program order (a valid topological order): one
    :class:`~repro.comm.graph.ComputeNode` per kernel invocation, and
    per exchange the jointly-planned group's copy nodes in the paper's
    Algorithm 1 wave order with *global* message indices. Dependency
    edges: ``"hop"`` within chunks, ``"buffer"`` for def-use (producer
    compute → first-hop copies of its payload's messages; terminal
    copies → consumer computes; compute → compute). The graph carries
    the ``messages`` table (msg → payload/reception buffer ids) and is
    §4.5-validated (byte cover per message, hop chains, buffer def-use)
    before being returned together with the flat plan tuple (telemetry
    routes + modeling). ``plan_group_fn(specs, max_paths=, num_chunks=)``
    is the engine's joint planner hook.
    """
    nodes: list = []
    edges: list[DepEdge] = []
    messages: list[tuple[int, int]] = []
    plans_all: list = []
    msg_nbytes: dict[int, int] = {}
    producer: dict[int, int] = {}        # buf -> compute node idx
    terminals_of: dict[int, list[int]] = {}   # reception buf -> copies
    for op in capture.ops:
        if op[0] == "input":
            continue
        if op[0] == "kernel":
            _, kname, operands, results, flops, cost_ns = op
            idx = len(nodes)
            compute_preds = set()
            for b in operands:
                p = producer.get(b)
                if p is not None:
                    compute_preds.add(p)
                for t in terminals_of.get(b, ()):
                    edges.append(DepEdge(t, idx, BUFFER_EDGE))
            for p in sorted(compute_preds):
                edges.append(DepEdge(p, idx, BUFFER_EDGE))
            nodes.append(ComputeNode(kname, 0, operands, results,
                                     flops, cost_ns))
            for r in results:
                producer[r] = idx
            continue
        # exchange
        _, sends, max_paths, num_chunks, results = op
        specs = []
        for (payload, src, dst) in sends:
            spec = capture.buffers[payload]
            specs.append((src, dst, spec.shape[0],
                          jnp.dtype(spec.dtype)))
        group = plan_group_fn(specs, max_paths=max_paths,
                              num_chunks=num_chunks)
        for plan, (payload, _, _), rbuf in zip(group.plans, sends,
                                               results):
            m_idx = len(messages)
            messages.append((payload, rbuf))
            msg_nbytes[m_idx] = plan.nbytes
            plans_all.append(plan)
            flow = (plan.src, plan.dst)
            prod = producer.get(payload)
            terms = terminals_of.setdefault(rbuf, [])
            per_path = [(pa.route.directional_links(), pa.chunk_bounds())
                        for pa in plan.paths]
            waves = max((len(b) for _, b in per_path), default=0)
            for c_idx in range(waves):
                for p_idx, (links, bounds) in enumerate(per_path):
                    if c_idx >= len(bounds):
                        continue
                    off, size = bounds[c_idx]
                    first = len(nodes)
                    for h_idx, link in enumerate(links):
                        k = len(nodes)
                        nodes.append(CopyNode(flow, m_idx, p_idx, c_idx,
                                              h_idx, 0, link, off, size))
                        if h_idx:
                            edges.append(DepEdge(k - 1, k, HOP_EDGE))
                    if prod is not None:
                        edges.append(DepEdge(prod, first, BUFFER_EDGE))
                    terms.append(len(nodes) - 1)
    graph = TransferGraph(tuple(nodes), tuple(edges), 1, len(messages),
                          topology_name, tuple(messages))
    graph.validate(msg_nbytes, cross_flow_exclusive=False)
    return graph, tuple(plans_all)


def emit_step(graph: TransferGraph, buffers: Sequence[BufferSpec],
              kernels: dict, values: dict, axis_name: str) -> dict:
    """Walk a SCHEDULED heterogeneous graph in topological order, one
    ``ppermute`` per copy node and one kernel call per compute node.

    ``values`` maps buffer id → local array for the step inputs; the
    walk fills in kernel results and reception buffers (zeros +
    per-terminal ``dynamic_update_slice``, the §4.5 "final
    synchronization" join) and returns the completed map. Dataflow
    follows the graph's hop and buffer edges exactly — the emitter owns
    no ordering of its own, preserving the §2.2 schedule = node-index
    order invariant.
    """
    values = dict(values)
    preds = graph.hop_predecessor
    terminals = graph.terminal_nodes
    chunk_vals: dict[int, jax.Array] = {}
    for idx in graph.topological_order():
        node = graph.nodes[idx]
        if isinstance(node, ComputeNode):
            args = [values[b] for b in node.operands]
            res = kernels[node.kernel](*args)
            if len(node.results) == 1:
                values[node.results[0]] = res
            else:
                for r, v in zip(node.results, res):
                    values[r] = v
            continue
        payload_id, result_id = graph.messages[node.msg_idx]
        isz = jnp.dtype(buffers[payload_id].dtype).itemsize
        if node.offset % isz or node.nbytes % isz:
            raise ValueError("chunk bounds not element-aligned")
        off_e, size_e = node.offset // isz, node.nbytes // isz
        pred = preds.get(idx)
        if pred is None:
            chunk = jax.lax.slice(values[payload_id], (off_e,),
                                  (off_e + size_e,))
        else:
            chunk = chunk_vals.pop(pred)
        chunk = jax.lax.ppermute(chunk, axis_name, [node.link])
        if idx in terminals:
            spec = buffers[result_id]
            cur = values.get(result_id)
            if cur is None:
                cur = jnp.zeros(spec.shape, jnp.dtype(spec.dtype))
            values[result_id] = jax.lax.dynamic_update_slice(
                cur, chunk, (off_e,))
        else:
            chunk_vals[idx] = chunk
    return values


class CapturedStep:
    """Launchable handle for one captured iteration.

    Calling it stages the inputs and launches the compiled SPMD program
    ONCE — `session.stats()["dispatches"]` increments by exactly one per
    call, the acceptance invariant of whole-iteration capture. Outputs
    come back device-stacked ``(num_devices, *local_shape)``; replicated
    results are row-identical (take row 0). Resolution rides the
    engine's fast path: the capture :meth:`~StepCapture.signature` +
    schedule name + planner epoch memoize the lowered/scheduled/compiled
    entry, and the scheduled graph digest keys the executable — two
    schedules of the same capture digest apart and never cross-serve.
    """

    def __init__(self, engine, capture: StepCapture,
                 outputs: Sequence[BufferRef],
                 schedule: str | None = None):
        self.engine = engine
        self.capture = capture
        self.outputs = tuple(capture._resolve(r) for r in outputs)
        self.schedule = schedule

    def resolve(self, schedule: str | None = None):
        """Resolve (lower → schedule → validate → compile → memoize)
        without launching; returns the fast-path entry whose ``graph``
        (scheduled, digest-keyed) the §2.2 contract checked. Useful for
        inspection and modeled-time evaluation.
        """
        return self.engine.resolve_step(
            self, schedule if schedule is not None else self.schedule)

    def __call__(self, *arrays, schedule: str | None = None,
                 block: bool = True) -> list[jax.Array]:
        """Run one captured iteration as ONE dispatch; ``arrays`` align
        with the capture's declared inputs (sharded inputs are global
        ``(num_devices, *local)``; replicated inputs are bare local
        arrays). Preserves eager numerics — the kernels are the same
        functions, receptions join by exact zero-sum."""
        return self.engine.run_step(
            self, arrays,
            schedule=schedule if schedule is not None else self.schedule,
            block=block)


def captured_psum(cap: StepCapture, ref: BufferRef, num_devices: int, *,
                  max_paths: int | None = None,
                  num_chunks: int | None = None,
                  name: str | None = None) -> BufferRef:
    """Express a ring all-reduce *sum* of a 1-D buffer as capture ops.

    ``num_devices - 1`` rounds; each round is one fused multipath
    exchange of every device's running value to its right neighbor plus
    one combine kernel that joins the receptions by exact zero-sum (the
    module-docstring contract) and accumulates. The whole collective
    therefore lives inside the SAME step graph as the compute that
    produced ``ref`` — the schedulers interleave its copies into compute
    gaps, and the §4.5 validator checks every round's byte cover and
    buffer def-use. Divide by ``num_devices`` afterwards for a pmean.
    """
    n = int(num_devices)
    if n < 2:
        return ref
    prefix = name if name is not None else f"psum{len(cap.ops)}"
    nelems = cap.buffers[cap._resolve(ref)].shape[0]
    acc, cur = ref, ref
    for r in range(n - 1):
        recvs = cap.exchange([(cur, i, (i + 1) % n) for i in range(n)],
                             max_paths=max_paths, num_chunks=num_chunks)

        def combine(acc_v, *received):
            got = received[0]
            for x in received[1:]:
                got = got + x
            return acc_v + got, got

        acc, cur = cap.kernel(combine, acc, *recvs,
                              name=f"{prefix}_r{r}",
                              flops=(n + 1) * nelems)
    return acc
