"""Alias — the version-compat shims live at :mod:`repro.compat`.

They started here, but every layer (core, kernels, launch, models, optim,
training) needs them, and ``core`` must not depend on ``comm``; the
implementation moved to the neutral top level. This alias keeps
``repro.comm.compat`` imports working.
"""

from repro.compat import (  # noqa: F401
    axis_size, get_abstract_mesh, has_pallas_tpu_interpret_mode, make_mesh,
    pallas_interpret_flag, pallas_tpu_compiler_params, set_mesh, shard_map)
