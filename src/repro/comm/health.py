"""Link-fault subsystem: injection, health monitoring, quarantine, and
the degraded-mode dispatch ladder (DESIGN §4.6).

A production multipath plan is only as good as its sickest link (De
Sensi et al. document per-link droop and intermittent failure that a
static topology model ignores). This module closes the loop over the
sensing and invalidation machinery the repo already has:

* the **fault model** lives on :class:`repro.core.topology.Topology`
  (``fail_link`` / ``degrade_link`` / ``restore_link`` / ``mark_flaky``)
  — every mutation bumps the plan epoch, so the §2.3 fast-path
  invalidation and the §4.4c calibration-shadow machinery do the cache
  work for free: no stale executable is ever served over a faulted link;
* :class:`FaultInjector` is the deterministic chaos harness
  (schedule/seed-driven: down-at-dispatch-N, droop-for-K-dispatches,
  flap, injected dispatch drops) usable from tests, benchmarks, and the
  ``REPRO_MP_FAULTS`` environment knob;
* :class:`HealthMonitor` watches the telemetry stream for per-link
  residuals against the calibrated §4.4 model, quarantines links that
  breach the droop threshold for M consecutive samples (via
  :meth:`repro.comm.planner.PathPlanner.quarantine` — an epoch-bumping
  exclusion, so re-plans validate against the surviving link set), and
  re-admits them on consecutive healthy probes;
* the engine walks the documented **degradation ladder** (:data:`LADDER`:
  full multipath → surviving-paths multipath → single best path →
  staged host relay), retrying with bounded exponential backoff and
  never raising to the caller until the ladder is exhausted
  (:class:`CommFaultError`); every successful dispatch preserves the
  §4.5 integrity invariants — degraded plans are validated exactly like
  healthy ones.
"""

from __future__ import annotations

import dataclasses
import random
import re
from typing import TYPE_CHECKING, Iterable, Sequence

from repro.core.topology import HOST, Topology

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids engine cycle
    from repro.comm.engine import MultiPathTransfer
    from repro.comm.planner import PathPlanner
    from repro.comm.telemetry import DispatchSample

#: The §4.6 degradation ladder, least to most degraded. Rung 0 is the
#: full multipath plan as requested; rung 1 re-plans over the surviving
#: (non-failed, non-quarantined) links at the same path count; rung 2
#: falls back to the single best surviving path; rung 3 stages through
#: the host (PCIe round-trip — delivery over bandwidth). The engine
#: records the rung of the last successful dispatch in
#: ``HealthStats.ladder_level`` and only raises :class:`CommFaultError`
#: once every rung is exhausted — the never-raise-early contract.
LADDER = ("multipath", "surviving_multipath", "single_path", "staged_host")

_ACTIONS = ("fail", "degrade", "restore", "drop", "flap")

_SPEC = re.compile(
    r"^(?P<action>fail|degrade|restore|drop|flap)"
    r"@(?P<at>\d+)"
    r"(?:~(?P<period>\d+))?"
    r"(?:x(?P<count>\d+))?"
    r":(?P<src>-?\d+)-(?P<dst>-?\d+)"
    r"(?:\*(?P<ratio>[0-9.]+))?$")


class LinkFaultError(RuntimeError):
    """A dispatch hit a faulted link (injected drop, or an entry that
    still routes over a failed/quarantined link).

    Internal to the degraded dispatch loop: the engine catches it,
    quarantines ``links``, retries with backoff, and re-plans — it only
    escapes to the caller wrapped in :class:`CommFaultError` after the
    whole ladder is exhausted, preserving the §4.6 never-raise-early
    contract.
    """

    def __init__(self, links: Iterable[tuple[int, int]], reason: str):
        self.links = tuple(tuple(link) for link in links)
        self.reason = reason
        super().__init__(f"{reason}: links {self.links}")


class CommFaultError(RuntimeError):
    """The degradation ladder is exhausted: no surviving multipath,
    single-path, or host-staged route can deliver the request.

    Raised only after every :data:`LADDER` rung failed (the §4.6
    contract that degraded mode never gives up while any route
    survives); carries the per-rung failure history for diagnosis.
    """

    def __init__(self, message: str, history: Sequence[str] = ()):
        self.history = tuple(history)
        detail = ("; ".join(self.history)) if self.history else ""
        super().__init__(message + (f" [{detail}]" if detail else ""))


@dataclasses.dataclass
class HealthStats:
    """Engine-level degraded-mode counters (DESIGN §4.6), surfaced as
    the ``health`` section of ``session.stats()``.

    ``retries``/``replans``/``faults_seen``/``host_relays`` are windowed
    (zeroed by ``stats(reset=True)``, the PR 6 windowed-stats contract);
    ``ladder_level`` is state — the :data:`LADDER` rung of the most
    recent successful dispatch — and survives a window reset, as does
    the ``events`` log (drained explicitly via
    ``session.drain_health_events()``).
    """

    retries: int = 0
    replans: int = 0
    faults_seen: int = 0
    host_relays: int = 0
    ladder_level: int = 0
    events: list = dataclasses.field(default_factory=list)

    def note(self, kind: str, **payload) -> None:
        """Append one health event (``{"kind": kind, **payload}``) to
        the log — the record ``ResilientTrainLoop`` drains so comm-layer
        faults surface in its event history instead of as opaque step
        exceptions (the §4.6 observability contract)."""
        self.events.append({"kind": kind, **payload})

    def reset_window(self) -> None:
        """Zero the windowed counters (retries/replans/faults_seen/
        host_relays) while preserving ``ladder_level`` and the event
        log — the same windowed-vs-state split ``PlanLifecycle``
        validates for its own counters."""
        self.retries = 0
        self.replans = 0
        self.faults_seen = 0
        self.host_relays = 0

    def snapshot(self, quarantined: int, enabled: bool) -> dict:
        """The stats-schema dict for this window. ``quarantined`` is the
        current planner quarantine count and ``enabled`` whether a
        monitor is attached — both state, not windowed; the returned
        shape is pinned by test_fastpath's stats-shape contract."""
        return {"enabled": enabled,
                "retries": self.retries,
                "replans": self.replans,
                "faults_seen": self.faults_seen,
                "host_relays": self.host_relays,
                "ladder_level": self.ladder_level,
                "quarantined_links": quarantined}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault-model mutation, fired when the engine's
    dispatch counter reaches ``at`` (deterministic by construction — the
    injector's reproducibility contract).

    ``action`` is one of ``fail`` / ``degrade`` / ``restore`` / ``drop``;
    ``ratio`` is the droop factor for ``degrade``; ``duration`` is the
    auto-restore horizon for ``degrade`` (droop-for-K-dispatches) or the
    window length for ``drop`` (launches blamed on ``link`` for K
    dispatches, exercising the retry/backoff path).
    """

    at: int
    action: str
    link: tuple[int, int]
    ratio: float = 0.0
    duration: int = 0

    def __post_init__(self) -> None:
        if self.action not in ("fail", "degrade", "restore", "drop"):
            raise ValueError(f"unknown fault action {self.action!r}")
        if self.at < 0:
            raise ValueError(f"negative dispatch index {self.at}")
        if self.action == "degrade" and not 0.0 < self.ratio <= 1.0:
            raise ValueError(f"degrade ratio must be in (0, 1], "
                             f"got {self.ratio}")


class FaultInjector:
    """Deterministic chaos harness: applies a schedule of
    :class:`FaultEvent` mutations keyed on the engine's dispatch
    counter.

    The injector is the *only* nondeterminism-free way to exercise the
    §4.6 degraded path: given the same schedule (or the same seed via
    :meth:`seeded`) and the same traffic, every run fails, droops, and
    drops the same links at the same dispatches — the reproducibility
    contract chaos tests and the ``REPRO_MP_FAULTS`` env knob rely on.
    Attached to an engine (``session`` wires it from
    ``CommConfig.faults``), ``on_dispatch`` fires due events before each
    dispatch resolves, so the epoch bump always precedes the re-plan and
    no stale executable is validated against the mutated topology.
    """

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self._events = sorted(events, key=lambda e: e.at)
        self._idx = 0
        self._drops: list[tuple[int, int, tuple[int, int]]] = []
        self.applied: list[dict] = []

    @classmethod
    def from_spec(cls, spec: str) -> "FaultInjector":
        """Parse the ``REPRO_MP_FAULTS`` grammar into an injector.

        Entries are ``;``/``,``-separated, each
        ``ACTION@AT[~PERIOD][xCOUNT]:SRC-DST[*RATIO]``:

        * ``fail@12:0-1`` — link (0, 1) down at dispatch 12;
        * ``degrade@20x8:2-3*0.25`` — droop (2, 3) to 25 % nominal at
          dispatch 20, auto-restore 8 dispatches later;
        * ``restore@40:0-1`` — restore (0, 1) at dispatch 40;
        * ``drop@5x2:0-1`` — blame launches on (0, 1) for 2 dispatches
          starting at 5 (exercises retry/backoff without a topology
          mutation);
        * ``flap@30~4x3:0-1`` — 3 fail/restore cycles of period 4
          starting at dispatch 30 (the flaky-link mode).

        Raises ``ValueError`` on malformed entries — a chaos schedule
        that silently half-parses would invalidate the determinism
        contract.
        """
        events: list[FaultEvent] = []
        for raw in re.split(r"[;,]", spec):
            raw = raw.strip()
            if not raw:
                continue
            m = _SPEC.match(raw)
            if m is None:
                raise ValueError(
                    f"malformed fault spec entry {raw!r}; expected "
                    f"ACTION@AT[~PERIOD][xCOUNT]:SRC-DST[*RATIO] with "
                    f"ACTION in {_ACTIONS}")
            action = m.group("action")
            at = int(m.group("at"))
            link = (int(m.group("src")), int(m.group("dst")))
            count = int(m.group("count") or 1)
            period = m.group("period")
            ratio = float(m.group("ratio") or 0.0)
            if action == "flap":
                if period is None:
                    raise ValueError(
                        f"flap entry {raw!r} needs a ~PERIOD")
                step = int(period)
                for cycle in range(count):
                    t = at + 2 * cycle * step
                    events.append(FaultEvent(t, "fail", link))
                    events.append(FaultEvent(t + step, "restore", link))
            elif action == "degrade":
                events.append(FaultEvent(at, "degrade", link, ratio=ratio,
                                         duration=count if count > 1
                                         else 0))
            elif action == "drop":
                events.append(FaultEvent(at, "drop", link, duration=count))
            else:
                events.append(FaultEvent(at, action, link))
        return cls(events)

    @classmethod
    def seeded(cls, topology: Topology, seed: int, *, events: int = 2,
               start: int = 2, spacing: int = 6) -> "FaultInjector":
        """Seed-driven schedule: ``events`` fail/restore cycles over
        device-device links chosen by ``random.Random(seed)``.

        Deterministic for a (topology digest, seed) pair — the same
        seed always faults the same links at the same dispatches, the
        property chaos tests' reproducibility contract.
        """
        rng = random.Random(seed)
        keys = sorted(k for k in topology.links
                      if HOST not in k)
        if not keys:
            raise ValueError("topology has no device-device links to fault")
        out: list[FaultEvent] = []
        t = start
        for _ in range(events):
            link = keys[rng.randrange(len(keys))]
            out.append(FaultEvent(t, "fail", link))
            out.append(FaultEvent(t + max(1, spacing // 2), "restore", link))
            t += spacing
        return cls(out)

    @property
    def active(self) -> bool:
        """True while events are still pending or a drop window may be
        live — the engine's hazard gate: an exhausted injector costs the
        healthy dispatch path nothing beyond one boolean (the
        zero-overhead-off contract health monitoring shares with
        telemetry)."""
        return self._idx < len(self._events) or bool(self._drops)

    def on_dispatch(self, engine: "MultiPathTransfer") -> list[dict]:
        """Apply every event due at the engine's current dispatch count.

        Fires *before* the dispatch resolves, so the topology epoch bump
        invalidates the fast path ahead of planning — the injector can
        never make the engine validate a stale executable against a
        mutated link set. Unapplicable events (failing an already-failed
        link, restoring a healthy one) are recorded as skipped rather
        than raised: a chaos schedule races real recovery by design.
        Returns the events applied this call.
        """
        fired: list[dict] = []
        topo = engine.topology
        while (self._idx < len(self._events)
               and self._events[self._idx].at <= engine.dispatches):
            ev = self._events[self._idx]
            self._idx += 1
            record = {"kind": "inject", "action": ev.action,
                      "link": ev.link, "at": ev.at,
                      "dispatch": engine.dispatches}
            try:
                if ev.action == "fail":
                    topo.fail_link(*ev.link)
                elif ev.action == "restore":
                    topo.restore_link(*ev.link)
                elif ev.action == "degrade":
                    topo.degrade_link(*ev.link, ev.ratio)
                    if ev.duration:
                        self._push(FaultEvent(ev.at + ev.duration,
                                              "restore", ev.link))
                elif ev.action == "drop":
                    self._drops.append(
                        (ev.at, ev.at + max(1, ev.duration), ev.link))
            except KeyError:
                record["skipped"] = True
            fired.append(record)
            self.applied.append(record)
            engine.health.faults_seen += 1
            engine.health.note(**record)
        return fired

    def _push(self, event: FaultEvent) -> None:
        """Insert a follow-up event (droop auto-restore) keeping the
        schedule sorted by dispatch index."""
        self._events.append(event)
        self._events.sort(key=lambda e: e.at)
        if self._idx and self._events[self._idx - 1].at > event.at:
            # Never resurrect already-applied events; the pointer only
            # needs to stay behind unapplied ones.
            self._idx -= 1

    def dropped_link(self, dispatch: int,
                     links: Iterable[tuple[int, int]]
                     ) -> tuple[int, int] | None:
        """The link an active drop window blames for this dispatch, or
        ``None``. Expired windows are pruned; a drop only fires when its
        link is actually part of the entry being launched — an injected
        NIC timeout on a link the plan does not use must not fail the
        dispatch (the blame-attribution invariant retries rely on)."""
        self._drops = [d for d in self._drops if d[1] > dispatch]
        link_set = set(links)
        for start, end, link in self._drops:
            if start <= dispatch < end and link in link_set:
                return link
        return None


class HealthMonitor:
    """Telemetry-driven link health: droop detection, quarantine, and
    probe-based re-admission (DESIGN §4.6).

    ``observe`` prices each :class:`~repro.comm.telemetry
    .DispatchSample` against the §4.4 model
    (:func:`repro.comm.calibration.modeled_sample_time_s`, calibrated
    overlay included) and attributes the measured/modeled residual to
    the sample's links; a link breaching ``droop_threshold`` for
    ``droop_samples`` consecutive samples is quarantined through the
    planner (an epoch-bumping exclusion — every cached plan over the
    link is invalidated, the §4.6 safety contract). Residual watching
    requires an attached calibration profile by default
    (``require_calibration``): residuals against nominal constants on a
    different machine are noise, and auto-quarantine from noise would
    violate the do-no-harm contract. Re-admission is probe-based:
    ``probe_healthy`` consecutive healthy probes (``flaky_factor`` ×
    more for links marked flaky) readmit the link, restoring the
    pre-fault plan digest in steady state.
    """

    def __init__(self, topology: Topology, planner: "PathPlanner", *,
                 droop_threshold: float = 2.0, droop_samples: int = 3,
                 probe_healthy: int = 2, recovery_ratio: float = 0.5,
                 probe_interval: int = 16, flaky_factor: int = 2,
                 require_calibration: bool = True):
        self.topology = topology
        self.planner = planner
        self.droop_threshold = float(droop_threshold)
        self.droop_samples = int(droop_samples)
        self.probe_healthy = int(probe_healthy)
        self.recovery_ratio = float(recovery_ratio)
        self.probe_interval = int(probe_interval)
        self.flaky_factor = int(flaky_factor)
        self.require_calibration = bool(require_calibration)
        self.events: list[dict] = []
        self.observed = 0
        self.quarantines = 0
        self.readmissions = 0
        self._streaks: dict[tuple[int, int], int] = {}
        self._probe_streaks: dict[tuple[int, int], int] = {}
        self._last_probe = -1

    @property
    def quarantined(self) -> frozenset:
        """The planner's live quarantine set — the monitor never keeps a
        private copy, so the exclusion the planner validates routes
        against and the set probes work through cannot diverge."""
        return self.planner.quarantined

    def quarantine_link(self, link: tuple[int, int], reason: str,
                        dispatch: int | None = None) -> bool:
        """Quarantine one link (idempotent) and log the event.

        Routed through :meth:`PathPlanner.quarantine`, so the epoch bump
        invalidates every fast-path entry over the link before the next
        resolve — the no-stale-executable contract. Returns True when
        the link was newly quarantined.
        """
        link = tuple(link)
        if link in self.planner.quarantined:
            return False
        self.planner.quarantine(link)
        self.quarantines += 1
        self._probe_streaks[link] = 0
        self.events.append({"kind": "quarantine", "link": link,
                            "reason": reason, "dispatch": dispatch})
        return True

    def observe(self, sample: "DispatchSample") -> float | None:
        """Price one dispatch sample against the calibrated model and
        update per-link droop streaks.

        Returns the measured/modeled ratio, or ``None`` when the sample
        cannot be judged (no calibration while ``require_calibration``,
        or a degenerate modeled time). A ratio above ``droop_threshold``
        bumps the streak of every link the sample crossed; hitting
        ``droop_samples`` consecutive breaches quarantines the link. A
        healthy sample resets its links' streaks — the M-*consecutive*
        contract, not M-cumulative.
        """
        if self.require_calibration and self.topology.calibration is None:
            return None
        from repro.comm.calibration import modeled_sample_time_s
        modeled = modeled_sample_time_s(sample, self.topology,
                                        self.topology.calibration)
        measured = sample.measured_s
        if modeled <= 0 or measured <= 0:
            return None
        self.observed += 1
        ratio = measured / modeled
        breach = ratio > self.droop_threshold
        for link in sample.links:
            if breach:
                streak = self._streaks.get(link, 0) + 1
                self._streaks[link] = streak
                if streak >= self.droop_samples:
                    self.quarantine_link(link, reason="droop")
            else:
                self._streaks.pop(link, None)
        return ratio

    def probe(self, link: tuple[int, int],
              engine: "MultiPathTransfer | None" = None,
              nelems: int = 256) -> bool:
        """Probe one link and feed the verdict to :meth:`note_probe`.

        The verdict is deterministic against the fault model: a failed
        or absent link is unhealthy; otherwise the link's *served*
        bandwidth (droop + calibration overlays included, read through
        ``Topology.link``) must be at least ``recovery_ratio`` × nominal
        — and, when an engine is given, a small single-path transfer
        routed over exactly this link (quarantine bypassed via
        ``admit_quarantined``) must deliver its payload intact. Returns
        the verdict.
        """
        link = tuple(link)
        state = self.topology.link_state(*link)
        if state in ("failed", "absent"):
            ok = False
        else:
            served = self.topology.link(*link)
            nominal = self.topology.links[link]
            ok = (served is not None
                  and served.bandwidth_gbps
                  >= self.recovery_ratio * nominal.bandwidth_gbps)
            if ok and engine is not None and HOST not in link:
                ok = self._probe_transfer(engine, link, nelems)
        self.note_probe(link, ok)
        return ok

    def _probe_transfer(self, engine: "MultiPathTransfer",
                        link: tuple[int, int], nelems: int) -> bool:
        """One compiled single-path send over exactly ``link`` with the
        quarantine exclusion lifted; healthy iff the payload arrives
        intact (validated element-wise)."""
        import jax.numpy as jnp
        from repro.comm.cache import FastPathEntry
        src, dst = link
        dtype = jnp.dtype(jnp.float32)
        plan = engine.planner.plan(
            src, dst, nelems * dtype.itemsize, max_paths=1,
            include_host=False, granularity=dtype.itemsize,
            admit_quarantined=True)
        hops = plan.paths[0].route.directional_links()
        if len(plan.paths) != 1 or hops != (link,):
            # The direct link was not admitted (e.g. raced a fail_link);
            # the model verdict above stands on its own.
            return True
        graph, chosen = engine._group_graph((plan,), 1, "round_robin")
        shapes = ((nelems, dtype),)
        key = engine._group_key(graph, (plan,), shapes, 1)
        compiled = engine.cache.get_or_build(
            key, lambda: engine._compile_group(key, graph, shapes))
        entry = FastPathEntry(plans=(plan,), graph=graph,
                              digest=key.digest, key=key,
                              compiled=compiled, schedule=chosen)
        msg = jnp.arange(nelems, dtype=dtype)
        out = engine._launch(entry, [msg], block=True)[0]
        return bool(jnp.array_equal(out, msg))

    def note_probe(self, link: tuple[int, int], ok: bool) -> None:
        """Fold one probe verdict into the re-admission streak.

        ``probe_healthy`` consecutive healthy probes (× ``flaky_factor``
        for links marked flaky — the hysteresis contract against
        flapping) readmit the link through the planner, bumping the
        epoch so steady-state plans return to the full route set; a
        failed probe resets the streak.
        """
        link = tuple(link)
        if link not in self.planner.quarantined:
            return
        if not ok:
            self._probe_streaks[link] = 0
            self.events.append({"kind": "probe_failed", "link": link})
            return
        streak = self._probe_streaks.get(link, 0) + 1
        self._probe_streaks[link] = streak
        needed = self.probe_healthy * (
            self.flaky_factor if link in self.topology.flaky_links else 1)
        self.events.append({"kind": "probe_ok", "link": link,
                            "streak": streak, "needed": needed})
        if streak >= needed:
            self.planner.readmit(link)
            self.readmissions += 1
            self._streaks.pop(link, None)
            self._probe_streaks.pop(link, None)
            self.events.append({"kind": "readmit", "link": link})

    def probe_all(self, engine: "MultiPathTransfer | None" = None,
                  nelems: int = 256) -> dict:
        """Probe every quarantined link once (sorted order — the
        deterministic sweep contract) and return ``{link: verdict}``."""
        return {link: self.probe(link, engine=engine, nelems=nelems)
                for link in sorted(self.planner.quarantined)}

    def maybe_probe(self, engine: "MultiPathTransfer") -> None:
        """Probe quarantined links at the ``probe_interval`` dispatch
        cadence — the engine's degraded dispatch loop calls this so
        re-admission needs no explicit operator action; a no-op (one
        comparison) when nothing is quarantined, preserving the
        zero-overhead-off contract."""
        if not self.planner.quarantined:
            return
        if engine.dispatches - self._last_probe < self.probe_interval:
            return
        self._last_probe = engine.dispatches
        self.probe_all(engine)

    def snapshot(self) -> dict:
        """JSON-able monitor state for ``session.describe()['health']``:
        quarantined links, droop/probe streaks, and lifetime counters —
        the observability surface the acceptance chaos tests validate."""
        return {
            "quarantined": [list(link)
                            for link in sorted(self.planner.quarantined)],
            "observed": self.observed,
            "quarantines": self.quarantines,
            "readmissions": self.readmissions,
            "droop_threshold": self.droop_threshold,
            "droop_samples": self.droop_samples,
            "probe_healthy": self.probe_healthy,
            "recovery_ratio": self.recovery_ratio,
        }
