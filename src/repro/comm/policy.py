"""PathPolicy — pluggable path-selection strategies (Algorithm 1, line 6).

The planner owns route *enumeration* and chunking mechanics; a policy
decides which of the enumerated routes carry the message and how many bytes
each gets. Three strategies ship:

* :class:`GreedyBandwidthPolicy` — the paper's ``GetPathConfig``: take the
  best ``max_paths`` routes and split shares proportionally to each route's
  bottleneck bandwidth. This reproduces the pre-refactor ``PathPlanner.plan``
  byte-for-byte.
* :class:`RoundRobinPolicy` — uniform striping: equal shares across the
  selected routes. Deliberately deterministic (no per-call rotation — a
  rotating route order would give every message a distinct plan signature
  and defeat the compiled-plan cache).
* :class:`TunerPolicy` — offline-tuner backed (paper §4.4): exhaustively
  searches (paths × chunks × host) under the analytic pipeline model and
  memoizes the winner per (src, dst, nbytes) so steady-state planning stays
  cheap.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Mapping, Protocol, Sequence, \
    runtime_checkable

from repro.core.topology import HOST, Route

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.comm.planner import PathPlanner
    from repro.comm.plan import TransferPlan


def contention_scaled(routes: Sequence[Route],
                      link_flows: Mapping[tuple[int, int], int]
                      ) -> list[Route]:
    """Derate each route's bottleneck bandwidth by group contention.

    ``link_flows`` counts how many other flows of the group already use
    each directional link; a link carrying *k* other flows contributes
    ``bandwidth / (1 + k)`` to the route's bottleneck — the same
    equal-share model :func:`repro.core.pipelining.wire_time_s` applies.
    Routes are re-sorted best-first under the derated bandwidths (host
    last, as in enumeration) so bandwidth-proportional share splitting
    sees the *effective* capacities instead of the nominal ones.
    """
    out = []
    for r in routes:
        eff = min(h.bandwidth_gbps / (1 + link_flows.get((h.src, h.dst), 0))
                  for h in r.hops)
        out.append(dataclasses.replace(r, bottleneck_gbps=eff))
    out.sort(key=lambda r: (r.via == HOST, -r.bottleneck_gbps, r.num_hops))
    return out


@runtime_checkable
class PathPolicy(Protocol):
    """Strategy protocol: build a plan from the enumerated candidate routes.

    ``routes`` arrive best-first (direct, then staged by hop count and
    bandwidth, host last) and already truncated to a single route when the
    message is below the planner's multipath threshold. Implementations
    normally call :meth:`PathPlanner.compose` to apply the shared chunking
    rules so the §4.5 invariants hold by construction.
    """

    name: str
    #: True when ``build`` selects among exactly the ``routes`` it is given.
    #: Group planning (``PathPlanner.plan_group``) relies on this to keep
    #: its contention-filtered route sets authoritative; policies that
    #: replan from scratch (the tuner) are swapped for greedy inside a
    #: group.
    honors_routes: bool

    def build(self, planner: "PathPlanner", src: int, dst: int, nbytes: int,
              *, routes: Sequence[Route], max_paths: int,
              num_chunks: int | None, granularity: int,
              include_host: bool) -> "TransferPlan":
        ...


class GreedyBandwidthPolicy:
    """Bandwidth-proportional shares over the best ``max_paths`` routes."""

    name = "greedy"
    honors_routes = True

    def build(self, planner: "PathPlanner", src: int, dst: int, nbytes: int,
              *, routes: Sequence[Route], max_paths: int,
              num_chunks: int | None, granularity: int,
              include_host: bool) -> "TransferPlan":
        routes = list(routes)[:max_paths]
        total_bw = sum(r.bottleneck_gbps for r in routes)
        shares: list[tuple[Route, int]] = []
        assigned = 0
        for i, route in enumerate(routes):
            if i == len(routes) - 1:
                share = nbytes - assigned  # remainder absorbs rounding (§4.5)
            else:
                share = (int(nbytes * route.bottleneck_gbps / total_bw)
                         // granularity * granularity)
            shares.append((route, share))
            assigned += share
        return planner.compose(src, dst, nbytes, shares,
                               num_chunks=num_chunks, granularity=granularity)


class RoundRobinPolicy:
    """Equal shares across the selected routes (uniform striping)."""

    name = "round_robin"
    honors_routes = True

    def build(self, planner: "PathPlanner", src: int, dst: int, nbytes: int,
              *, routes: Sequence[Route], max_paths: int,
              num_chunks: int | None, granularity: int,
              include_host: bool) -> "TransferPlan":
        routes = list(routes)[:max_paths]
        k = len(routes)
        base = (nbytes // k) // granularity * granularity
        shares = [(route, base) for route in routes[:-1]]
        shares.append((routes[-1], nbytes - base * (k - 1)))
        return planner.compose(src, dst, nbytes, shares,
                               num_chunks=num_chunks, granularity=granularity)


class TunerPolicy:
    """Offline-tuned plans (paper §4.4), memoized per message signature.

    The search itself runs the greedy policy over the candidate grid (so the
    tuner explores exactly the configurations the paper's handler would
    build), scored by the analytic pipeline model.
    """

    name = "tuner"
    honors_routes = False

    def __init__(self, *, path_counts: tuple[int, ...] = (1, 2, 3, 4),
                 chunk_counts: tuple[int, ...] = (1, 2, 4, 8, 16),
                 include_host_options: tuple[bool, ...] = (False, True),
                 use_compiled_plans: bool = True):
        self.path_counts = path_counts
        self.chunk_counts = chunk_counts
        self.include_host_options = include_host_options
        self.use_compiled_plans = use_compiled_plans
        self._memo: dict[tuple, "TransferPlan"] = {}

    def build(self, planner: "PathPlanner", src: int, dst: int, nbytes: int,
              *, routes: Sequence[Route], max_paths: int,
              num_chunks: int | None, granularity: int,
              include_host: bool) -> "TransferPlan":
        # Key on the topology OBJECT (identity hash): names are non-unique
        # defaults (full_mesh() is always "beluga4"), and a policy shared
        # across sessions must not serve one topology's plan to another.
        key = (planner.topology, src, dst, nbytes, num_chunks,
               granularity, max_paths, include_host)
        plan = self._memo.get(key)
        if plan is None:
            chunk_counts = (self.chunk_counts if num_chunks is None
                            else (num_chunks,))
            path_counts = tuple(p for p in self.path_counts
                                if p <= max_paths) or (max_paths,)
            # The caller's host constraint is a hard cap: a host-staged
            # plan handed to the engine would be rejected as unexecutable.
            host_options = tuple(h for h in self.include_host_options
                                 if include_host or not h) or (False,)
            plan = planner.tune(src, dst, nbytes,
                                path_counts=path_counts,
                                chunk_counts=chunk_counts,
                                include_host_options=host_options,
                                use_compiled_plans=self.use_compiled_plans,
                                granularity=granularity)
            self._memo[key] = plan
        return plan


def make_policy(name: str, **kwargs) -> PathPolicy:
    """Resolve a policy name from :data:`repro.comm.config.POLICY_NAMES`."""
    registry = {
        GreedyBandwidthPolicy.name: GreedyBandwidthPolicy,
        RoundRobinPolicy.name: RoundRobinPolicy,
        TunerPolicy.name: TunerPolicy,
    }
    try:
        cls = registry[name]
    except KeyError:
        raise ValueError(f"unknown path policy {name!r}; expected one of "
                         f"{sorted(registry)}") from None
    return cls(**kwargs)
