"""MultiPathTransfer — executable multi-path P2P transfers on a JAX mesh.

This is the UCT-layer analogue (DESIGN.md §2): it lowers one or more
:class:`~repro.comm.plan.TransferPlan` objects to ONE
:class:`~repro.comm.graph.TransferGraph` (the CUDA Graph analogue), runs
the configured chunk-interleaving scheduler pass over it
(:mod:`repro.comm.passes`, DESIGN.md §2.2 — the emitter owns no ordering
of its own), walks the SCHEDULED graph's copy nodes in topological order
emitting one ``ppermute`` per node, compiles the resulting SPMD program
once, and caches the executable in a
:class:`~repro.comm.cache.TransferPlanCache` keyed on the scheduled
graph's canonical :meth:`~repro.comm.graph.TransferGraph.digest` — the
paper's graph cache keyed on (src, dst, size, path configuration), here
additionally distinguishing dispatch orders.

A **transfer group** (:meth:`MultiPathTransfer.transfer_group`) fuses a set
of concurrent messages — planned jointly by
:meth:`~repro.comm.planner.PathPlanner.plan_group` — into ONE graph, one
traced / lowered / compiled program, one cache entry, and one launch: the
paper's graph-per-message becomes one graph per traffic pattern (message
fusion à la Choi et al.). Single sends are the 1-message special case of
the same machinery.

Steady state takes the **dispatch fast path** (DESIGN.md §2.3): the whole
plan→lower→schedule→digest resolution is memoized per request signature
in an epoch-stamped :class:`~repro.comm.cache.FastPathCache`, operand
staging runs through pooled per-key staging programs, and repeat traffic
is one dict lookup + one staging write + one launch — the paper's "setup
once, launch many". Any planner/topology mutation bumps the epoch and
forces a re-plan; ``REPRO_MP_FASTPATH=0`` disables the front cache and
``REPRO_MP_VALIDATE=always`` re-validates even on hits.

Correctness model (§4.5 of the paper → functional dataflow here): the
graph's hop edges ARE the program's dataflow (hop *i+1* consumes hop *i*'s
value), chunks write disjoint precomputed destination offsets, paths never
share a directional link (validated on the same graph the program is
emitted from), and "final synchronization" is the functional join of all
terminal copy nodes. Because the emitter walks the same lowering the
model and the validators consume, the three can no longer diverge.

The engine runs on a flat 1-D device axis (default ``"dev"``); topology
device ids are mesh positions. Model-parallel meshes are a separate concern
(``repro/launch/mesh.py``). Most callers should go through
:class:`~repro.comm.session.CommSession` rather than constructing the
engine directly.
"""

from __future__ import annotations

import dataclasses
import os
import re
import time
from collections import OrderedDict
from functools import lru_cache
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.cache import (CompiledPlan, FastPathCache, FastPathEntry,
                              TransferPlanCache, compile_plan)
from repro.comm.capture import CapturedStep, StepCapture, emit_step, lower_step
from repro.compat import shard_map
from repro.comm.config import VALIDATE_MODES, _env_bool
from repro.comm.graph import ComputeNode, TransferGraph, lower
from repro.comm.health import (LADDER, CommFaultError, FaultInjector,
                               HealthMonitor, HealthStats, LinkFaultError)
from repro.comm.passes import AutoSchedule, GraphPass, apply_schedule
from repro.comm.plan import TransferGroup, TransferPlan, TransferRequest
from repro.comm.planner import PathPlanner
from repro.comm.telemetry import (DispatchSample, StageTimings,
                                  TimelineRecorder)
from repro.core.pipelining import validate_plan
from repro.core.topology import HOST, Topology

AXIS = "dev"


@dataclasses.dataclass(frozen=True)
class GroupKey:
    """Graph-cache key for a fused transfer group.

    ``digest`` is the canonical content hash of the lowered
    :class:`~repro.comm.graph.TransferGraph` (nodes + edges + window), so
    the key can never diverge from the program that was actually emitted
    — EVERY message's routes, chunking, and byte ranges contribute (the
    old hand-assembled key once dropped the reverse plan's signature; a
    digest of the whole graph cannot). ``entries`` adds the per-message
    element type/count, which the graph (byte-level) does not carry but
    the traced program shape depends on. The dispatch path canonicalizes
    message order before planning (see :meth:`MultiPathTransfer
    .transfer_group`), so structurally identical groups whose operands
    were merely permuted collide on one entry.

    Captured whole-iteration steps reuse this key: ``digest`` is the
    scheduled heterogeneous graph's digest (compute nodes included) and
    ``entries`` carries the capture signature plus one
    ``(kernel, flops, cost_ns)`` triple per compute node, so the key
    covers compute identity as well as routes.
    """

    digest: str
    entries: tuple   # ((src, dst, nelems, dtype_str), ...) per message
    window: int = 1
    #: Mesh size the program was compiled for: operand shapes/shardings are
    #: (window, num_devices, nelems), so a cache shared by sessions on
    #: different-sized meshes must not serve one mesh's executable to the
    #: other (the graph digest covers routes, not the device axis).
    num_devices: int = 0
    #: True when the program was compiled with operand donation
    #: (``donate_argnums``): a donated executable consumes its operands,
    #: so it must never be served to an AOT caller that reuses arrays
    #: across launches (``compiled_for*`` always compiles undonated).
    donated: bool = False


@dataclasses.dataclass
class _StepEntry:
    """Fast-path entry for a captured whole-iteration step.

    Same shape as :class:`~repro.comm.cache.FastPathEntry` (the front
    cache stores entries opaquely) plus the recording itself (``program``
    — needed to rebuild the SPMD program if the plan cache evicts the
    executable under us) and the step's output buffer ids.
    """

    plans: tuple
    graph: TransferGraph
    digest: str
    key: GroupKey
    compiled: CompiledPlan
    schedule: str
    program: StepCapture
    outputs: tuple


def plan_signature(plan: TransferPlan) -> tuple:
    """Human-readable per-path summary ((links, chunks, bytes), ...).

    Informational/diagnostic — cache keys use the graph digest instead.
    """
    return tuple((p.route.directional_links(), p.num_chunks, p.nbytes)
                 for p in plan.paths)


def group_signature(group: TransferGroup) -> tuple:
    """Per-plan (src, dst, nbytes, plan signature) for the whole group."""
    return tuple((p.src, p.dst, p.nbytes, plan_signature(p))
                 for p in group.plans)


@lru_cache(maxsize=256)
def _scheduled_graph(graph: TransferGraph, schedule: str,
                     topology: Topology,
                     topology_epoch: tuple) -> tuple[TransferGraph, str]:
    """Memoized schedule application for name-addressed schedulers.

    ``lower()`` memoizes the lowering, so steady-state launches replay
    the same graph object; without this cache every cache-hit dispatch
    would re-run the pass AND the full §2.2 contract check. Custom
    :class:`GraphPass` objects bypass the memo (their identity is not a
    stable key). ``topology_epoch`` is part of the key on purpose:
    ``Topology`` hashes by identity, so without it a link mutation
    (``add_link`` on an existing pair changes bandwidths in place) could
    serve a model-weighted scheduler (``critical_path``/``auto``) a
    dispatch order computed from stale link weights.
    """
    return apply_schedule(graph, schedule, topology)


def _check_executable(plan: TransferPlan) -> None:
    for pa in plan.paths:
        for link in pa.route.hops:
            if HOST in (link.src, link.dst):
                # Checked per HOP, not per route.via: a 3-hop detour can
                # stage through the host mid-route while its recorded via
                # is a device — it would otherwise reach ppermute as
                # device id -1.
                raise ValueError(
                    "host-staged path is not executable on the accelerator "
                    "mesh (DESIGN.md §2); plan with include_host=False")


def emit_graph(graph: TransferGraph, xs: Sequence[jax.Array],
               axis_name: str, itemsizes: Sequence[int]) -> list[jax.Array]:
    """Walk graph nodes in topological order, one ``ppermute`` per node.

    ``xs[i]`` is message *i*'s local shard of shape ``(window, 1,
    nelems_i)``; on the source device it holds the message, elsewhere
    contents are ignored. Returns same-shaped arrays holding each message
    on its destination device and zeros elsewhere.

    Dataflow follows the graph's hop edges exactly: a node with no hop
    predecessor slices its chunk from the input, every other node consumes
    its predecessor's ``ppermute`` output, and terminal nodes join into
    the zero-initialized output (the §4.5 "final synchronization").
    """
    outs = [jnp.zeros_like(x) for x in xs]
    preds = graph.hop_predecessor
    terminals = graph.terminal_nodes
    values: dict[int, jax.Array] = {}
    for idx in graph.topological_order():
        node = graph.nodes[idx]
        isz = itemsizes[node.msg_idx]
        if node.offset % isz or node.nbytes % isz:
            raise ValueError("chunk bounds not element-aligned; pass "
                             "granularity=itemsize to planner.plan()")
        off_e, size_e = node.offset // isz, node.nbytes // isz
        pred = preds.get(idx)
        if pred is None:
            chunk = jax.lax.slice(
                xs[node.msg_idx],
                (node.window, 0, off_e),
                (node.window + 1, 1, off_e + size_e))
        else:
            chunk = values.pop(pred)
        chunk = jax.lax.ppermute(chunk, axis_name, [node.link])
        if idx in terminals:
            outs[node.msg_idx] = jax.lax.dynamic_update_slice(
                outs[node.msg_idx], chunk, (node.window, 0, off_e))
        else:
            values[idx] = chunk
    return outs


def multipath_send_local(x: jax.Array, plan: TransferPlan, *,
                         axis_name: str = AXIS,
                         itemsize: int | None = None,
                         schedule: str | GraphPass = "round_robin",
                         topology: Topology | None = None) -> jax.Array:
    """Execute a plan *inside* a ``shard_map`` program.

    ``x`` is the local shard, shape ``(1, nelems)``; on the source device it
    holds the message, elsewhere contents are ignored. Returns an array of
    the same shape that holds the message on the destination device and
    zeros elsewhere. One ``ppermute`` per graph copy node, dispatched in
    the order the ``schedule`` pass (§2.2) produces. Pass ``topology``
    alongside a model-weighted scheduler (``"critical_path"``,
    ``"auto"``) to get the same dispatch order the engine derives for
    that name; without it, ``"critical_path"`` degrades to uniform
    raw-byte weights and ``"auto"`` raises.
    """
    _check_executable(plan)
    itemsize = itemsize or x.dtype.itemsize
    graph, _ = apply_schedule(lower(plan), schedule, topology)
    (out,) = emit_graph(graph, (x[None],), axis_name, (itemsize,))
    return out[0]


class MultiPathTransfer:
    """Build, cache, and launch compiled multi-path transfer programs."""

    def __init__(self, mesh: jax.sharding.Mesh | None = None, *,
                 topology: Topology | None = None,
                 planner: PathPlanner | None = None,
                 cache: TransferPlanCache | None = None,
                 schedule: str | GraphPass = "round_robin",
                 fastpath: bool | None = None,
                 validate: str | None = None,
                 fastpath_cache: FastPathCache | None = None,
                 telemetry: TimelineRecorder | None = None,
                 monitor: HealthMonitor | None = None,
                 faults: FaultInjector | None = None,
                 retry_limit: int = 2,
                 backoff_base_s: float = 0.001):
        if mesh is None:
            devs = jax.devices()
            mesh = jax.sharding.Mesh(devs, (AXIS,))
        self.mesh = mesh
        self.axis_name = mesh.axis_names[0]
        self.num_devices = mesh.devices.size
        if topology is None:
            topology = Topology.full_mesh(self.num_devices, with_host=True)
        self.topology = topology
        # `if ... is None` (not `or`): an *empty* TransferPlanCache is falsy
        # via __len__, and `or` would silently replace a caller's cache.
        self.planner = planner if planner is not None else PathPlanner(
            topology)
        self.cache = cache if cache is not None else TransferPlanCache()
        #: Default chunk-interleaving scheduler (DESIGN.md §2.2) applied
        #: to every lowering between ``lower()`` and the emitter; every
        #: public entry point takes a per-call ``schedule=`` override.
        self.schedule = schedule
        #: Steady-state dispatch fast path (DESIGN.md §2.3): memoize the
        #: whole plan→lower→schedule→digest resolution per request
        #: signature so repeat traffic is one dict lookup + staging +
        #: launch. ``REPRO_MP_FASTPATH=0`` (or ``fastpath=False``) turns
        #: it off; every dispatch then re-runs the full pipeline.
        self.fastpath = (_env_bool("REPRO_MP_FASTPATH", True)
                         if fastpath is None else fastpath)
        #: ``"miss"`` (default) validates plans/graphs only when they are
        #: (re)built; ``"always"`` re-validates on every dispatch, fast-
        #: path hits included (§4.5 safety escape hatch).
        self.validate = (os.environ.get("REPRO_MP_VALIDATE", "miss")
                         if validate is None else validate)
        if self.validate not in VALIDATE_MODES:
            raise ValueError(f"unknown validate mode {self.validate!r}; "
                             f"expected one of {VALIDATE_MODES}")
        self._fastpath = (fastpath_cache if fastpath_cache is not None
                          else FastPathCache())
        #: Optional dispatch-timeline recorder (DESIGN §4.4c). ``None``
        #: or a disabled recorder keeps the dispatch path at one boolean
        #: check — the zero-overhead-off telemetry contract.
        self.telemetry = telemetry
        # Per-dispatch telemetry carried from _resolve to _launch (the
        # two halves of one dispatch; the engine is not thread-safe and
        # never was — same invariant as the staging pool).
        self._pending_stages: StageTimings | None = None
        self._pending_hit = False
        #: Pooled staging programs keyed on (window, nelems, dtype, src):
        #: each one holds a zero operand template (device_put once) and a
        #: compiled write of the message into the src row — per-launch
        #: staging is ONE fused kernel instead of zeros + scatter +
        #: resharding of a fresh (window, ndev, nelems) array. LRU-bounded
        #: to the fast-path capacity: every entry pins a device-resident
        #: template, so the pool must not grow without bound under
        #: many-distinct-size traffic.
        self._staging: OrderedDict[tuple, object] = OrderedDict()
        #: Cumulative nanoseconds spent *dispatching* the staging kernels
        #: across every launch (host-side enqueue; per-executable totals
        #: in `PlanLifecycle.staging_ns`). Staging execution overlaps the
        #: launch — the compiled program consumes the staged operands
        #: through dataflow — so it lands in the launch timings, not here.
        self.staging_ns = 0
        # Operand donation lets XLA reuse staging buffers launch-to-launch
        # (paper: graph replay over the same buffers). The CPU backend
        # ignores donation (with a warning), so only enable it where it
        # takes effect; donated programs are keyed apart (GroupKey.donated)
        # from the undonated AOT handles `compiled_for*` returns.
        self._donate = jax.default_backend() not in ("cpu",)
        #: Concrete schedule name → dispatch/compile calls resolved to it
        #: (``auto`` counts as the candidate it picked; cache hits and
        #: memoized pass applications included). Surfaced via
        #: ``session.stats()``.
        self.schedule_counts: dict[str, int] = {}
        self._sharding = NamedSharding(mesh, P(None, self.axis_name))
        #: Number of compiled-program launches issued (one per transfer or
        #: per fused group — the paper's "one cudaGraphLaunch" count).
        self.dispatches = 0
        #: Copy nodes / dependency edges across every graph this engine
        #: compiled (cache misses only) — `session.stats()` surfaces them.
        #: `copy_nodes_compiled`/`compute_nodes_compiled` break the node
        #: total down by kind (heterogeneous captured-step graphs carry
        #: both); `nodes_compiled` stays the total of the two.
        self.nodes_compiled = 0
        self.edges_compiled = 0
        self.copy_nodes_compiled = 0
        self.compute_nodes_compiled = 0
        #: Degraded-mode accounting (DESIGN §4.6): retries/replans/ladder
        #: level, surfaced as the ``health`` stats section. Always
        #: present so counters exist whether or not a monitor is wired.
        self.health = HealthStats()
        #: Optional telemetry-driven link health monitor; when attached,
        #: dispatch faults quarantine through it (events logged) and the
        #: degraded loop probes quarantined links on its cadence.
        self.monitor = monitor
        #: Optional deterministic chaos injector (``REPRO_MP_FAULTS``);
        #: fires before each dispatch resolves so epoch bumps always
        #: precede planning — no stale executable survives an injection.
        self.faults = faults
        #: Retries per degradation-ladder rung before escalating, and
        #: the bounded exponential backoff base between them (§4.6).
        self.retry_limit = retry_limit
        self.backoff_base_s = backoff_base_s

    # -- planning -----------------------------------------------------------
    def plan_for(self, src: int, dst: int, nelems: int, dtype=jnp.float32,
                 **plan_kwargs) -> TransferPlan:
        itemsize = jnp.dtype(dtype).itemsize
        plan = self.planner.plan(src, dst, nelems * itemsize,
                                 granularity=itemsize,
                                 include_host=plan_kwargs.pop(
                                     "include_host", False),
                                 **plan_kwargs)
        validate_plan(plan)
        return plan

    def plan_group_for(self, specs: Sequence[tuple], *,
                       max_paths: int | None = None,
                       num_chunks: int | None = None,
                       exclusive: bool = False) -> TransferGroup:
        """Jointly plan executable messages; ``specs`` holds one
        ``(src, dst, nelems, dtype)`` tuple per message. Host paths are
        never admitted (they are not executable on the accelerator mesh).
        """
        requests = []
        for (src, dst, nelems, dtype) in specs:
            itemsize = jnp.dtype(dtype).itemsize
            requests.append(TransferRequest(src, dst, nelems * itemsize,
                                            granularity=itemsize))
        group = self.planner.plan_group(requests, max_paths=max_paths,
                                        include_host=False,
                                        num_chunks=num_chunks,
                                        exclusive=exclusive)
        for plan in group.plans:
            validate_plan(plan)
            _check_executable(plan)
        return group

    # -- program construction -----------------------------------------------
    def _group_graph(self, plans: Sequence[TransferPlan], window: int,
                     schedule: str | GraphPass | None = None,
                     stages: StageTimings | None = None
                     ) -> tuple[TransferGraph, str]:
        """Lower the fused group and run the scheduler pass (§2.2).

        Returns the SCHEDULED graph — the one the program is emitted
        from AND the one ``_group_key`` digests, so the cache key always
        incorporates the post-pass dispatch order (two schedules of one
        plan get distinct entries and can never cross-serve
        executables) — plus the concrete schedule name that was chosen.
        The emitter owns no ordering of its own. ``stages`` (telemetry
        only) receives the lower/schedule wall-time attribution.
        """
        for p in plans:
            _check_executable(p)
        t0 = time.perf_counter_ns()
        graph = lower(TransferGroup(tuple(plans), self.topology.name),
                      window)
        t1 = time.perf_counter_ns()
        sched = self.schedule if schedule is None else schedule
        if isinstance(sched, str):
            out = _scheduled_graph(graph, sched, self.topology,
                                   self.topology.epoch)
        else:
            out = apply_schedule(graph, sched, self.topology)
        if stages is not None:
            stages.lower_ns = t1 - t0
            stages.schedule_ns = time.perf_counter_ns() - t1
        return out

    def _count_schedule(self, chosen: str) -> None:
        self.schedule_counts[chosen] = self.schedule_counts.get(chosen,
                                                                0) + 1

    def _build_group_fn(self, graph: TransferGraph,
                        itemsizes: Sequence[int]):
        """Fused SPMD program: the graph's copy nodes, one trace."""
        ax = self.axis_name

        def local_body(*xs):  # x_i local: (window, 1, nelems_i)
            return tuple(emit_graph(graph, xs, ax, itemsizes))

        specs = tuple(P(None, ax) for _ in itemsizes)
        return shard_map(local_body, mesh=self.mesh,
                         in_specs=specs, out_specs=specs, check_vma=False)

    def _compile_group(self, key: GroupKey, graph: TransferGraph,
                       shapes: Sequence[tuple[int, object]]) -> CompiledPlan:
        abstracts = tuple(
            jax.ShapeDtypeStruct((key.window, self.num_devices, nelems),
                                 dtype, sharding=self._sharding)
            for nelems, dtype in shapes)
        itemsizes = tuple(jnp.dtype(dtype).itemsize for _, dtype in shapes)
        fn = self._build_group_fn(graph, itemsizes)
        self.nodes_compiled += graph.num_nodes
        self.edges_compiled += graph.num_edges
        self.copy_nodes_compiled += graph.num_copy_nodes
        self.compute_nodes_compiled += graph.num_compute_nodes
        jit_kwargs = {}
        if key.donated:
            # XLA reuses the staged operand buffers for the outputs
            # launch-to-launch (the paper's graph replay over one buffer
            # set); safe because the dispatch path rebuilds operands
            # every launch and never touches them again.
            jit_kwargs["donate_argnums"] = tuple(range(len(shapes)))
        return compile_plan(key, fn, abstracts, num_nodes=graph.num_nodes,
                            **jit_kwargs)

    def _group_key(self, graph: TransferGraph, plans: Sequence[TransferPlan],
                   shapes: Sequence[tuple[int, object]], window: int,
                   donated: bool = False) -> GroupKey:
        entries = tuple(
            (p.src, p.dst, nelems, str(jnp.dtype(dtype)))
            for p, (nelems, dtype) in zip(plans, shapes))
        return GroupKey(graph.digest(), entries, window, self.num_devices,
                        donated)

    # -- steady-state dispatch (DESIGN.md §2.3) -----------------------------
    def _request_signature(self, mode: str, specs: Sequence[tuple],
                           window: int, schedule: str,
                           max_paths: int | None, num_chunks: int | None,
                           exclusive: bool) -> tuple:
        """Request identity for the fast path: everything that determines
        the resolved plans + program BESIDES planner/topology state
        (which the epoch stamp covers). ``mode`` separates single-message
        planning (``plan``) from joint group planning (``plan_group``) —
        the two can legitimately resolve the same spec differently.
        """
        return (mode,
                tuple((src, dst, nelems, str(jnp.dtype(dtype)))
                      for src, dst, nelems, dtype in specs),
                window, schedule, max_paths, num_chunks, exclusive,
                self.num_devices)

    def _stage_fn(self, window: int, nelems: int, dtype, src: int):
        """Pooled staging program for one (window, nelems, dtype, src) key.

        Holds a zero operand template — device_put across the mesh ONCE —
        and a compiled write of the message into the src row, so per-
        launch staging is one fused kernel producing the sharded
        ``(window, ndev, nelems)`` operand instead of a fresh zero-fill +
        scatter + resharding of the whole array (the old per-launch
        O(window·ndev·nelems) host-side cost).
        """
        key = (window, nelems, str(jnp.dtype(dtype)), src)
        fn = self._staging.get(key)
        if fn is None:
            zeros = jax.device_put(
                jnp.zeros((window, self.num_devices, nelems), dtype),
                self._sharding)

            def stage(m, _zeros=zeros):
                return _zeros.at[:, src].set(m)

            fn = jax.jit(stage, out_shardings=self._sharding)
            # Warm the staging executable once at pool-insertion time so
            # steady-state `staging_ns` measures operand builds, not the
            # one-time jit compile (that is first-dispatch setup cost).
            jax.block_until_ready(fn(jnp.zeros((nelems,), dtype)))
            self._staging[key] = fn
            if len(self._staging) > self._fastpath.capacity:
                self._staging.popitem(last=False)
        else:
            self._staging.move_to_end(key)
        return fn

    def _launch(self, entry: FastPathEntry, messages: Sequence[jax.Array],
                *, block: bool) -> list[jax.Array]:
        """Stage operands (pooled) and launch the compiled program ONCE.

        When telemetry is enabled the launch is split into dispatch vs
        execute (``CompiledPlan.timed_call``) and the finished
        :class:`~repro.comm.telemetry.StageTimings` is recorded as one
        :class:`~repro.comm.telemetry.DispatchSample`; lifecycle
        accounting is identical either way.
        """
        stages, hit = self._pending_stages, self._pending_hit
        self._pending_stages, self._pending_hit = None, False
        window = entry.graph.window
        stagers = [self._stage_fn(window, m.shape[0], m.dtype, p.src)
                   for m, p in zip(messages, entry.plans)]
        t0 = time.perf_counter_ns()
        xs = [stage(m) for stage, m in zip(stagers, messages)]
        staging = time.perf_counter_ns() - t0
        self.staging_ns += staging
        compiled = entry.compiled
        compiled.lifecycle.staging_ns += staging
        if stages is None:
            ys = compiled(*xs) if block else compiled.dispatch(*xs)
        else:
            stages.staging_ns = staging
            if block:
                ys, stages.launch_ns, stages.execute_ns = (
                    compiled.timed_call(*xs))
            else:
                t1 = time.perf_counter_ns()
                ys = compiled.dispatch(*xs)
                stages.launch_ns = time.perf_counter_ns() - t1
            routes = tuple(
                tuple((pa.route.directional_links(), pa.nbytes,
                       pa.num_chunks) for pa in p.paths)
                for p in entry.plans)
            self.telemetry.record(DispatchSample(
                routes=routes,
                nbytes=sum(p.nbytes for p in entry.plans),
                num_nodes=entry.graph.num_nodes, window=window,
                schedule=entry.schedule, stages=stages,
                fastpath_hit=hit))
        self.dispatches += 1
        return [y[0, p.dst] for y, p in zip(ys, entry.plans)]

    def _resolve(self, specs: Sequence[tuple], *, window: int,
                 max_paths: int | None, num_chunks: int | None,
                 exclusive: bool, schedule: str | GraphPass | None,
                 single: bool) -> FastPathEntry:
        """Resolve a request to a launchable :class:`FastPathEntry`.

        Fast path (hit): one dict lookup against the epoch-stamped
        :class:`FastPathCache` — planner, ``lower()``, scheduler pass,
        validation, and digest are all skipped; the plan cache is still
        consulted by stored key so LRU stats/recency stay coherent (and
        an evicted executable is recompiled from the memoized graph
        without re-planning). Slow path (miss): the full pipeline, then
        the resolution is memoized under the current planner epoch.
        Custom :class:`GraphPass` objects bypass the fast path — their
        identity is not a stable signature.
        """
        sched = self.schedule if schedule is None else schedule
        sched_name = sched if isinstance(sched, str) else None
        use_fast = self.fastpath and sched_name is not None
        tel = self.telemetry
        stages = (StageTimings() if tel is not None and tel.enabled
                  else None)
        self._pending_stages, self._pending_hit = stages, False
        shapes = [(nelems, jnp.dtype(dtype))
                  for (_, _, nelems, dtype) in specs]
        sig = epoch = None
        if use_fast:
            sig = self._request_signature(
                "plan" if single else "plan_group", specs, window,
                sched_name, max_paths, num_chunks, exclusive)
            epoch = self.planner.epoch
            entry = self._fastpath.get(sig, epoch)
            if entry is not None:
                compiled = self.cache.get(entry.key)
                if compiled is None:   # evicted under us: recompile only
                    compiled = self._compile_group(entry.key, entry.graph,
                                                   shapes)
                    self.cache.put(entry.key, compiled)
                    if stages is not None:
                        stages.compile_ns = compiled.lifecycle.build_ns
                entry.compiled = compiled
                if self.validate == "always":
                    for p in entry.plans:
                        validate_plan(p)
                    entry.graph.validate(
                        {i: p.nbytes for i, p in enumerate(entry.plans)},
                        cross_flow_exclusive=False)
                compiled.lifecycle.fastpath_hits += 1
                self._count_schedule(entry.schedule)
                self._pending_hit = True
                return entry
        t0 = time.perf_counter_ns()
        if single:
            (src, dst, nelems, dtype) = specs[0]
            plans: tuple[TransferPlan, ...] = (self.plan_for(
                src, dst, nelems, dtype, max_paths=max_paths,
                num_chunks=num_chunks),)
        else:
            plans = self.plan_group_for(specs, max_paths=max_paths,
                                        num_chunks=num_chunks,
                                        exclusive=exclusive).plans
        if stages is not None:
            stages.plan_ns = time.perf_counter_ns() - t0
        graph, chosen = self._group_graph(plans, window, sched,
                                          stages=stages)
        self._count_schedule(chosen)
        key = self._group_key(graph, plans, shapes, window,
                              donated=self._donate)
        built: list[CompiledPlan] = []

        def _builder() -> CompiledPlan:
            c = self._compile_group(key, graph, shapes)
            built.append(c)
            return c

        compiled = self.cache.get_or_build(key, _builder)
        if stages is not None and built:
            stages.compile_ns = compiled.lifecycle.build_ns
        entry = FastPathEntry(plans=tuple(plans), graph=graph,
                              digest=key.digest, key=key,
                              compiled=compiled, schedule=chosen)
        if use_fast:
            self._fastpath.put(sig, epoch, entry)
        return entry

    # -- whole-iteration capture (heterogeneous graphs) ---------------------
    def capture(self, build_fn, *, schedule: str | None = None
                ) -> CapturedStep:
        """Record one iteration and return a launchable
        :class:`~repro.comm.capture.CapturedStep`.

        ``build_fn(cap)`` declares the step against a fresh
        :class:`~repro.comm.capture.StepCapture` and returns the output
        ref(s). Nothing is planned or compiled here — resolution happens
        on first launch (or :meth:`CapturedStep.resolve`) and is
        memoized on the fast path.
        """
        cap = StepCapture()
        outputs = build_fn(cap)
        if not isinstance(outputs, (tuple, list)):
            outputs = (outputs,)
        return CapturedStep(self, cap, tuple(outputs), schedule=schedule)

    def _build_step_fn(self, program: StepCapture, graph: TransferGraph,
                       outputs: tuple):
        """Fused whole-iteration SPMD program: the SCHEDULED graph's copy
        AND compute nodes, one trace. Each kernel is wrapped in an inner
        ``jax.jit`` named ``capk_<kernel>`` so traced kernel calls are
        countable in the jaxpr exactly like ``ppermute`` eqns — the
        one-launch acceptance check."""
        ax = self.axis_name
        buffers = tuple(program.buffers)
        input_ids = tuple(program.inputs)
        wrapped = {}
        for kname, fn in program.kernels.items():
            def _impl(*args, _fn=fn):
                return _fn(*args)
            _impl.__name__ = "capk_" + re.sub(r"\W", "_", kname)
            wrapped[kname] = jax.jit(_impl)

        def local_body(*xs):
            values = {}
            for bid, x in zip(input_ids, xs):
                values[bid] = x if buffers[bid].replicated else x[0]
            values = emit_step(graph, buffers, wrapped, values, ax)
            return tuple(values[o][None] for o in outputs)

        in_specs = tuple(P() if buffers[b].replicated else P(ax)
                         for b in input_ids)
        out_specs = tuple(P(ax) for _ in outputs)
        return shard_map(local_body, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    def _step_abstracts(self, program: StepCapture) -> tuple:
        abstracts = []
        for bid in program.inputs:
            spec = program.buffers[bid]
            dtype = jnp.dtype(spec.dtype)
            if spec.replicated:
                abstracts.append(jax.ShapeDtypeStruct(
                    spec.shape, dtype,
                    sharding=NamedSharding(self.mesh, P())))
            else:
                abstracts.append(jax.ShapeDtypeStruct(
                    (self.num_devices,) + spec.shape, dtype,
                    sharding=NamedSharding(self.mesh, P(self.axis_name))))
        return tuple(abstracts)

    def _compile_step(self, key: GroupKey, graph: TransferGraph,
                      program: StepCapture, outputs: tuple) -> CompiledPlan:
        """Compile one captured step (never donated: callers legitimately
        reuse input arrays, e.g. re-running a step on the same batch)."""
        fn = self._build_step_fn(program, graph, outputs)
        self.nodes_compiled += graph.num_nodes
        self.edges_compiled += graph.num_edges
        self.copy_nodes_compiled += graph.num_copy_nodes
        self.compute_nodes_compiled += graph.num_compute_nodes
        return compile_plan(key, fn, self._step_abstracts(program),
                            num_nodes=graph.num_nodes)

    def resolve_step(self, step: CapturedStep,
                     schedule: str | GraphPass | None = None) -> _StepEntry:
        """Resolve a captured step to a launchable entry.

        Mirrors :meth:`_resolve`: fast-path hit is one dict lookup
        keyed on (capture signature, outputs, schedule name, mesh size)
        under the planner epoch; miss runs lower_step → scheduler pass →
        §4.5 validation (inside lowering) → compile, keyed on the
        scheduled graph digest + capture signature + per-kernel compute
        identity, then memoizes. Two schedules of the same capture
        digest apart and never cross-serve executables.
        """
        program = step.capture
        sched = self.schedule if schedule is None else schedule
        sched_name = sched if isinstance(sched, str) else None
        use_fast = self.fastpath and sched_name is not None
        tel = self.telemetry
        stages = (StageTimings() if tel is not None and tel.enabled
                  else None)
        self._pending_stages, self._pending_hit = stages, False
        sig = epoch = None
        if use_fast:
            sig = ("capture_step", program.signature(), step.outputs,
                   sched_name, self.num_devices)
            epoch = self.planner.epoch
            entry = self._fastpath.get(sig, epoch)
            if entry is not None:
                compiled = self.cache.get(entry.key)
                if compiled is None:   # evicted under us: recompile only
                    compiled = self._compile_step(
                        entry.key, entry.graph, entry.program,
                        entry.outputs)
                    self.cache.put(entry.key, compiled)
                    if stages is not None:
                        stages.compile_ns = compiled.lifecycle.build_ns
                entry.compiled = compiled
                if self.validate == "always":
                    for p in entry.plans:
                        validate_plan(p)
                    entry.graph.validate(
                        {i: p.nbytes for i, p in enumerate(entry.plans)},
                        cross_flow_exclusive=False)
                compiled.lifecycle.fastpath_hits += 1
                self._count_schedule(entry.schedule)
                self._pending_hit = True
                return entry
        t0 = time.perf_counter_ns()
        graph, plans = lower_step(program, self.plan_group_for,
                                  self.topology.name)
        t1 = time.perf_counter_ns()
        scheduled, chosen = apply_schedule(graph, sched, self.topology)
        if stages is not None:
            stages.lower_ns = t1 - t0
            stages.schedule_ns = time.perf_counter_ns() - t1
        self._count_schedule(chosen)
        compute_id = tuple((n.kernel, n.flops, n.cost_ns)
                           for n in scheduled.nodes
                           if isinstance(n, ComputeNode))
        key = GroupKey(scheduled.digest(),
                       entries=(program.signature(), step.outputs)
                       + compute_id,
                       window=1, num_devices=self.num_devices)
        built: list[CompiledPlan] = []

        def _builder() -> CompiledPlan:
            c = self._compile_step(key, scheduled, program, step.outputs)
            built.append(c)
            return c

        compiled = self.cache.get_or_build(key, _builder)
        if stages is not None and built:
            stages.compile_ns = compiled.lifecycle.build_ns
        entry = _StepEntry(plans=plans, graph=scheduled, digest=key.digest,
                           key=key, compiled=compiled, schedule=chosen,
                           program=program, outputs=step.outputs)
        if use_fast:
            self._fastpath.put(sig, epoch, entry)
        return entry

    def _launch_step(self, entry: _StepEntry, arrays: Sequence[jax.Array],
                     *, block: bool) -> list[jax.Array]:
        """Stage the step inputs (device_put onto the declared shardings;
        staging a whole iteration's operands is dominated by the step
        itself, so inputs are not pooled like message staging) and launch
        the compiled whole-iteration program ONCE."""
        stages, hit = self._pending_stages, self._pending_hit
        self._pending_stages, self._pending_hit = None, False
        program = entry.program
        if len(arrays) != len(program.inputs):
            raise ValueError(f"captured step takes {len(program.inputs)} "
                             f"input arrays, got {len(arrays)}")
        t0 = time.perf_counter_ns()
        xs = []
        for bid, arr in zip(program.inputs, arrays):
            spec = program.buffers[bid]
            arr = jnp.asarray(arr, jnp.dtype(spec.dtype))
            want = (spec.shape if spec.replicated
                    else (self.num_devices,) + spec.shape)
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"input for buffer {bid} must have shape {want} "
                    f"({'replicated' if spec.replicated else 'sharded'}), "
                    f"got {tuple(arr.shape)}")
            sh = NamedSharding(self.mesh, P() if spec.replicated
                               else P(self.axis_name))
            xs.append(jax.device_put(arr, sh))
        staging = time.perf_counter_ns() - t0
        self.staging_ns += staging
        compiled = entry.compiled
        compiled.lifecycle.staging_ns += staging
        if stages is None:
            ys = compiled(*xs) if block else compiled.dispatch(*xs)
        else:
            stages.staging_ns = staging
            if block:
                ys, stages.launch_ns, stages.execute_ns = (
                    compiled.timed_call(*xs))
            else:
                t1 = time.perf_counter_ns()
                ys = compiled.dispatch(*xs)
                stages.launch_ns = time.perf_counter_ns() - t1
            routes = tuple(
                tuple((pa.route.directional_links(), pa.nbytes,
                       pa.num_chunks) for pa in p.paths)
                for p in entry.plans)
            compute = tuple((n.kernel, n.flops, n.cost_ns)
                            for n in entry.graph.nodes
                            if isinstance(n, ComputeNode))
            self.telemetry.record(DispatchSample(
                routes=routes,
                nbytes=sum(p.nbytes for p in entry.plans),
                num_nodes=entry.graph.num_nodes, window=1,
                schedule=entry.schedule, stages=stages,
                fastpath_hit=hit, compute=compute))
        self.dispatches += 1
        return list(ys)

    def run_step(self, step: CapturedStep, arrays: Sequence[jax.Array], *,
                 schedule: str | GraphPass | None = None,
                 block: bool = True) -> list[jax.Array]:
        """Resolve + launch one captured iteration as ONE dispatch.

        Returns the step outputs device-stacked ``(num_devices,
        *local_shape)``, aligned with the capture's declared outputs.

        Under fault state (§4.6 hazard: live injector, quarantined or
        failed links) the captured step retries with bounded backoff —
        each :class:`~repro.comm.health.LinkFaultError` quarantines the
        blamed links so the re-resolve re-plans over surviving routes
        (``plan_group_for`` naturally narrows the path set; there is no
        host rung for captured steps). Exhaustion raises
        :class:`~repro.comm.health.CommFaultError` with the attempt
        history; the healthy path is byte-identical to before.
        """
        if self.faults is not None:
            self.faults.on_dispatch(self)
        if not self._hazard():
            entry = self.resolve_step(step, schedule)
            return self._launch_step(entry, arrays, block=block)
        hs = self.health
        delay = self.backoff_base_s
        history: list[str] = []
        for attempt in range(self.retry_limit + 2):
            if attempt:
                hs.replans += 1
            try:
                entry = self.resolve_step(step, schedule)
                self._fault_check(entry)
                out = self._launch_step(entry, arrays, block=block)
                level = self._steady_rung(0)
                if hs.ladder_level != level:
                    hs.note("ladder", level=level, rung=LADDER[level],
                            dispatch=self.dispatches)
                hs.ladder_level = level
                if self.monitor is not None:
                    self.monitor.maybe_probe(self)
                return out
            except LinkFaultError as exc:
                history.append(f"step: {exc}")
                self._note_fault(exc, 1)
                if delay > 0:
                    time.sleep(delay)
                    delay = min(delay * 2, 0.05)
            except ValueError as exc:
                history.append(f"step: {exc}")
                raise CommFaultError(
                    f"captured-step ladder exhausted: {exc}",
                    history) from exc
        raise CommFaultError(
            "captured-step dispatch failed after retries", history)

    # -- degraded-mode dispatch (DESIGN §4.6) -------------------------------
    def _hazard(self) -> bool:
        """True while any fault state can affect dispatch: a live
        injector, quarantined links, or failed topology links. The
        healthy path costs exactly these boolean reads — the §4.6
        zero-overhead-off contract."""
        return ((self.faults is not None and self.faults.active)
                or bool(self.planner.quarantined)
                or bool(self.topology.failed_links))

    def _fault_check(self, entry) -> None:
        """Validate a resolved entry against the live fault state.

        Raises :class:`~repro.comm.health.LinkFaultError` when the entry
        still routes over a failed or quarantined link (a fault landed
        between resolve and launch) or when the injector's active drop
        window blames one of the entry's links — the §4.6 invariant that
        no launch is ever issued onto a link known to be down.
        """
        links = tuple({link for p in entry.plans
                       for link in p.directional_links()})
        failed = self.topology.failed_links
        quarantined = self.planner.quarantined
        bad = [link for link in links
               if link in failed or link in quarantined]
        if bad:
            raise LinkFaultError(bad, "entry routes over faulted links")
        if self.faults is not None:
            link = self.faults.dropped_link(self.dispatches, links)
            if link is not None:
                raise LinkFaultError((link,), "injected dispatch drop")

    def _note_fault(self, exc: LinkFaultError, rung: int) -> None:
        """Account one failed attempt: bump the retry counter, log the
        event, and quarantine the blamed links (through the monitor when
        attached, so the event stream stays unified) — the epoch bump
        this causes is what makes the following re-resolve a re-plan
        over surviving links."""
        hs = self.health
        hs.retries += 1
        hs.note("retry", rung=LADDER[min(rung, len(LADDER) - 1)],
                links=list(exc.links), reason=exc.reason,
                dispatch=self.dispatches)
        for link in exc.links:
            if link in self.topology.failed_links:
                continue  # physically gone; quarantine is for suspects
            if self.monitor is not None:
                self.monitor.quarantine_link(link, reason=exc.reason,
                                             dispatch=self.dispatches)
            else:
                self.planner.quarantine(link)

    def _steady_rung(self, rung: int) -> int:
        """The :data:`~repro.comm.health.LADDER` level to record for a
        successful dispatch at ``rung``: multipath rungs report
        ``surviving_multipath`` whenever fault state constrained the
        route set (the invariant that ``ladder_level == 0`` means the
        full healthy plan)."""
        if rung >= 2:
            return rung
        if self.planner.quarantined or self.topology.failed_links:
            return 1
        return 0

    def _host_relay(self, specs: Sequence[tuple],
                    messages: Sequence[jax.Array],
                    history: Sequence[str]) -> list[jax.Array]:
        """Last ladder rung: deliver each message through a host (PCIe)
        round-trip — a device_get/device_put staging relay, the
        executable adaptation of the paper's host-staged path.

        Delivery over bandwidth: payloads arrive intact (the §4.5
        integrity contract still holds) at host-link speed, outside the
        compiled graph. Requires nominal host links on both endpoints;
        raises :class:`~repro.comm.health.CommFaultError` (the ladder is
        exhausted) when any message lacks them.
        """
        topo = self.topology
        for (src, dst, _, _) in specs:
            if (topo.link(src, HOST) is None
                    or topo.link(HOST, dst) is None):
                raise CommFaultError(
                    f"degradation ladder exhausted for {src}->{dst}: no "
                    f"surviving device route and no host-staged route",
                    history)
        outs = []
        for (_, _, _, dtype), m in zip(specs, messages):
            staged = jax.device_get(m)           # PCIe pull to host
            outs.append(jnp.asarray(staged, dtype))  # PCIe push to dst
        hs = self.health
        hs.host_relays += 1
        hs.ladder_level = 3
        hs.note("host_relay", messages=len(specs),
                dispatch=self.dispatches)
        self.dispatches += 1
        return outs

    def _dispatch(self, specs: Sequence[tuple],
                  messages: Sequence[jax.Array], *, window: int,
                  max_paths: int | None, num_chunks: int | None,
                  exclusive: bool, schedule: str | GraphPass | None,
                  single: bool, block: bool) -> list[jax.Array]:
        """Resolve + launch one request, degradation-aware (§4.6).

        Healthy state (no injector activity, no quarantine, no failed
        links) is the unchanged fast path: resolve, launch, done —
        exceptions propagate exactly as before, preserving every
        caller-visible contract (e.g. ``exclusive=True`` starvation
        raises). Under fault state the request walks
        :data:`~repro.comm.health.LADDER` instead.
        """
        if self.faults is not None:
            self.faults.on_dispatch(self)
        if not self._hazard():
            hs = self.health
            if hs.ladder_level:
                hs.ladder_level = 0  # fully recovered
            entry = self._resolve(specs, window=window,
                                  max_paths=max_paths,
                                  num_chunks=num_chunks,
                                  exclusive=exclusive, schedule=schedule,
                                  single=single)
            return self._launch(entry, messages, block=block)
        return self._dispatch_degraded(
            specs, messages, window=window, max_paths=max_paths,
            num_chunks=num_chunks, exclusive=exclusive, schedule=schedule,
            single=single, block=block)

    def _dispatch_degraded(self, specs: Sequence[tuple],
                           messages: Sequence[jax.Array], *, window: int,
                           max_paths: int | None, num_chunks: int | None,
                           exclusive: bool,
                           schedule: str | GraphPass | None,
                           single: bool, block: bool) -> list[jax.Array]:
        """Walk the §4.6 degradation ladder until the request delivers.

        Rung 0 resolves the request as asked; each
        :class:`~repro.comm.health.LinkFaultError` quarantines the
        blamed links (an epoch bump — the next resolve IS a re-plan over
        surviving links), sleeps the bounded exponential backoff, and
        retries up to ``retry_limit`` times per rung. A rung with no
        admissible route (planner ``ValueError``) escalates immediately:
        surviving multipath → single best path → host-staged relay.
        Degraded rungs drop the ``exclusive`` guarantee (delivery over
        exclusivity — documented in DESIGN §4.6); every launched plan
        still passes the same §4.5 validation as healthy traffic. Only
        when every rung is exhausted does
        :class:`~repro.comm.health.CommFaultError` reach the caller.
        """
        hs = self.health
        delay = self.backoff_base_s
        history: list[str] = []
        failed_once = False
        rungs = ((0, max_paths, 1),
                 (1, max_paths, self.retry_limit + 1),
                 (2, 1, self.retry_limit + 1))
        for rung, rung_paths, attempts in rungs:
            for _ in range(attempts):
                if failed_once:
                    hs.replans += 1
                try:
                    entry = self._resolve(
                        specs, window=window, max_paths=rung_paths,
                        num_chunks=num_chunks,
                        exclusive=exclusive and rung == 0,
                        schedule=schedule, single=single)
                    self._fault_check(entry)
                    out = self._launch(entry, messages, block=block)
                    level = self._steady_rung(rung)
                    if hs.ladder_level != level:
                        hs.note("ladder", level=level,
                                rung=LADDER[level],
                                dispatch=self.dispatches)
                    hs.ladder_level = level
                    if self.monitor is not None:
                        self.monitor.maybe_probe(self)
                    return out
                except LinkFaultError as exc:
                    failed_once = True
                    history.append(f"{LADDER[rung]}: {exc}")
                    entry.compiled.lifecycle.retries += 1
                    self._note_fault(exc, rung)
                    if delay > 0:
                        time.sleep(delay)
                        delay = min(delay * 2, 0.05)
                except ValueError as exc:
                    failed_once = True
                    history.append(f"{LADDER[rung]}: {exc}")
                    break  # no admissible route at this rung: escalate
        return self._host_relay(specs, messages, history)

    # -- public API ---------------------------------------------------------
    def transfer(self, message: jax.Array, src: int, dst: int, *,
                 window: int = 1, max_paths: int | None = None,
                 num_chunks: int | None = None,
                 schedule: str | GraphPass | None = None,
                 block: bool = True) -> jax.Array:
        """Move ``message`` (1-D array) from device ``src`` to ``dst``.

        Returns the received message (fetched from the destination shard).
        ``block=False`` launches without waiting; the caller syncs.
        ``schedule`` overrides the engine's chunk-interleaving scheduler
        for this call (DESIGN.md §2.2). For simultaneous
        opposite-direction traffic (OMB BIBW) or any other concurrent
        set, use :meth:`transfer_group` — the old ``bidirectional=True``
        flag is folded into the group API.
        """
        message = jnp.asarray(message)
        if message.ndim != 1:
            raise ValueError("message must be 1-D; reshape first")
        return self._dispatch(
            [(src, dst, message.shape[0], message.dtype)], [message],
            window=window, max_paths=max_paths, num_chunks=num_chunks,
            exclusive=False, schedule=schedule, single=True,
            block=block)[0]

    def transfer_group(self, messages: Sequence[jax.Array],
                       pairs: Sequence[tuple[int, int]], *,
                       window: int = 1, max_paths: int | None = None,
                       num_chunks: int | None = None,
                       exclusive: bool = False,
                       schedule: str | GraphPass | None = None,
                       block: bool = True) -> list[jax.Array]:
        """Move ``messages[i]`` (1-D) from ``pairs[i][0]`` to ``pairs[i][1]``
        — all of them in ONE compiled launch.

        The set is planned jointly (contention-aware; see
        :meth:`PathPlanner.plan_group`), lowered to one transfer graph,
        fused into one SPMD program, and cached under a :class:`GroupKey`
        derived from the graph digest. Returns the received messages,
        aligned with the inputs.

        Message identity is canonicalized before planning: the group is
        re-ordered by ``(src, dst, nelems, dtype)`` (stable), so
        structurally identical groups whose messages arrive in a
        different dispatch order resolve to the SAME plans, graph, cache
        entry, and fast-path signature instead of compiling a permuted
        twin (ROADMAP "graph-level cache dedup"). Results are returned in
        the caller's order.
        """
        msgs = [jnp.asarray(m) for m in messages]
        if len(msgs) != len(pairs):
            raise ValueError(f"{len(msgs)} messages vs {len(pairs)} pairs")
        if not msgs:
            return []
        for m in msgs:
            if m.ndim != 1:
                raise ValueError("messages must be 1-D; reshape first")
        specs = [(src, dst, m.shape[0], m.dtype)
                 for m, (src, dst) in zip(msgs, pairs)]
        order = sorted(range(len(msgs)),
                       key=lambda i: (specs[i][0], specs[i][1],
                                      specs[i][2], str(specs[i][3])))
        outs = self._dispatch([specs[i] for i in order],
                              [msgs[i] for i in order], window=window,
                              max_paths=max_paths, num_chunks=num_chunks,
                              exclusive=exclusive, schedule=schedule,
                              single=False, block=block)
        inverse = {i: k for k, i in enumerate(order)}
        return [outs[inverse[i]] for i in range(len(msgs))]

    def compiled_for(self, src: int, dst: int, nelems: int, dtype=jnp.float32,
                     *, window: int = 1, max_paths: int | None = None,
                     num_chunks: int | None = None,
                     schedule: str | GraphPass | None = None,
                     ) -> tuple[CompiledPlan, TransferPlan]:
        """AOT handle for benchmarks: returns (executable, plan).

        Always compiled WITHOUT operand donation (``GroupKey.donated`` is
        False) — AOT callers time repeated launches over the same operand
        arrays, which a donated executable would consume.
        """
        plan = self.plan_for(src, dst, nelems, dtype, max_paths=max_paths,
                             num_chunks=num_chunks)
        graph, chosen = self._group_graph((plan,), window, schedule)
        self._count_schedule(chosen)
        shapes = ((nelems, jnp.dtype(dtype)),)
        key = self._group_key(graph, (plan,), shapes, window)
        compiled = self.cache.get_or_build(
            key, lambda: self._compile_group(key, graph, shapes))
        return compiled, plan

    def compiled_for_group(self, specs: Sequence[tuple], *,
                           window: int = 1, max_paths: int | None = None,
                           num_chunks: int | None = None,
                           exclusive: bool = False,
                           schedule: str | GraphPass | None = None,
                           ) -> tuple[CompiledPlan, TransferGroup]:
        """AOT handle for a fused group; ``specs`` as in
        :meth:`plan_group_for`. Returns (executable, group). Specs are
        taken in the caller's order (no canonicalization — the executable
        expects operands aligned with ``group.plans``) and the program is
        compiled without donation, like :meth:`compiled_for`."""
        group = self.plan_group_for(specs, max_paths=max_paths,
                                    num_chunks=num_chunks,
                                    exclusive=exclusive)
        graph, chosen = self._group_graph(group.plans, window, schedule)
        self._count_schedule(chosen)
        shapes = [(nelems, jnp.dtype(dtype))
                  for (_, _, nelems, dtype) in specs]
        key = self._group_key(graph, group.plans, shapes, window)
        compiled = self.cache.get_or_build(
            key, lambda: self._compile_group(key, graph, shapes))
        return compiled, group

    # -- introspection ------------------------------------------------------
    def stats(self, reset: bool = False) -> dict:
        """Engine-level accounting: launches, plan-cache counters, fast-
        path counters (hits / misses / epoch invalidations), cumulative
        staging time, compiled graph totals, and per-schedule resolution
        counts. ``CommSession.stats()`` re-exports these sections.

        ``reset=True`` returns the snapshot then zeroes every windowed
        counter (engine counters, both caches' counters, cached plans'
        windowed lifecycles) so long-running sessions can report
        per-window rates instead of lifetime sums. Telemetry samples are
        NOT dropped — they feed calibration and are cleared explicitly
        via the recorder (``session.telemetry.clear()``).
        """
        out = {
            "dispatches": self.dispatches,
            "cache": self.cache.stats(reset=reset),
            "fastpath": {"enabled": self.fastpath,
                         "validate": self.validate,
                         "staging_ns": self.staging_ns,
                         **self._fastpath.stats(reset=reset)},
            "graph": {"nodes_compiled": self.nodes_compiled,
                      "edges_compiled": self.edges_compiled,
                      "copy_nodes_compiled": self.copy_nodes_compiled,
                      "compute_nodes_compiled":
                          self.compute_nodes_compiled},
            "schedules": dict(self.schedule_counts),
            # auto's candidate-score memo (keyed on digest + topology
            # epoch): hits are selections answered without re-scoring.
            "schedule_scores": AutoSchedule.score_stats(reset=reset),
            "health": self.health.snapshot(
                len(self.planner.quarantined), self.monitor is not None),
        }
        if self.telemetry is not None:
            out["telemetry"] = self.telemetry.stats()
        if reset:
            self.dispatches = 0
            self.staging_ns = 0
            self.nodes_compiled = 0
            self.edges_compiled = 0
            self.copy_nodes_compiled = 0
            self.compute_nodes_compiled = 0
            self.schedule_counts = {}
            self.health.reset_window()
        return out
