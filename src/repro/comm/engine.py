"""MultiPathTransfer — executable multi-path P2P transfers on a JAX mesh.

This is the UCT-layer analogue (DESIGN.md §2): it takes a
:class:`~repro.comm.plan.TransferPlan`, builds the SPMD program whose ops
are the plan's copy nodes (one ``ppermute`` per chunk per hop — the CUDA
Graph's memcpy nodes), compiles it once, and caches the executable in a
:class:`~repro.comm.cache.TransferPlanCache` keyed exactly like the
paper's graph cache (src, dst, size, path configuration).

Correctness model (§4.5 of the paper → functional dataflow here):

* each chunk writes a disjoint, precomputed destination offset,
* staged hop-2 consumes hop-1's value (dataflow dependency),
* paths never share a directional link (planner invariant),
* "final synchronization" is the functional join of all chunk outputs.

The engine runs on a flat 1-D device axis (default ``"dev"``); topology
device ids are mesh positions. Model-parallel meshes are a separate concern
(``repro/launch/mesh.py``). Most callers should go through
:class:`~repro.comm.session.CommSession` rather than constructing the
engine directly.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.cache import CompiledPlan, TransferPlanCache, compile_plan
from repro.compat import shard_map
from repro.comm.plan import TransferPlan
from repro.comm.planner import PathPlanner
from repro.core.pipelining import validate_plan
from repro.core.topology import HOST, Topology

AXIS = "dev"


@dataclasses.dataclass(frozen=True)
class TransferKey:
    """Graph-cache key: the paper keys on src/dst/size/path config."""

    src: int
    dst: int
    nelems: int
    dtype: str
    plan_sig: tuple  # ((via, num_chunks, nbytes), ...) per path
    window: int = 1
    bidirectional: bool = False


def plan_signature(plan: TransferPlan) -> tuple:
    return tuple((p.route.directional_links(), p.num_chunks, p.nbytes)
                 for p in plan.paths)


def _check_executable(plan: TransferPlan) -> None:
    for pa in plan.paths:
        if pa.route.via == HOST:
            raise ValueError(
                "host-staged path is not executable on the accelerator mesh "
                "(DESIGN.md §2); plan with include_host=False")


def multipath_send_local(x: jax.Array, plan: TransferPlan, *,
                         axis_name: str = AXIS,
                         itemsize: int | None = None) -> jax.Array:
    """Execute a plan *inside* a ``shard_map`` program.

    ``x`` is the local shard, shape ``(1, nelems)``; on the source device it
    holds the message, elsewhere contents are ignored. Returns an array of
    the same shape that holds the message on the destination device and
    zeros elsewhere. One ``ppermute`` per chunk per hop = one copy node.
    """
    _check_executable(plan)
    itemsize = itemsize or x.dtype.itemsize
    out = jnp.zeros_like(x)
    for pa in plan.paths:
        for off_b, size_b in pa.chunk_bounds():
            if off_b % itemsize or size_b % itemsize:
                raise ValueError("chunk bounds not element-aligned; pass "
                                 "granularity=itemsize to planner.plan()")
            off_e, size_e = off_b // itemsize, size_b // itemsize
            chunk = jax.lax.slice(x, (0, off_e), (1, off_e + size_e))
            for (a, b) in pa.route.directional_links():
                chunk = jax.lax.ppermute(chunk, axis_name, [(a, b)])
            out = jax.lax.dynamic_update_slice(out, chunk, (0, off_e))
    return out


class MultiPathTransfer:
    """Build, cache, and launch compiled multi-path transfer programs."""

    def __init__(self, mesh: jax.sharding.Mesh | None = None, *,
                 topology: Topology | None = None,
                 planner: PathPlanner | None = None,
                 cache: TransferPlanCache | None = None):
        if mesh is None:
            devs = jax.devices()
            mesh = jax.sharding.Mesh(devs, (AXIS,))
        self.mesh = mesh
        self.axis_name = mesh.axis_names[0]
        self.num_devices = mesh.devices.size
        if topology is None:
            topology = Topology.full_mesh(self.num_devices, with_host=True)
        self.topology = topology
        # `if ... is None` (not `or`): an *empty* TransferPlanCache is falsy
        # via __len__, and `or` would silently replace a caller's cache.
        self.planner = planner if planner is not None else PathPlanner(
            topology)
        self.cache = cache if cache is not None else TransferPlanCache()
        self._sharding = NamedSharding(mesh, P(self.axis_name))

    # -- planning -----------------------------------------------------------
    def plan_for(self, src: int, dst: int, nelems: int, dtype=jnp.float32,
                 **plan_kwargs) -> TransferPlan:
        itemsize = jnp.dtype(dtype).itemsize
        plan = self.planner.plan(src, dst, nelems * itemsize,
                                 granularity=itemsize,
                                 include_host=plan_kwargs.pop(
                                     "include_host", False),
                                 **plan_kwargs)
        validate_plan(plan)
        return plan

    # -- program construction -------------------------------------------------
    def _build_fn(self, plans: Sequence[TransferPlan], nelems: int,
                  window: int):
        """SPMD program executing ``window`` rounds of the given plan(s)."""
        for p in plans:
            _check_executable(p)
        ax = self.axis_name

        def local_body(x):  # x: (window, len(plans), 1, nelems) local
            outs = []
            for w in range(window):
                row = []
                for i, plan in enumerate(plans):
                    xi = x[w, i]
                    row.append(multipath_send_local(xi, plan, axis_name=ax))
                outs.append(jnp.stack(row))
            return jnp.stack(outs)

        return shard_map(
            local_body, mesh=self.mesh,
            in_specs=P(None, None, ax),
            out_specs=P(None, None, ax),
            check_vma=False)

    def _compile(self, key: TransferKey, plans: Sequence[TransferPlan],
                 dtype) -> CompiledPlan:
        nelems = key.nelems
        shape = (key.window, len(plans), self.num_devices, nelems)
        abstract = jax.ShapeDtypeStruct(
            shape, dtype, sharding=NamedSharding(
                self.mesh, P(None, None, self.axis_name)))
        num_nodes = sum(p.num_nodes for p in plans) * key.window
        fn = self._build_fn(plans, nelems, key.window)
        return compile_plan(key, fn, (abstract,), num_nodes=num_nodes)

    # -- public API ------------------------------------------------------------
    def transfer(self, message: jax.Array, src: int, dst: int, *,
                 window: int = 1, bidirectional: bool = False,
                 max_paths: int | None = None,
                 num_chunks: int | None = None,
                 block: bool = True) -> jax.Array:
        """Move ``message`` (1-D array) from device ``src`` to ``dst``.

        Returns the received message (fetched from the destination shard).
        With ``bidirectional=True`` the same message is simultaneously sent
        dst→src (OMB BIBW pattern) and both receptions are validated.
        ``block=False`` launches without waiting (overlapping independent
        transfers, e.g. a pytree migration); the caller syncs.
        """
        message = jnp.asarray(message)
        if message.ndim != 1:
            raise ValueError("message must be 1-D; reshape first")
        nelems = message.shape[0]
        plan = self.plan_for(src, dst, nelems, message.dtype,
                             max_paths=max_paths, num_chunks=num_chunks)
        plans = [plan]
        if bidirectional:
            plans.append(self.plan_for(dst, src, nelems, message.dtype,
                                       max_paths=max_paths,
                                       num_chunks=num_chunks))
        key = TransferKey(src, dst, nelems, str(message.dtype),
                          plan_signature(plan), window, bidirectional)
        compiled = self.cache.get_or_build(
            key, lambda: self._compile(key, plans, message.dtype))

        x = jnp.zeros((window, len(plans), self.num_devices, nelems),
                      message.dtype)
        x = x.at[:, 0, src].set(message)
        if bidirectional:
            x = x.at[:, 1, dst].set(message)
        x = jax.device_put(x, NamedSharding(
            self.mesh, P(None, None, self.axis_name)))
        y = compiled(x) if block else compiled.dispatch(x)
        return y[0, 0, dst]

    def compiled_for(self, src: int, dst: int, nelems: int, dtype=jnp.float32,
                     *, window: int = 1, bidirectional: bool = False,
                     max_paths: int | None = None,
                     num_chunks: int | None = None,
                     ) -> tuple[CompiledPlan, TransferPlan]:
        """AOT handle for benchmarks: returns (executable, plan)."""
        plan = self.plan_for(src, dst, nelems, dtype, max_paths=max_paths,
                             num_chunks=num_chunks)
        plans = [plan]
        if bidirectional:
            plans.append(self.plan_for(dst, src, nelems, dtype,
                                       max_paths=max_paths,
                                       num_chunks=num_chunks))
        key = TransferKey(src, dst, nelems, str(jnp.dtype(dtype)),
                          plan_signature(plan), window, bidirectional)
        compiled = self.cache.get_or_build(
            key, lambda: self._compile(key, plans, dtype))
        return compiled, plan
