"""CommSession — the single typed entry point for multi-path communication.

The paper's handler owns path selection, graph construction, and graph
caching behind one send/recv call (Algorithm 1). ``CommSession`` is that
handler for this repo: it owns one :class:`~repro.core.topology.Topology`,
one :class:`~repro.comm.planner.PathPlanner` (with its pluggable
:class:`~repro.comm.policy.PathPolicy`), and one
:class:`~repro.comm.cache.TransferPlanCache`, and every subsystem —
training, serving, benchmarks, examples — drives communication through it:

* ``session.send(x, src, dst)`` / ``session.bidirectional(...)`` — compiled
  multi-path P2P (the executable engine),
* ``session.exchange([(x, src, dst), ...])`` — a *transfer group*: a set of
  concurrent messages planned jointly (contention-aware), fused into one
  compiled SPMD program, one cache entry, one launch,
* ``session.all_gather/reduce_scatter/all_reduce/all_to_all/psum(...)`` —
  driver-level launches of the bidirectional-ring collectives, compiled
  once per (op, shape, dtype) and cached in the *same* plan cache,
* ``session.collectives`` — the same collectives bound to the session's
  axis name, for use *inside* user ``shard_map`` programs,
* ``session.plan(...)`` / ``session.tune(...)`` — planning and the offline
  tuner (paper §4.4),
* every execution path runs the configured chunk-interleaving scheduler
  (``CommConfig.schedule`` / ``CommSession(schedule="auto")`` / per-call
  ``schedule=``) over the lowered transfer graph before compiling
  (:mod:`repro.comm.passes`, DESIGN.md §2.2),
* repeat traffic takes the steady-state dispatch fast path (DESIGN.md
  §2.3, ``CommConfig.fastpath`` / ``REPRO_MP_FASTPATH``): the whole
  plan→lower→schedule→digest resolution is served from an epoch-stamped
  :class:`~repro.comm.cache.FastPathCache`, so a repeat send is one dict
  lookup + one staging write + one launch (``session.stats()["fastpath"]``
  reports hits / misses / epoch invalidations),
* ``session.send_pytree(...)`` — P2P for arbitrary pytrees (e.g. serving
  KV-cache migration).

See DESIGN.md §5 for the session model and §6 for the migration guide from
the legacy ``MultiPathTransfer``/``PathPlanner`` wiring.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm import collectives as coll
from repro import compat
from repro.comm.cache import CompiledPlan, TransferPlanCache, compile_plan
from repro.comm.calibration import (CalibrationFitter, CalibrationProfile,
                                    modeled_vs_measured)
from repro.compat import shard_map
from repro.comm.config import CommConfig
from repro.comm.engine import MultiPathTransfer
from repro.comm.graph import canonical_digest, lower
from repro.comm.health import FaultInjector, HealthMonitor, HealthStats
from repro.comm.passes import GraphPass
from repro.comm.plan import TransferPlan
from repro.comm.planner import PathPlanner
from repro.comm.policy import PathPolicy, make_policy
from repro.comm.telemetry import TimelineRecorder
from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class CollectiveKey:
    """Plan-cache key for a compiled collective launch.

    The digest keys the mesh size along with op/shape/dtype/axis: a cache
    shared across sessions on different-sized meshes must not serve one
    mesh's executable to the other (P2P keys carry
    ``GroupKey.num_devices`` for the same reason — the transfer-graph
    digest covers routes, not the device axis).
    Like :class:`~repro.comm.engine.GroupKey`, the key's identity is a
    canonical digest (:func:`repro.comm.graph.canonical_digest`) so every
    entry in the shared plan cache is derived the same way.
    """

    op: str
    digest: str

    @classmethod
    def for_collective(cls, op: str, shape: tuple, dtype: str, axis: str,
                       num_devices: int) -> "CollectiveKey":
        return cls(op, canonical_digest(
            ("collective", op, tuple(shape), dtype, axis, num_devices)))


@dataclasses.dataclass(frozen=True)
class BoundCollectives:
    """Multipath collectives bound to a session's axis name.

    For use *inside* ``shard_map`` programs (e.g. the manual-collectives
    training mode); the driver-level compiled counterparts live on
    :class:`CommSession`.
    """

    axis_name: str

    def all_gather(self, x: jax.Array) -> jax.Array:
        return coll.bidir_ring_all_gather(x, self.axis_name)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        return coll.bidir_ring_reduce_scatter(x, self.axis_name)

    def all_reduce(self, x: jax.Array) -> jax.Array:
        return coll.multipath_all_reduce(x, self.axis_name)

    def all_to_all(self, x: jax.Array) -> jax.Array:
        return coll.multipath_all_to_all(x, self.axis_name)

    def psum(self, x: jax.Array) -> jax.Array:
        return coll.psum_via_multipath(x, self.axis_name)

    def pmean(self, x: jax.Array) -> jax.Array:
        return self.psum(x) / compat.axis_size(self.axis_name)


class CommSession:
    """Facade owning topology, planner, policy, engine, and plan cache."""

    def __init__(self, config: CommConfig | None = None, *,
                 mesh: jax.sharding.Mesh | None = None,
                 topology: Topology | None = None,
                 policy: PathPolicy | None = None,
                 cache: TransferPlanCache | None = None,
                 schedule: str | None = None):
        self.config = config if config is not None else CommConfig.from_env()
        if schedule is not None:
            # Convenience: CommSession(schedule="auto") — equivalent to
            # replacing config.schedule (validated there against
            # SCHEDULE_NAMES).
            self.config = self.config.replace(schedule=schedule)
        self._mesh = mesh
        self.axis_name = (mesh.axis_names[0] if mesh is not None
                          else self.config.axis_name)
        if topology is None:
            topology = Topology.full_mesh(self.mesh.devices.size,
                                          with_host=True)
        self.topology = topology
        self.policy = policy if policy is not None else make_policy(
            self.config.policy)
        self.planner = PathPlanner(topology, config=self.config,
                                   policy=self.policy)
        self.cache = cache if cache is not None else TransferPlanCache(
            self.config.cache_capacity)
        self.collectives = BoundCollectives(self.axis_name)
        #: Dispatch-timeline recorder (DESIGN §4.4c). ``config.telemetry``
        #: force-enables it; otherwise ``REPRO_MP_TELEMETRY`` decides
        #: (default off — one boolean per dispatch).
        self.telemetry = TimelineRecorder(
            capacity=self.config.telemetry_capacity,
            enabled=True if self.config.telemetry else None)
        #: Link-health monitor (DESIGN §4.6): watches telemetry residuals
        #: for droop, quarantines suspect links on the planner, and
        #: re-admits them after healthy probes. ``config.health`` /
        #: ``REPRO_MP_HEALTH`` gates construction — with it off the
        #: session carries no monitor and dispatch pays nothing.
        self.monitor: HealthMonitor | None = None
        if self.config.health:
            self.monitor = HealthMonitor(
                self.topology, self.planner,
                droop_threshold=self.config.droop_threshold,
                droop_samples=self.config.droop_samples,
                probe_healthy=self.config.probe_healthy,
                recovery_ratio=self.config.recovery_ratio,
                probe_interval=self.config.probe_interval)
            # Droop detection rides the telemetry ring's observer hook
            # (fires only while telemetry is enabled — the zero-cost-off
            # contract is the recorder's, not duplicated here).
            self.telemetry.on_record = self.monitor.observe
        #: Deterministic chaos injector parsed from ``config.faults`` /
        #: ``REPRO_MP_FAULTS`` (empty spec → no injector, no hazard).
        self.faults: FaultInjector | None = (
            FaultInjector.from_spec(self.config.faults)
            if self.config.faults else None)
        self._engine: MultiPathTransfer | None = None
        if self.config.profile_dir:
            self._load_calibration(self.config.profile_dir)

    def _load_calibration(self, profiles_dir: str) -> None:
        """Load-on-init: attach the persisted calibration profile whose
        digest matches this session's topology, if one exists. A corrupt
        or version-mismatched file degrades to a warning (the session
        runs on nominal constants) rather than failing construction."""
        try:
            profile = CalibrationProfile.load_for(self.topology,
                                                  profiles_dir)
        except (ValueError, OSError) as exc:
            warnings.warn(f"ignoring calibration profile in "
                          f"{profiles_dir!r}: {exc}", stacklevel=3)
            return
        if profile is not None:
            self.topology.set_calibration(profile)

    # -- lazy resources -----------------------------------------------------
    @property
    def mesh(self) -> jax.sharding.Mesh:
        if self._mesh is None:
            self._mesh = jax.sharding.Mesh(jax.devices(), (self.axis_name,))
        return self._mesh

    @property
    def engine(self) -> MultiPathTransfer:
        """The executable transfer engine (built on first use so planning-
        only sessions never initialize a device mesh)."""
        if self._engine is None:
            self._engine = MultiPathTransfer(
                self.mesh,
                topology=self.topology,
                planner=self.planner,
                cache=self.cache,
                schedule=self.config.schedule,
                fastpath=self.config.fastpath,
                validate=self.config.validate,
                telemetry=self.telemetry,
                monitor=self.monitor,
                faults=self.faults,
                retry_limit=self.config.retry_limit,
                backoff_base_s=self.config.backoff_base_s)
        return self._engine

    @property
    def num_devices(self) -> int:
        return self.topology.num_devices

    # -- planning and tuning ------------------------------------------------
    def plan(self, src: int, dst: int, nbytes: int, **kwargs) -> TransferPlan:
        """Plan one P2P message (Algorithm 1 lines 4–11) via the policy."""
        return self.planner.plan(src, dst, nbytes, **kwargs)

    def plan_for(self, src: int, dst: int, nelems: int, dtype=jnp.float32,
                 **kwargs) -> TransferPlan:
        """Element-granular plan for a typed 1-D message."""
        return self.engine.plan_for(src, dst, nelems, dtype, **kwargs)

    def tune(self, src: int, dst: int, nbytes: int, **kwargs) -> TransferPlan:
        """Offline tuner (paper §4.4): best (paths × chunks × host) config."""
        return self.planner.tune(src, dst, nbytes, **kwargs)

    # -- point-to-point -----------------------------------------------------
    def send(self, x: jax.Array, src: int, dst: int, *,
             window: int | None = None, max_paths: int | None = None,
             num_chunks: int | None = None,
             schedule: str | GraphPass | None = None,
             block: bool = True) -> jax.Array:
        """Send 1-D ``x`` from device ``src`` to ``dst``; returns the
        received message. Compiled plans are cached (src, dst, size,
        config, dispatch schedule). ``schedule`` overrides the session's
        chunk-interleaving scheduler for this call (DESIGN.md §2.2).
        """
        return self.engine.transfer(
            x, src, dst, window=self.config.window if window is None
            else window, max_paths=max_paths, num_chunks=num_chunks,
            schedule=schedule, block=block)

    def bidirectional(self, x: jax.Array, src: int, dst: int, *,
                      window: int | None = None, max_paths: int | None = None,
                      num_chunks: int | None = None,
                      schedule: str | GraphPass | None = None
                      ) -> tuple[jax.Array, jax.Array]:
        """Simultaneous src→dst and dst→src of the same message (OMB BIBW).

        Executes as a 2-transfer group (one fused launch, cache-keyed on
        BOTH plans' signatures) and returns ``(forward, reverse)`` — the
        reception at ``dst`` and the reception at ``src``. Earlier versions
        returned only the forward reception; see DESIGN.md §6.
        """
        fwd, rev = self.exchange(
            [(x, src, dst), (x, dst, src)],
            window=self.config.window if window is None else window,
            max_paths=max_paths, num_chunks=num_chunks, schedule=schedule)
        return fwd, rev

    def exchange(self, items, *, window: int | None = None,
                 max_paths: int | None = None,
                 num_chunks: int | None = None,
                 exclusive: bool = False,
                 schedule: str | GraphPass | None = None,
                 block: bool = True) -> list[jax.Array]:
        """Execute a transfer group: ``items`` is a sequence of
        ``(x, src, dst)`` triples moved *concurrently*.

        The set is planned jointly — distinct flows get link-disjoint
        routes when the topology permits, and shares are derated for any
        sharing that remains (§4.4 model with ``concurrent_plans``) — then
        fused into ONE compiled SPMD program: one trace/lower/compile, one
        plan-cache entry keyed on every plan's signature, one launch.

        Arrays may be any shape/dtype (flattened on the wire, restored on
        return). Degenerate items are per-item no-ops returned unchanged:
        ``src == dst`` (nothing to move) and zero-size arrays (nothing to
        send — ``nbytes must be positive`` would otherwise reject them).
        ``exclusive=True`` demands group-level link exclusivity and raises
        if the topology cannot provide it. Returns the received arrays,
        aligned with ``items``.
        """
        items = list(items)
        results: list[jax.Array | None] = [None] * len(items)
        live: list[tuple[int, jax.Array, int, int]] = []
        for i, (x, src, dst) in enumerate(items):
            x = jnp.asarray(x)
            if src == dst or x.size == 0:
                results[i] = x
                continue
            live.append((i, x, src, dst))
        if live:
            outs = self.engine.transfer_group(
                [x.reshape(-1) for _, x, _, _ in live],
                [(src, dst) for _, _, src, dst in live],
                window=self.config.window if window is None else window,
                max_paths=max_paths, num_chunks=num_chunks,
                exclusive=exclusive, schedule=schedule, block=block)
            for (i, x, _, _), out in zip(live, outs):
                results[i] = out.reshape(x.shape)
        return results  # type: ignore[return-value]

    def plan_group(self, requests, **kwargs):
        """Jointly plan concurrent messages without executing
        (:meth:`PathPlanner.plan_group`); ``requests`` are
        ``(src, dst, nbytes)`` tuples or :class:`TransferRequest`."""
        return self.planner.plan_group(requests, **kwargs)

    def compiled_for(self, src: int, dst: int, nelems: int,
                     dtype=jnp.float32, **kwargs
                     ) -> tuple[CompiledPlan, TransferPlan]:
        """AOT (executable, plan) handle for benchmarks."""
        return self.engine.compiled_for(src, dst, nelems, dtype, **kwargs)

    def capture(self, build_fn, *, schedule: str | None = None):
        """Capture one whole iteration (kernels + multipath exchanges) as
        ONE heterogeneous transfer graph; returns a launchable
        :class:`~repro.comm.capture.CapturedStep`.

        ``build_fn(cap)`` declares the step against a
        :class:`~repro.comm.capture.StepCapture` — inputs, kernel
        invocations, fused exchanges — and returns the output ref(s).
        The recording lowers to one graph of copy AND compute nodes,
        the session's chunk-interleaving scheduler (§2.2) interleaves
        copies into compute gaps, and every call launches ONE compiled
        SPMD program: ``stats()["dispatches"]`` increments by exactly
        one per captured iteration, however many kernels and messages
        it carries. Resolution rides the §2.3 fast path (memoized per
        capture signature + schedule + planner epoch).
        """
        return self.engine.capture(build_fn, schedule=schedule)

    def send_pytree(self, tree, src: int, dst: int):
        """Move every array leaf of ``tree`` from ``src`` to ``dst``.

        All leaves are fused into ONE transfer group: one compiled SPMD
        program covering every leaf (one plan-cache entry keyed on all
        leaf plans, not one per leaf), and one launch — steady-state KV
        migration is a single dispatch regardless of leaf count.
        Zero-size leaves and ``src == dst`` are per-leaf no-ops.
        """
        leaves, treedef = jax.tree.flatten(tree)
        moved = self.exchange([(leaf, src, dst) for leaf in leaves])
        jax.block_until_ready(moved)
        return jax.tree.unflatten(treedef, moved)

    # -- driver-level collectives ------------------------------------------
    def _run_collective(self, op: str, x: jax.Array, local_fn,
                        in_spec: P, out_spec: P,
                        num_nodes: int) -> jax.Array:
        x = jnp.asarray(x)
        key = CollectiveKey.for_collective(
            op, tuple(x.shape), str(x.dtype), self.axis_name,
            self.mesh.devices.size)
        in_sharding = NamedSharding(self.mesh, in_spec)

        def build() -> CompiledPlan:
            fn = shard_map(local_fn, mesh=self.mesh, in_specs=in_spec,
                           out_specs=out_spec, check_vma=False)
            abstract = jax.ShapeDtypeStruct(x.shape, x.dtype,
                                            sharding=in_sharding)
            return compile_plan(key, fn, (abstract,), num_nodes=num_nodes)

        compiled = self.cache.get_or_build(key, build)
        return compiled(jax.device_put(x, in_sharding))

    def _axis_size(self) -> int:
        return self.mesh.shape[self.axis_name]

    def all_gather(self, x: jax.Array) -> jax.Array:
        """Bidirectional-ring all-gather of ``x`` sharded on dim 0.

        Returns the same global array, fully replicated — both ring
        directions carry half the features each step.
        """
        n = self._axis_size()
        return self._run_collective(
            "all_gather", x, self.collectives.all_gather,
            P(self.axis_name), P(None), num_nodes=2 * (n - 1))

    def _check_ring_divisible(self, op: str, x: jax.Array, n: int) -> None:
        if x.shape[0] % n:
            raise ValueError(
                f"{op} needs dim 0 divisible by the axis size {n}, got "
                f"{x.shape[0]}; pad upstream or use psum for arbitrary "
                f"shapes")

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        """Bidirectional-ring reduce-scatter of a replicated operand; the
        result is sharded on dim 0 (device i owns the reduced block i)."""
        n = self._axis_size()
        self._check_ring_divisible("reduce_scatter", x, n)
        return self._run_collective(
            "reduce_scatter", x, self.collectives.reduce_scatter,
            P(None), P(self.axis_name), num_nodes=2 * (n - 1))

    def all_reduce(self, x: jax.Array) -> jax.Array:
        """All-reduce (sum over the axis) of a replicated operand whose
        dim 0 is divisible by the axis size; use :meth:`psum` otherwise."""
        n = self._axis_size()
        self._check_ring_divisible("all_reduce", x, n)
        return self._run_collective(
            "all_reduce", x, self.collectives.all_reduce,
            P(None), P(None), num_nodes=4 * (n - 1))

    def all_to_all(self, x: jax.Array) -> jax.Array:
        """All-to-all: ``x`` sharded on dim 0, one destination block per
        device pair — global dim 0 must be exactly n² (block payload goes
        in the trailing dims; reshape ``(n², r, ...)`` for multi-row
        blocks). The local operand must have leading dim n, one block per
        destination, or the ring algorithm would silently drop blocks."""
        n = self._axis_size()
        if x.shape[0] != n * n:
            raise ValueError(
                f"all_to_all needs global dim 0 == n²={n * n} (one block "
                f"per device pair), got {x.shape[0]}; put multi-row block "
                f"payloads in the trailing dims")
        return self._run_collective(
            "all_to_all", x, self.collectives.all_to_all,
            P(self.axis_name), P(self.axis_name), num_nodes=n - 1)

    def psum(self, x: jax.Array) -> jax.Array:
        """Sum a replicated arbitrary-shape operand over the axis (pads and
        stripes through the bidirectional ring)."""
        n = self._axis_size()
        nd = jnp.asarray(x).ndim
        return self._run_collective(
            "psum", x, self.collectives.psum,
            P(*([None] * nd)), P(*([None] * nd)), num_nodes=4 * (n - 1))

    # -- calibration (DESIGN §4.4c) -----------------------------------------
    def calibrate(self, *, fitter: CalibrationFitter | None = None,
                  attach: bool = True, persist: bool | str = False,
                  **fit_kwargs) -> CalibrationProfile:
        """Fit a :class:`CalibrationProfile` from the session's recorded
        telemetry samples and (by default) attach it to the topology.

        Attaching goes through
        :meth:`~repro.core.topology.Topology.set_calibration`, so the
        plan epoch bumps and every subsequent estimate, ``auto``
        arbitration, and planner derate consumes the fitted terms.
        ``persist=True`` saves under ``config.profile_dir`` (a string
        persists under that directory instead); ``fit_kwargs`` forward to
        :class:`CalibrationFitter` (min_samples / warmup / decay /
        max_ratio — the robustness gates). The recorder's per-kernel
        execute channel is forwarded too, so a session that timed
        captured kernels gets a fitted per-kernel compute term. Raises
        ``ValueError`` when no samples were recorded (enable
        ``REPRO_MP_TELEMETRY`` and run traffic first).
        """
        samples = self.telemetry.samples()
        if not samples:
            raise ValueError(
                "no telemetry samples recorded — enable REPRO_MP_TELEMETRY "
                "(or CommConfig.telemetry) and dispatch traffic before "
                "calibrating")
        if fitter is None:
            fitter = CalibrationFitter(self.topology, **fit_kwargs)
        elif fit_kwargs:
            raise ValueError("pass fit_kwargs or a fitter, not both")
        profile = fitter.fit(samples,
                             kernels=self.telemetry.kernel_samples())
        if attach:
            self.topology.set_calibration(profile)
        if persist:
            out_dir = (persist if isinstance(persist, str)
                       else self.config.profile_dir)
            if not out_dir:
                raise ValueError("persist=True needs config.profile_dir "
                                 "(or pass persist=<dir>)")
            profile.save(out_dir)
        return profile

    # -- introspection ------------------------------------------------------
    def describe(self, src: int, dst: int, nbytes: int, *,
                 window: int | None = None,
                 schedule: str | GraphPass | None = None,
                 **plan_kwargs) -> dict:
        """Plan one message and report its transfer graph + model costs.

        Pure planning — no mesh, no compilation — so it works on
        planning-only sessions and is what the dry-run reporter and the
        benchmarks consume. Returns the SCHEDULED graph's shape (copy
        nodes, dependency edges, critical-path depth, canonical post-pass
        digest — the cache-key ingredient) and the analytic model's
        costs, all derived from the SAME lowering + scheduler pass the
        engine would execute. The ``"schedule"`` section reports the
        requested scheduler, the concrete order chosen (``auto`` resolves
        to its winner), its modeled time, and the delta vs the
        ``round_robin`` baseline (≤ 0 when the chosen order is modeled
        faster); for ``auto`` it additionally carries the per-candidate
        ``"candidates"`` scores its selection already computed.
        """
        from repro.comm.passes import (AutoSchedule, apply_schedule,
                                       make_schedule)
        from repro.core import pipelining as pl

        window = self.config.window if window is None else window
        requested = self.config.schedule if schedule is None else schedule
        plan = self.plan(src, dst, nbytes, **plan_kwargs)
        base_graph = lower(plan, window)
        sched = (make_schedule(requested, self.topology)
                 if isinstance(requested, str) else requested)
        candidates = None
        if isinstance(sched, AutoSchedule):
            # Reuse the scores auto's selection computes anyway instead
            # of re-evaluating the winner and the baseline.
            chosen, graph, candidates = sched.select(base_graph)
            scheduled_t = candidates[chosen]
            baseline_t = candidates["round_robin"]
        else:
            graph, chosen = apply_schedule(base_graph, sched,
                                           self.topology)
            scheduled_t = pl.scheduled_time_s(graph, self.topology)
            baseline_t = (scheduled_t if graph is base_graph else
                          pl.scheduled_time_s(base_graph, self.topology))
        wire = pl.wire_time_s(plan, self.topology)
        schedule_info = {
            "requested": (requested if isinstance(requested, str)
                          else requested.name),
            "chosen": chosen,
            "scheduled_time_s": scheduled_t,
            "round_robin_time_s": baseline_t,
            "delta_vs_round_robin_s": scheduled_t - baseline_t,
        }
        if candidates is not None:
            schedule_info["candidates"] = candidates
        return {
            "src": src, "dst": dst, "nbytes": nbytes, "window": window,
            "topology": self.topology.name,
            "num_paths": plan.num_paths,
            "schedule": schedule_info,
            # Steady-state dispatch (§2.3): whether repeat traffic for
            # this request would skip the pipeline just replayed above,
            # and the epoch stamp such an entry would be keyed under.
            "fastpath": {
                "enabled": self.config.fastpath,
                "validate": self.config.validate,
                "epoch": list(self.planner.epoch),
            },
            "graph": {
                "digest": graph.digest(),
                "nodes": graph.num_nodes,
                "copy_nodes": graph.num_copy_nodes,
                "compute_nodes": graph.num_compute_nodes,
                "edges": graph.num_edges,
                "critical_path_nodes": graph.critical_path_nodes(),
            },
            "model": {
                "wire_time_s": wire,
                "time_s": pl.estimate_transfer_time_s(plan, self.topology),
                "time_first_iter_s": pl.estimate_transfer_time_s(
                    plan, self.topology, first_iteration=True),
                "launch_overhead_ns": pl.launch_overhead_ns(
                    plan, compiled_plan=True, topo=self.topology),
                "launch_overhead_nograph_ns": pl.launch_overhead_ns(
                    plan, compiled_plan=False, topo=self.topology),
                "effective_gbps": pl.effective_bandwidth_gbps(
                    plan, self.topology),
            },
            # Lane-model view (§2.2): how the scheduled order prices
            # under the resource-lane simulation vs the serialized
            # chain, and how many modeled copy seconds hide behind
            # compute. Zero hidden time on a pure-comm describe.
            "overlap": self._overlap_info(graph),
            # Measured feedback (§4.4c): which terms the model sections
            # above actually consumed, plus modeled-vs-measured residuals
            # over the recorded samples so drift is visible.
            "calibration": self._calibration_info(),
            # Island structure (§3.1): whether this request crosses a
            # node boundary, and the flat-vs-two-level modeled
            # all-reduce delta for a payload of this size.
            "hierarchy": self._hierarchy_info(src, dst, nbytes),
            # Fault state (§4.6): failed / degraded / quarantined links
            # and the monitor's thresholds, so a dry-run shows whether
            # this plan was produced under degradation.
            "health": self._health_info(),
        }

    def _overlap_info(self, graph) -> dict:
        """The ``describe()['overlap']`` section: lane vs serialized
        makespans of the scheduled graph plus modeled hidden-copy
        seconds and the fraction of total copy time hidden — the
        §2.2 overlap-visibility contract."""
        from repro.core import pipelining as pl
        lane = pl.scheduled_time_s(graph, self.topology, mode="lanes")
        serialized = pl.scheduled_time_s(graph, self.topology,
                                         mode="serialized")
        hidden = pl.hidden_copy_time_s(graph, self.topology)
        weights = pl.graph_node_weights_s(graph, self.topology)
        copy_s = sum(w for nd, w in zip(graph.nodes, weights)
                     if not hasattr(nd, "kernel"))
        return {"lane_makespan_s": lane,
                "serialized_makespan_s": serialized,
                "hidden_copy_s": hidden,
                "hidden_copy_fraction": (hidden / copy_s
                                         if copy_s > 0 else 0.0)}

    def _hierarchy_info(self, src: int, dst: int, nbytes: int) -> dict:
        """The ``describe()['hierarchy']`` section: island count, the
        request's island endpoints, and — on >1-island topologies — the
        §4.4 tier model's flat vs two-level all-reduce times for this
        payload plus the layout ``config.collective_strategy`` resolves
        to, so benchmarks report the flat-vs-hierarchical delta from the
        same model the selection contract uses."""
        topo = self.topology
        info: dict = {"islands": topo.num_islands,
                      "src_island": topo.node_of(src),
                      "dst_island": topo.node_of(dst),
                      "cross_island": topo.is_inter_island(src, dst)}
        if topo.num_islands > 1:
            chosen, times = coll.select_all_reduce_strategy(
                topo, nbytes, self.config.collective_strategy)
            info["all_reduce"] = {
                "chosen": chosen,
                "flat_time_s": times["flat"],
                "two_level_time_s": times["two_level"],
                "delta_two_level_vs_flat_s": (times["two_level"]
                                              - times["flat"]),
            }
        return info

    def _health_info(self) -> dict:
        """The ``describe()['health']`` section: whether monitoring is
        enabled, the topology's failed/degraded/flaky link overlays, the
        planner's quarantine set, and — when a monitor is attached — its
        counters and thresholds. Pure state, JSON-able, no side effects:
        the §4.6 visibility contract for dry-runs and reports."""
        topo = self.topology
        info: dict = {
            "enabled": self.monitor is not None,
            "failed": sorted(list(k) for k in topo.failed_links),
            "degraded": {f"{a}-{b}": r
                         for (a, b), r in sorted(
                             topo.degraded_links.items())},
            "quarantined": sorted(list(k)
                                  for k in self.planner.quarantined),
        }
        if self.monitor is not None:
            info["monitor"] = self.monitor.snapshot()
        return info

    def probe_links(self, nelems: int = 256) -> dict:
        """Actively probe every quarantined link (DESIGN §4.6 recovery).

        Each probe validates the link's served bandwidth against the
        recovery threshold AND pushes a payload over exactly that link
        through the compiled engine, verifying delivery intact (the
        §4.5 integrity contract applied to re-admission). A link is
        re-admitted only after ``probe_healthy`` consecutive healthy
        probes (doubled for flaky-marked links). Returns ``{(src, dst):
        ok}`` keyed by the probed links; empty when nothing is
        quarantined or health is off.
        """
        if self.monitor is None:
            return {}
        return self.monitor.probe_all(self.engine, nelems=nelems)

    def drain_health_events(self) -> list[dict]:
        """Return and clear the accumulated health event log — injector
        firings, retries, quarantines, probes, re-admissions, ladder
        moves — merged in arrival order. Draining preserves counters
        (``stats()['health']`` windows are unaffected); it exists so
        supervisors like ``ResilientTrainLoop`` can fold comm-fault
        history into their own event stream without double-reporting."""
        events: list[dict] = []
        eng = self._engine
        if eng is not None:
            events.extend(eng.health.events)
            eng.health.events.clear()
        if self.monitor is not None:
            events.extend(self.monitor.events)
            self.monitor.events.clear()
        return events

    def _calibration_info(self) -> dict:
        """The ``describe()['calibration']`` section: live-profile
        summary and modeled-vs-measured residuals (constant vs fitted)
        over the telemetry ring — the §4.4c drift-visibility contract."""
        profile = self.topology.calibration
        info: dict = {"active": profile is not None}
        if profile is not None:
            info["profile"] = profile.summary()
        samples = self.telemetry.samples()
        if samples:
            info["residuals"] = modeled_vs_measured(
                samples, self.topology, profile)
        return info

    def stats(self, reset: bool = False) -> dict:
        """One-stop accounting: cache hits/misses, launches, policy,
        topology. ``dispatches`` counts compiled-program launches — a fused
        group (``exchange``, ``send_pytree``, ``bidirectional``) is ONE
        dispatch however many messages it carries — as is a captured
        whole-iteration step (``session.capture``). ``graph`` totals the
        nodes / dependency edges of every transfer graph this session
        compiled (cache misses only); ``copy_nodes_compiled`` /
        ``compute_nodes_compiled`` break the node total down by kind
        (heterogeneous captured-step graphs carry both). ``schedule`` is the session's
        default scheduler and ``schedules`` counts dispatch/compile
        calls per concrete schedule resolved — ``auto`` counts as
        whichever candidate it picked, and cache-hit launches count too
        (unlike ``graph``, which totals cache misses only).
        ``schedule_scores`` reports ``auto``'s candidate-score memo
        (hits / misses keyed on graph digest + topology epoch) —
        repeat selections of an unchanged graph are answered without
        re-scoring every candidate. ``fastpath``
        is the steady-state dispatch front cache (DESIGN.md §2.3):
        hits / misses / epoch ``invalidations`` plus ``staging_ns``, the
        cumulative host-side staging-dispatch time (staging *execution*
        overlaps the launch and lands in the launch timings).

        ``health`` is the §4.6 degradation ledger: ``retries`` /
        ``replans`` / ``faults_seen`` / ``host_relays`` are windowed
        counters (zeroed by ``reset=True`` like the rest), while
        ``ladder_level`` and ``quarantined_links`` are live state and
        survive resets — a reset must not forget that links are still
        quarantined.

        ``reset=True`` returns the snapshot then zeroes every windowed
        counter (engine dispatches/staging, both caches, cached plans'
        windowed lifecycles) — rates instead of lifetime sums for
        long-running serving sessions. Telemetry samples survive a reset
        (they feed :meth:`calibrate`); drop them via
        ``session.telemetry.clear()``.
        """
        eng = self._engine
        if eng is not None:
            es = eng.stats(reset=reset)
        else:
            # Same schema (and real default capacity) as the live engine
            # sections, derived from an empty cache rather than spelled
            # out by hand.
            from repro.comm.cache import FastPathCache
            from repro.comm.passes import AutoSchedule
            es = {"dispatches": 0,
                  "cache": self.cache.stats(reset=reset),
                  "fastpath": {"enabled": self.config.fastpath,
                               "validate": self.config.validate,
                               "staging_ns": 0, **FastPathCache().stats()},
                  "graph": {"nodes_compiled": 0, "edges_compiled": 0,
                            "copy_nodes_compiled": 0,
                            "compute_nodes_compiled": 0},
                  "schedules": {},
                  "schedule_scores": AutoSchedule.score_stats(reset=reset),
                  "health": HealthStats().snapshot(
                      len(self.planner.quarantined),
                      self.monitor is not None)}
        return {
            "cache": es["cache"],
            "dispatches": es["dispatches"],
            "fastpath": es["fastpath"],
            "graph": es["graph"],
            "policy": self.policy.name,
            "schedule": self.config.schedule,
            "schedules": es["schedules"],
            "schedule_scores": es["schedule_scores"],
            "health": es["health"],
            "topology": self.topology.name,
            "num_devices": self.topology.num_devices,
            "axis_name": self.axis_name,
            "telemetry": self.telemetry.stats(),
            "calibration": {
                "active": self.topology.calibration is not None},
        }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"CommSession(topology={self.topology.name!r}, "
                f"policy={self.policy.name!r}, "
                f"devices={self.topology.num_devices})")
