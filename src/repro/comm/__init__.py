"""repro.comm — the unified communication API (paper Algorithm 1).

One typed entry point for multi-path P2P, collectives, tuning, and plan
caching. Layering (DESIGN.md §1):

* :mod:`repro.comm.config`      — :class:`CommConfig` (+ ``from_env``)
* :mod:`repro.comm.plan`        — transfer-plan data model
* :mod:`repro.comm.graph`       — :class:`TransferGraph` heterogeneous DAG IR
* :mod:`repro.comm.passes`      — chunk-interleaving scheduler passes (§2.2)
* :mod:`repro.comm.capture`     — whole-iteration step capture (§2.4)
* :mod:`repro.comm.policy`      — pluggable :class:`PathPolicy` strategies
* :mod:`repro.comm.planner`     — route enumeration + plan construction
* :mod:`repro.comm.cache`       — compiled-plan LRU (CUDA-Graph analogue)
* :mod:`repro.comm.telemetry`   — per-dispatch stage-timing recorder (§4.4c)
* :mod:`repro.comm.calibration` — measured-feedback model fitting (§4.4c)
* :mod:`repro.comm.collectives` — bidirectional-ring collectives
* :mod:`repro.comm.health`      — link-fault injection + health monitor (§4.6)
* :mod:`repro.comm.engine`      — executable transfer engine (shard_map)
* :mod:`repro.comm.session`     — :class:`CommSession` facade

Typical use::

    from repro.comm import CommConfig, CommSession

    session = CommSession(CommConfig(max_paths=3))
    out = session.send(message, src=0, dst=1)
    print(session.stats()["cache"])

The legacy ``repro.core.paths`` / ``repro.core.multipath`` /
``repro.core.plan_cache`` / ``repro.core.collectives`` modules are
deprecated shims over this package.
"""

from repro.compat import make_mesh, shard_map  # noqa: F401
from repro.comm.config import (  # noqa: F401
    COLLECTIVE_STRATEGIES, POLICY_NAMES, SCHEDULE_NAMES, VALIDATE_MODES,
    CommConfig)
from repro.comm.plan import (  # noqa: F401
    PathAssignment, TransferGroup, TransferPlan, TransferRequest)
from repro.comm.graph import (  # noqa: F401
    BUFFER_EDGE, ComputeNode, CopyNode, DepEdge, TransferGraph,
    canonical_digest, lower)
from repro.comm.capture import (  # noqa: F401
    BufferRef, BufferSpec, CapturedStep, StepCapture, captured_psum,
    emit_step, lower_step)
from repro.comm.passes import (  # noqa: F401
    AutoSchedule, CriticalPathSchedule, DepthFirstSchedule, GraphPass,
    RoundRobinSchedule, apply_schedule, check_pass, make_schedule,
    reindex, run_pipeline)
from repro.comm.policy import (  # noqa: F401
    GreedyBandwidthPolicy, PathPolicy, RoundRobinPolicy, TunerPolicy,
    contention_scaled, make_policy)
from repro.comm.planner import PathPlanner  # noqa: F401
from repro.comm.cache import (  # noqa: F401
    CompiledPlan, FastPathCache, FastPathEntry, PlanLifecycle,
    TransferPlanCache, compile_plan)
from repro.comm.telemetry import (  # noqa: F401
    DispatchSample, StageTimings, TimelineRecorder)
from repro.comm.calibration import (  # noqa: F401
    PROFILE_VERSION, CalibrationFitter, CalibrationProfile,
    modeled_sample_time_s, modeled_vs_measured)
from repro.comm.collectives import (  # noqa: F401
    bidir_ring_all_gather, bidir_ring_reduce_scatter, modeled_all_reduce_s,
    multipath_all_reduce, multipath_all_to_all, psum_via_multipath,
    select_all_reduce_strategy, tier_bandwidths_gbps, two_level_all_reduce)
from repro.comm.health import (  # noqa: F401
    LADDER, CommFaultError, FaultEvent, FaultInjector, HealthMonitor,
    HealthStats, LinkFaultError)
from repro.comm.engine import (  # noqa: F401
    AXIS, GroupKey, MultiPathTransfer, group_signature,
    multipath_send_local, plan_signature)
from repro.comm.session import (  # noqa: F401
    BoundCollectives, CollectiveKey, CommSession)
