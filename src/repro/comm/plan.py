"""Transfer-plan data model: the 2-D (horizontal × vertical) split.

Moved from ``repro/core/paths.py`` as part of the ``repro.comm`` API
consolidation; pure data, shared by policies, the planner, the pipelining
time model, and the executable engine.

Beyond the single-message :class:`TransferPlan`, this module holds the
*group* data model: a :class:`TransferRequest` describes one message of a
set planned jointly, and a :class:`TransferGroup` is the jointly-planned
result — one plan per message, produced by
:meth:`~repro.comm.planner.PathPlanner.plan_group` so that cross-message
link sharing is priced (and, where feasible, avoided) instead of ignored.
"""

from __future__ import annotations

import dataclasses

from repro.core.topology import Route


@dataclasses.dataclass(frozen=True)
class PathAssignment:
    """One path of a transfer: a route, its byte range, and its chunking.

    ``granularity`` keeps every chunk boundary aligned (e.g. to the dtype
    itemsize when the engine moves typed arrays rather than raw bytes).
    """

    route: Route
    offset: int          # byte offset into the message (disjoint, §4.5)
    nbytes: int          # share of the message on this path
    num_chunks: int      # vertical split (pipelining)
    granularity: int = 1

    def chunk_bounds(self) -> list[tuple[int, int]]:
        """Disjoint (offset, size) per chunk; last chunk absorbs remainder."""
        if self.nbytes == 0:
            return []
        g = self.granularity
        base = (self.nbytes // self.num_chunks) // g * g
        bounds = []
        off = self.offset
        for i in range(self.num_chunks):
            size = base if i < self.num_chunks - 1 else (
                self.nbytes - base * (self.num_chunks - 1))
            bounds.append((off, size))
            off += size
        return bounds


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    """The full 2-D plan for one P2P message (horizontal × vertical split)."""

    src: int
    dst: int
    nbytes: int
    paths: tuple[PathAssignment, ...]
    topology_name: str

    @property
    def num_paths(self) -> int:
        return len(self.paths)

    @property
    def num_nodes(self) -> int:
        """Copy-node count of the equivalent CUDA Graph (paper Fig. 13/14):
        one node per chunk per hop."""
        return sum(p.num_chunks * p.route.num_hops for p in self.paths)

    def covered_bytes(self) -> int:
        return sum(p.nbytes for p in self.paths)

    def directional_links(self) -> set[tuple[int, int]]:
        """All directional links used by any path of this plan."""
        return {link for pa in self.paths
                for link in pa.route.directional_links()}


@dataclasses.dataclass(frozen=True)
class TransferRequest:
    """One message of a jointly-planned transfer group.

    ``granularity`` keeps chunk boundaries aligned per message (dtype
    itemsize when the engine moves typed arrays) — messages of a group may
    have different dtypes, so it is per-request rather than per-group.
    """

    src: int
    dst: int
    nbytes: int
    granularity: int = 1

    @property
    def flow(self) -> tuple[int, int]:
        return (self.src, self.dst)


@dataclasses.dataclass(frozen=True)
class TransferGroup:
    """A set of concurrent P2P messages planned as one unit.

    Produced by :meth:`~repro.comm.planner.PathPlanner.plan_group`: plans
    are aligned with the requests, and route selection accounted for every
    other message of the group. Distinct flows (``(src, dst)`` pairs) get
    link-disjoint routes whenever the topology permits; messages of the
    *same* flow share that flow's routes (they serialize per link, which
    the analytic model prices as contention). The engine fuses the whole
    group into one compiled SPMD program and one launch.
    """

    plans: tuple[TransferPlan, ...]
    topology_name: str

    @property
    def num_messages(self) -> int:
        return len(self.plans)

    @property
    def num_nodes(self) -> int:
        """Total copy-node count of the fused program (one CUDA Graph)."""
        return sum(p.num_nodes for p in self.plans)

    @property
    def total_nbytes(self) -> int:
        return sum(p.nbytes for p in self.plans)

    def link_flows(self) -> dict[tuple[int, int], set[tuple[int, int]]]:
        """Directional link → set of flows (src, dst) that use it."""
        out: dict[tuple[int, int], set[tuple[int, int]]] = {}
        for plan in self.plans:
            for link in plan.directional_links():
                out.setdefault(link, set()).add((plan.src, plan.dst))
        return out

    def shared_links(self) -> set[tuple[int, int]]:
        """Directional links carrying more than one flow (contended)."""
        return {link for link, flows in self.link_flows().items()
                if len(flows) > 1}

    @property
    def exclusive(self) -> bool:
        """True when no directional link is shared across distinct flows —
        the group-level §4.5 invariant, feasible for exchange patterns
        (bidirectional, halo) but not e.g. many messages into one device."""
        return not self.shared_links()
