"""Transfer-plan data model: the 2-D (horizontal × vertical) split.

Moved from ``repro/core/paths.py`` as part of the ``repro.comm`` API
consolidation; pure data, shared by policies, the planner, the pipelining
time model, and the executable engine.
"""

from __future__ import annotations

import dataclasses

from repro.core.topology import Route


@dataclasses.dataclass(frozen=True)
class PathAssignment:
    """One path of a transfer: a route, its byte range, and its chunking.

    ``granularity`` keeps every chunk boundary aligned (e.g. to the dtype
    itemsize when the engine moves typed arrays rather than raw bytes).
    """

    route: Route
    offset: int          # byte offset into the message (disjoint, §4.5)
    nbytes: int          # share of the message on this path
    num_chunks: int      # vertical split (pipelining)
    granularity: int = 1

    def chunk_bounds(self) -> list[tuple[int, int]]:
        """Disjoint (offset, size) per chunk; last chunk absorbs remainder."""
        if self.nbytes == 0:
            return []
        g = self.granularity
        base = (self.nbytes // self.num_chunks) // g * g
        bounds = []
        off = self.offset
        for i in range(self.num_chunks):
            size = base if i < self.num_chunks - 1 else (
                self.nbytes - base * (self.num_chunks - 1))
            bounds.append((off, size))
            off += size
        return bounds


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    """The full 2-D plan for one P2P message (horizontal × vertical split)."""

    src: int
    dst: int
    nbytes: int
    paths: tuple[PathAssignment, ...]
    topology_name: str

    @property
    def num_paths(self) -> int:
        return len(self.paths)

    @property
    def num_nodes(self) -> int:
        """Copy-node count of the equivalent CUDA Graph (paper Fig. 13/14):
        one node per chunk per hop."""
        return sum(p.num_chunks * p.route.num_hops for p in self.paths)

    def covered_bytes(self) -> int:
        return sum(p.nbytes for p in self.paths)
