"""TransferGraph — the first-class copy-node DAG (the CUDA Graph analogue).

The paper's core artifact is the CUDA Graph itself: explicit memcpy nodes
with dependency edges, instantiated once and replayed. This module makes
that graph a first-class IR for the repo: a single lowering pass
(:func:`lower`) turns a :class:`~repro.comm.plan.TransferPlan` or a
:class:`~repro.comm.plan.TransferGroup` into a :class:`TransferGraph` —
one :class:`CopyNode` per chunk per hop per window round, plus explicit
dependency edges — and every downstream layer consumes the same graph:

* the executable engine (:mod:`repro.comm.engine`) walks nodes in
  topological order emitting one ``ppermute`` per node,
* the analytic model (:mod:`repro.core.pipelining`) evaluates wire time
  as the critical path over the DAG and launch overhead from the node
  count,
* the §4.5 validators check disjoint byte cover, directional-link
  exclusivity, and connected hop chains on nodes/edges,
* compiled-program cache keys derive from the canonical
  :meth:`TransferGraph.digest`.

Because the model, the validator, and the executable are all views over
ONE lowering, they can no longer silently disagree about what a plan
means (the PR-2 mid-route-host bug was exactly such a divergence).

The IR is **heterogeneous** (whole-iteration capture): alongside
:class:`CopyNode` the graph may carry :class:`ComputeNode` entries —
one per SPMD kernel invocation — so a full iteration (stencil sweep + halo
exchange, grad compute + multipath pmean) is ONE graph scheduled by the
same passes and launched as ONE compiled program. Compute nodes declare
the *buffer ids* they read (``operands``) and write (``results``);
dataflow between compute and copies is stored as ``"buffer"`` edges and
validated as part of §4.5 (def-use consistency against the graph's
``messages`` table).

Edge kinds:

* ``"hop"`` — hop order within a chunk (hop *i+1* consumes hop *i*'s
  value; the CUDA Graph dependency edge),
* ``"window"`` — replay ordering between window rounds of the same chunk
  (round *w+1* re-sends the chunk after round *w* completed),
* ``"buffer"`` — def-use dataflow through a named buffer: producer
  compute → first-hop copy of a message whose payload it wrote, terminal
  copy → consumer compute of the message's reception buffer, or compute
  → compute directly.

Per-link serialization between consecutive chunks of one path is *not*
stored — it is derivable (:meth:`TransferGraph.serialization_edges`) and
only the time model needs it; storing it would bloat digests without
adding information.

**Dispatch order is node-index order.** The lowering emits nodes in the
paper's Algorithm 1 round-robin interleave (chunk waves across paths);
chunk-interleaving schedulers (:mod:`repro.comm.passes`) are graph→graph
rewrites that renumber nodes into a different dispatch order between
:func:`lower` and the emitter, preserving the §4.5 invariants (byte cover
and hop chains fixed, serialization order free) while :meth:`digest`
distinguishes the schedules. See DESIGN.md §2.2 for the pass contract.
"""

from __future__ import annotations

import dataclasses
import hashlib
import heapq
from functools import cached_property, lru_cache

from repro.comm.plan import TransferGroup, TransferPlan

#: Edge kinds (see module docstring).
HOP_EDGE = "hop"
WINDOW_EDGE = "window"
BUFFER_EDGE = "buffer"


@dataclasses.dataclass(frozen=True)
class CopyNode:
    """One copy node: one chunk of one message crossing one link.

    The CUDA-Graph memcpy-node analogue (paper Fig. 13/14). ``offset`` /
    ``nbytes`` are the chunk's byte range *within its message* — constant
    along the chunk's hop chain, so every node knows exactly which bytes
    it moves.

    Invariant obligations (§4.5, checked by :meth:`TransferGraph.validate`):
    nodes of one message must cover ``[0, nbytes)`` disjointly at their
    terminal hops, and a node's ``(flow, msg_idx, path_idx, chunk_idx,
    hop_idx, window, link, offset, nbytes)`` tuple is its identity — a
    scheduler pass may renumber node *indices* but must never alter the
    tuple itself (byte cover and hop chains are fixed).
    """

    flow: tuple[int, int]      # (src, dst) of the owning message
    msg_idx: int               # message index within the group
    path_idx: int              # horizontal split index within the message
    chunk_idx: int             # vertical split index within the path
    hop_idx: int               # position along the route's hop chain
    window: int                # replay round (0-based)
    link: tuple[int, int]      # directional link traversed
    offset: int                # byte offset into the message
    nbytes: int                # chunk size in bytes


@dataclasses.dataclass(frozen=True)
class ComputeNode:
    """One SPMD kernel invocation inside a heterogeneous graph.

    The CUDA-Graph kernel-node analogue: ``kernel`` is the registered
    kernel name (its *identity* — digests, cache keys, and telemetry
    signatures all key on it, so re-registering a different function
    under the same name is a contract breach exactly like mutating a
    cached plan). ``operands`` / ``results`` are buffer ids in the
    owning capture's buffer table; the §4.5 validator checks that every
    :data:`BUFFER_EDGE` touching this node is consistent with them
    (def-use edges must name buffers the node actually reads/writes).

    Invariant obligations (§2.2): like :class:`CopyNode`, the tuple
    ``(kernel, window, operands, results, flops, cost_ns)`` is the
    node's identity — scheduler passes may renumber indices but must
    preserve the tuple (unless they declare ``allows_rewrite``).
    ``flops`` / ``cost_ns`` feed the cost model: ``cost_ns`` (measured)
    wins when non-zero, else declared ``flops`` are priced at the
    :data:`repro.core.pipelining.COMPUTE_GFLOPS` rate.
    """

    kernel: str                 # registered kernel name (identity)
    window: int                 # replay round (0-based)
    operands: tuple[int, ...]   # buffer ids read
    results: tuple[int, ...]    # buffer ids written
    flops: int = 0              # declared work (model input)
    cost_ns: int = 0            # measured time; overrides flops if set


@dataclasses.dataclass(frozen=True)
class DepEdge:
    """A dependency edge between node indices (``src`` before ``dst``).

    Invariant obligations: index order is dispatch order, so every stored
    edge must point forward (``src < dst`` after any scheduler pass — the
    §2.2 contract; :meth:`TransferGraph.topological_order` re-validates
    acyclicity). ``kind`` is :data:`HOP_EDGE` (dataflow: hop *i+1*
    consumes hop *i*'s value), :data:`WINDOW_EDGE` (replay ordering), or
    :data:`BUFFER_EDGE` (def-use dataflow through a named buffer, the
    compute↔copy coupling in heterogeneous graphs); passes may not add,
    drop, or re-kind edges, only renumber endpoints (unless they declare
    ``allows_rewrite`` — see DESIGN §2.2).
    """

    src: int
    dst: int
    kind: str  # HOP_EDGE | WINDOW_EDGE


def canonical_digest(payload: object) -> str:
    """Stable hex digest of a canonical (repr-able) payload.

    Used by :meth:`TransferGraph.digest` and by non-P2P cache keys (the
    collective keys) so every compiled-program key in the plan cache is
    derived the same way. The payload must already be canonical — the
    caller's invariant obligation is that two semantically identical
    inputs ``repr`` identically (sort any unordered parts first).
    """
    return hashlib.sha256(repr(payload).encode()).hexdigest()[:32]


@dataclasses.dataclass(frozen=True)
class TransferGraph:
    """The copy-node DAG for one message or one fused transfer group.

    Node-index order is the dispatch schedule: the emitter walks indices
    (via :meth:`topological_order`), the model serializes same-link chunks
    in index order, and :meth:`digest` — the cache-key ingredient — hashes
    nodes *in order*, so two schedules of one plan digest apart. The §4.5
    invariants live in :meth:`validate`; scheduler passes must preserve
    them and leave the node/edge *content* untouched (DESIGN.md §2.2).
    """

    nodes: tuple[CopyNode | ComputeNode, ...]
    edges: tuple[DepEdge, ...]
    window: int
    num_messages: int
    topology_name: str
    #: msg_idx → (payload buffer id, reception buffer id) for captured
    #: graphs; empty for pure-comm lowerings. Needed by the §4.5 buffer
    #: def-use validation and the heterogeneous emitter.
    messages: tuple[tuple[int, int], ...] = ()

    # -- basic shape --------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        """Total node count (copies + computes) — invariant under every
        non-rewriting scheduler pass (the equal-graph acceptance: traced
        ``ppermute`` + kernel-call count equals this)."""
        return len(self.nodes)

    @property
    def num_copy_nodes(self) -> int:
        """:class:`CopyNode` count — equals the traced ``ppermute``
        count; invariant under non-rewriting passes (§2.2)."""
        return sum(1 for n in self.nodes if isinstance(n, CopyNode))

    @property
    def num_compute_nodes(self) -> int:
        """:class:`ComputeNode` count — equals the traced kernel-call
        count; invariant under non-rewriting passes (§2.2)."""
        return sum(1 for n in self.nodes if isinstance(n, ComputeNode))

    @property
    def num_edges(self) -> int:
        """Stored dependency-edge count (hop + window + buffer;
        serialization edges are derived, not stored) — invariant under
        passes."""
        return len(self.edges)

    def flows(self) -> tuple[tuple[int, int], ...]:
        """Per-message (src, dst), aligned with ``msg_idx``. Compute
        nodes carry no flow and are skipped; the §4.5 per-message
        invariants apply to copy nodes only."""
        seen: dict[int, tuple[int, int]] = {}
        for n in self.nodes:
            if isinstance(n, CopyNode):
                seen.setdefault(n.msg_idx, n.flow)
        return tuple(seen[i] for i in sorted(seen))

    # -- dataflow structure -------------------------------------------------
    @cached_property
    def hop_predecessor(self) -> dict[int, int]:
        """Node index → its hop-chain predecessor (data dependency)."""
        return {e.dst: e.src for e in self.edges if e.kind == HOP_EDGE}

    @cached_property
    def terminal_nodes(self) -> frozenset[int]:
        """Copy nodes with no outgoing hop edge — each chunk's landing
        copy (compute nodes are never terminals; the §4.5 byte-cover
        invariant is checked over exactly this set)."""
        non_terminal = {e.src for e in self.edges if e.kind == HOP_EDGE}
        return frozenset(
            i for i, n in enumerate(self.nodes)
            if isinstance(n, CopyNode)) - non_terminal

    def topological_order(self) -> list[int]:
        """Kahn's algorithm over the stored edges, lowest index first.

        The lowering emits nodes in a valid topological order already;
        running Kahn's keeps that a checked property rather than a
        convention (a cycle raises ``ValueError``).
        """
        succs: dict[int, list[int]] = {}
        indeg = [0] * self.num_nodes
        for e in self.edges:
            succs.setdefault(e.src, []).append(e.dst)
            indeg[e.dst] += 1
        ready = [i for i, d in enumerate(indeg) if d == 0]
        heapq.heapify(ready)
        order: list[int] = []
        while ready:
            i = heapq.heappop(ready)
            order.append(i)
            for j in succs.get(i, ()):
                indeg[j] -= 1
                if indeg[j] == 0:
                    heapq.heappush(ready, j)
        if len(order) != self.num_nodes:
            raise ValueError("dependency cycle in transfer graph")
        return order

    def serialization_edges(self) -> list[tuple[int, int]]:
        """Implicit per-link serialization edges (not stored, derived).

        Consecutive chunks of one (message, path, window) traverse the
        same directional link at the same hop position and serialize on
        it **in dispatch (node-index) order** — so a scheduler pass that
        renumbers nodes reorders exactly these edges, which is the only
        freedom the §2.2 pass contract grants. The critical-path
        evaluations in :mod:`repro.core.pipelining` add these to the hop
        and window edges. Compute nodes all share one ``("compute",)``
        slot — kernels execute serially on the device's compute stream
        in dispatch order, which is the resource the §2.2 schedulers
        trade against link serialization when they interleave copies
        into compute gaps.
        """
        by_slot: dict[tuple, list[int]] = {}
        for i, n in enumerate(self.nodes):
            if isinstance(n, ComputeNode):
                by_slot.setdefault(("compute",), []).append(i)
            else:
                by_slot.setdefault(
                    (n.msg_idx, n.path_idx, n.window, n.hop_idx),
                    []).append(i)
        out: list[tuple[int, int]] = []
        for slot in by_slot.values():
            out.extend(zip(slot, slot[1:]))
        return out

    def critical_path_nodes(self) -> int:
        """Longest chain length (in nodes) over hop + serialization +
        window edges — the depth of the DAG the scheduler must respect."""
        depth = [1] * self.num_nodes
        succs: dict[int, list[int]] = {}
        for e in self.edges:
            succs.setdefault(e.src, []).append(e.dst)
        for a, b in self.serialization_edges():
            succs.setdefault(a, []).append(b)
        for i in reversed(self.topological_order()):
            for j in succs.get(i, ()):
                depth[i] = max(depth[i], 1 + depth[j])
        return max(depth, default=0)

    # -- identity -----------------------------------------------------------
    @cached_property
    def _digest(self) -> str:
        """Memoized hash body — computed once per (frozen) instance.

        Nodes/edges are immutable, so the digest is a pure function of
        the instance; before this memo every ``_group_key`` construction
        re-hashed the whole graph on the dispatch hot path. The §2.2
        invariant that passes return *new* graphs (never mutate) is what
        makes per-instance caching sound. Nodes are tagged with their
        type name so heterogeneous graphs canonicalize unambiguously —
        a :class:`CopyNode` and a :class:`ComputeNode` can never collide
        even if their field tuples happened to match.
        """
        return canonical_digest((
            tuple((type(n).__name__,) + dataclasses.astuple(n)
                  for n in self.nodes),
            tuple(sorted(dataclasses.astuple(e) for e in self.edges)),
            self.window, self.num_messages, self.messages))

    def digest(self) -> str:
        """Canonical content hash — THE cache-key ingredient.

        Two lowerings digest equal iff they have identical nodes *in the
        same dispatch order*, the same edge set, and the same window
        count, regardless of how the source plan objects were assembled;
        compiled-program keys (:class:`repro.comm.engine.GroupKey`) are
        derived from this instead of hand-assembled plan signatures.

        Node order is significant on purpose — it IS the schedule, so two
        scheduler passes over one plan digest apart and can never
        cross-serve executables. Edge *storage* order is not semantic
        (edges are a set) and is sorted before hashing, so a pass that
        renumbers nodes and re-sorts edges digests equal to any other
        pass producing the same dispatch order. Memoized on the instance
        (graphs are frozen): repeat calls — e.g. steady-state dispatch
        re-deriving a ``GroupKey`` — hash nothing.
        """
        return self._digest

    # -- invariants (§4.5, checked on nodes/edges) --------------------------
    def validate(self, nbytes_per_message: dict[int, int] | None = None,
                 *, cross_flow_exclusive: bool = True) -> None:
        """Assert the §4.5 integrity invariants on the graph itself.

        1. **Disjoint byte cover** — per message, terminal-node chunk
           ranges are disjoint and (when ``nbytes_per_message`` is given)
           exactly cover ``[0, nbytes)``.
        2. **Directional-link exclusivity** — within one message no two
           paths share a link; across messages no link carries two
           *distinct* flows (same-flow messages legitimately share their
           flow's routes). ``cross_flow_exclusive=False`` skips the
           cross-message half (the planner's shared fallback trades it
           away deliberately).
        3. **Connected hop chains** — every chunk's links chain
           ``flow.src → ... → flow.dst`` in hop order.
        4. **Buffer def-use consistency** (heterogeneous graphs) — every
           :data:`BUFFER_EDGE` names real dataflow: compute→compute
           edges share a buffer id between the producer's ``results``
           and the consumer's ``operands``; compute→copy edges land on a
           first-hop copy of a message whose payload buffer the producer
           wrote; copy→compute edges leave a terminal copy of a message
           whose reception buffer the consumer reads (resolved through
           the graph's ``messages`` table).

        Raises ``ValueError`` on any breach.
        """
        # (2) link exclusivity, on copy nodes
        link_paths: dict[tuple[int, tuple[int, int]], int] = {}
        link_flow: dict[tuple[int, int], tuple[int, int]] = {}
        for n in self.nodes:
            if not isinstance(n, CopyNode):
                continue
            prev_path = link_paths.setdefault((n.msg_idx, n.link),
                                              n.path_idx)
            if prev_path != n.path_idx:
                raise ValueError(
                    f"directional link {n.link} shared by paths")
            if cross_flow_exclusive:
                prev_flow = link_flow.setdefault(n.link, n.flow)
                if prev_flow != n.flow:
                    raise ValueError(
                        f"directional link {n.link} shared across flows "
                        f"{prev_flow} and {n.flow} (group-level §4.5 "
                        f"exclusivity breach)")
        # (3) connected hop chains, on hop edges
        chains: dict[tuple[int, int, int, int], list[CopyNode]] = {}
        for n in self.nodes:
            if not isinstance(n, CopyNode):
                continue
            chains.setdefault(
                (n.msg_idx, n.path_idx, n.chunk_idx, n.window),
                []).append(n)
        for chain in chains.values():
            chain.sort(key=lambda n: n.hop_idx)
            links = [n.link for n in chain]
            flow = chain[0].flow
            if links[0][0] != flow[0] or links[-1][1] != flow[1]:
                raise ValueError(f"route endpoints wrong: {links}")
            for (a, b), (c, d) in zip(links, links[1:]):
                if b != c:
                    raise ValueError(f"disconnected hops {links}")
        # (1) disjoint cover, on terminal nodes of window 0 (messages that
        # lowered to no nodes still get their coverage checked)
        per_msg: dict[int, list[tuple[int, int]]] = {
            m: [] for m in range(self.num_messages)}
        for i in self.terminal_nodes:
            n = self.nodes[i]
            if n.window:
                continue
            per_msg.setdefault(n.msg_idx, []).append((n.offset, n.nbytes))
        for msg_idx, intervals in per_msg.items():
            intervals.sort()
            pos = 0
            for off, size in intervals:
                if off != pos:
                    raise ValueError(
                        f"gap/overlap at byte {pos} (chunk at {off})")
                if size <= 0:
                    raise ValueError("empty chunk")
                pos = off + size
            if nbytes_per_message is not None:
                want = nbytes_per_message[msg_idx]
                if pos != want:
                    raise ValueError(
                        f"coverage ends at {pos}, message is {want}")
        # (4) buffer def-use consistency, on buffer edges
        for e in self.edges:
            if e.kind != BUFFER_EDGE:
                continue
            src_n, dst_n = self.nodes[e.src], self.nodes[e.dst]
            if isinstance(src_n, ComputeNode) and isinstance(
                    dst_n, ComputeNode):
                if not set(src_n.results) & set(dst_n.operands):
                    raise ValueError(
                        f"buffer edge {e.src}->{e.dst} names no shared "
                        f"buffer between producer results and consumer "
                        f"operands")
                continue
            if not self.messages:
                raise ValueError(
                    "buffer edge touches a copy node but the graph has "
                    "no messages table")
            if isinstance(src_n, ComputeNode):
                if not isinstance(dst_n, CopyNode) or dst_n.hop_idx != 0:
                    raise ValueError(
                        f"compute->copy buffer edge {e.src}->{e.dst} "
                        f"must land on a first-hop copy")
                payload, _ = self.messages[dst_n.msg_idx]
                if payload not in src_n.results:
                    raise ValueError(
                        f"copy {e.dst} reads payload buffer {payload} "
                        f"that compute {e.src} does not write")
            elif isinstance(dst_n, ComputeNode):
                if e.src not in self.terminal_nodes:
                    raise ValueError(
                        f"copy->compute buffer edge {e.src}->{e.dst} "
                        f"must leave a terminal copy")
                _, result = self.messages[src_n.msg_idx]
                if result not in dst_n.operands:
                    raise ValueError(
                        f"compute {e.dst} does not read reception "
                        f"buffer {result} written by copy {e.src}")
            else:
                raise ValueError(
                    f"buffer edge {e.src}->{e.dst} joins two copy nodes")


@lru_cache(maxsize=256)
def lower(obj: TransferPlan | TransferGroup, window: int = 1
          ) -> TransferGraph:
    """THE lowering pass: plan/group → copy-node DAG.

    One :class:`CopyNode` per chunk per hop per window round, emitted in
    the paper's Algorithm 1 **round-robin dispatch order**: window-major,
    then message, then chunk *waves* interleaved across paths (chunk 0 of
    every path, chunk 1 of every path, …), hops innermost. This emission
    order is a valid topological order and is exactly what the
    ``round_robin`` scheduler pass (:mod:`repro.comm.passes`) reproduces
    — applying it to a fresh lowering is the identity (same digest).
    Edges: hop order within each chunk (``"hop"``), and replay ordering
    between a chunk's last hop in round *w* and its first hop in round
    *w+1* (``"window"``). So for any lowering::

        num_nodes == window * Σ_paths chunks·hops
        num_edges == window * Σ_chunks (hops−1) + (window−1) · Σ chunks

    Plans and groups are frozen/hashable, so lowerings are memoized —
    the engine, the model, and the validator all get the *same* graph
    object for the same source, and the invariant checks
    (:meth:`TransferGraph.validate`) apply to the one graph they share.
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if isinstance(obj, TransferPlan):
        plans: tuple[TransferPlan, ...] = (obj,)
        topo_name = obj.topology_name
        num_messages = 1
    else:
        plans = tuple(obj.plans)
        topo_name = obj.topology_name
        num_messages = len(plans)

    nodes: list[CopyNode] = []
    edges: list[DepEdge] = []
    # (msg, path, chunk) → (first-hop idx, last-hop idx) of previous window
    prev_round: dict[tuple[int, int, int], tuple[int, int]] = {}
    for w in range(window):
        for m_idx, plan in enumerate(plans):
            flow = (plan.src, plan.dst)
            per_path = [(pa.route.directional_links(), pa.chunk_bounds())
                        for pa in plan.paths]
            waves = max((len(bounds) for _, bounds in per_path), default=0)
            for c_idx in range(waves):
                for p_idx, (links, bounds) in enumerate(per_path):
                    if c_idx >= len(bounds):
                        continue
                    off, size = bounds[c_idx]
                    first = len(nodes)
                    for h_idx, link in enumerate(links):
                        idx = len(nodes)
                        nodes.append(CopyNode(
                            flow, m_idx, p_idx, c_idx, h_idx, w,
                            link, off, size))
                        if h_idx:
                            edges.append(DepEdge(idx - 1, idx, HOP_EDGE))
                    last = len(nodes) - 1
                    chunk_key = (m_idx, p_idx, c_idx)
                    if chunk_key in prev_round:
                        edges.append(DepEdge(prev_round[chunk_key][1],
                                             first, WINDOW_EDGE))
                    prev_round[chunk_key] = (first, last)
    return TransferGraph(tuple(nodes), tuple(edges), window,
                         num_messages, topo_name)
