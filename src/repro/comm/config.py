"""CommConfig — the single typed configuration for the comm session API.

Absorbs the ``REPRO_MP_*`` environment parsing that used to be inlined in
``repro/core/paths.py`` (and ``REPRO_PLAN_CACHE_SIZE`` from
``repro/core/plan_cache.py``). New code constructs a :class:`CommConfig`
explicitly (or via :meth:`CommConfig.from_env`) and hands it to a
:class:`~repro.comm.session.CommSession`; the environment variables remain
supported only through :meth:`from_env` (paper §4.4 "Environment
Configuration").

Environment variables read by :meth:`from_env`:

* ``REPRO_MP_MAX_PATHS``   — max concurrent paths (default 4)
* ``REPRO_MP_CHUNK_BYTES`` — target chunk size (default 1 MiB, paper §4.3)
* ``REPRO_MP_MAX_CHUNKS``  — max chunks per path (default 8)
* ``REPRO_MP_HOST_PATH``   — "1"/"0" include the host-staged path
* ``REPRO_MP_THRESHOLD``   — multipath engagement threshold (default 2 MiB,
  paper §5.3: below it the single direct path wins)
* ``REPRO_MP_WINDOW``      — default message window for ``session.send``
* ``REPRO_MP_POLICY``      — path policy name (greedy | round_robin | tuner)
* ``REPRO_MP_SCHEDULE``    — chunk-interleaving scheduler applied to the
  lowered transfer graph (round_robin | depth_first | critical_path |
  overlap | auto; DESIGN.md §2.2)
* ``REPRO_MP_FASTPATH``    — "1"/"0" steady-state dispatch fast path
  (default on; DESIGN.md §2.3): repeat traffic skips planner, lowering,
  scheduler pass, validation, and digest entirely
* ``REPRO_MP_VALIDATE``    — "miss" (default) validates plans/graphs only
  when the fast path misses; "always" re-validates on every dispatch,
  fast-path hits included (the §4.5 safety escape hatch)
* ``REPRO_PLAN_CACHE_SIZE``— compiled-plan LRU capacity (default 64)
* ``REPRO_MP_TELEMETRY``   — "1"/"0" per-dispatch stage-timing telemetry
  (default off; DESIGN.md §4.4c — off costs one boolean per dispatch)
* ``REPRO_MP_TELEMETRY_CAPACITY`` — telemetry ring-buffer size (2048)
* ``REPRO_MP_PROFILE_DIR`` — calibration-profile directory; when set, the
  session loads the profile matching its topology digest on init and
  ``session.calibrate(persist=True)`` writes there
* ``REPRO_MP_COLLECTIVES`` — all-reduce layout on hierarchical
  topologies (auto | flat | two_level; DESIGN §3.1 — ``auto`` lets the
  §4.4 tier model arbitrate, flat is forced on single-island topologies)
* ``REPRO_MP_HEALTH``      — "1"/"0" link-health monitoring + degraded-mode
  dispatch (default on; DESIGN §4.6 — off skips monitor construction; the
  healthy dispatch path costs one boolean either way)
* ``REPRO_MP_FAULTS``      — chaos schedule applied by a
  :class:`repro.comm.health.FaultInjector`
  (e.g. ``"fail@12:0-1;restore@40:0-1"``; empty = no injector)
* ``REPRO_MP_DROOP_THRESHOLD`` — measured/modeled residual ratio above
  which a sample counts as a droop breach (default 2.0)
* ``REPRO_MP_DROOP_SAMPLES``   — consecutive breaches before quarantine (3)
* ``REPRO_MP_RETRY_LIMIT``     — dispatch retries per ladder rung (2)
* ``REPRO_MP_BACKOFF_S``       — base of the bounded exponential retry
  backoff, seconds (default 0.001; doubles per retry, capped at 50 ms)
* ``REPRO_MP_PROBE_HEALTHY``   — consecutive healthy probes to readmit (2)
* ``REPRO_MP_PROBE_INTERVAL``  — dispatches between automatic probes (16)
* ``REPRO_MP_RECOVERY_RATIO``  — served/nominal bandwidth floor a probe
  accepts as healthy (default 0.5)
"""

from __future__ import annotations

import dataclasses
import os

_MiB = 1 << 20

#: Policy names accepted by :func:`repro.comm.policy.make_policy`.
POLICY_NAMES = ("greedy", "round_robin", "tuner")

#: Scheduler (graph-pass) names accepted by
#: :func:`repro.comm.passes.make_schedule` — ``round_robin`` is today's
#: lowering order (identity pass), ``overlap`` list-schedules over the
#: resource-lane makespan model to hide copies behind compute, ``auto``
#: model-scores every candidate order and picks the winner before
#: compiling (DESIGN.md §2.2).
SCHEDULE_NAMES = ("round_robin", "depth_first", "critical_path",
                  "overlap", "auto")

#: All-reduce layout names (DESIGN §3.1): ``auto`` lets the §4.4 tier
#: model pick per topology, ``flat``/``two_level`` force the layout (the
#: two-level decomposition only differs on >1-island topologies).
COLLECTIVE_STRATEGIES = ("auto", "flat", "two_level")

#: Validation modes for compiled dispatch (DESIGN.md §4.5): ``miss``
#: validates a plan/graph only when it is (re)built — the fast path trusts
#: epoch-stamped entries — while ``always`` re-runs ``validate_plan`` and
#: ``graph.validate()`` on every dispatch, fast-path hits included.
VALIDATE_MODES = ("miss", "always")


def _env_int(name: str, default: int) -> int:
    try:
        return int(os.environ.get(name, default))
    except ValueError:
        return default


def _env_bool(name: str, default: bool) -> bool:
    v = os.environ.get(name)
    if v is None:
        return default
    return v.strip() not in ("0", "false", "False", "")


def _env_float(name: str, default: float) -> float:
    try:
        return float(os.environ.get(name, default))
    except ValueError:
        return default


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Frozen configuration for one :class:`~repro.comm.session.CommSession`.

    The defaults reproduce the paper's tuned settings (§4.3/§4.4): up to 4
    concurrent paths, ~1 MiB pipeline chunks capped at 8 per path, host path
    off, multipath engaging at 2 MiB.
    """

    max_paths: int = 4
    chunk_bytes: int = _MiB
    max_chunks: int = 8
    include_host: bool = False
    multipath_threshold: int = 2 * _MiB
    window: int = 1
    policy: str = "greedy"
    schedule: str = "round_robin"
    fastpath: bool = True
    validate: str = "miss"
    cache_capacity: int = 64
    axis_name: str = "dev"
    telemetry: bool = False
    telemetry_capacity: int = 2048
    profile_dir: str = ""
    collective_strategy: str = "auto"
    health: bool = True
    faults: str = ""
    droop_threshold: float = 2.0
    droop_samples: int = 3
    retry_limit: int = 2
    backoff_base_s: float = 0.001
    probe_healthy: int = 2
    probe_interval: int = 16
    recovery_ratio: float = 0.5

    def __post_init__(self) -> None:
        if self.max_paths < 1:
            raise ValueError(f"max_paths must be >= 1, got {self.max_paths}")
        if self.chunk_bytes < 1:
            raise ValueError(
                f"chunk_bytes must be >= 1, got {self.chunk_bytes}")
        if self.max_chunks < 1:
            raise ValueError(
                f"max_chunks must be >= 1, got {self.max_chunks}")
        if self.multipath_threshold < 0:
            raise ValueError("multipath_threshold must be >= 0, got "
                             f"{self.multipath_threshold}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.cache_capacity < 1:
            raise ValueError(
                f"cache_capacity must be >= 1, got {self.cache_capacity}")
        if self.policy not in POLICY_NAMES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"expected one of {POLICY_NAMES}")
        if self.schedule not in SCHEDULE_NAMES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"expected one of {SCHEDULE_NAMES}")
        if self.validate not in VALIDATE_MODES:
            raise ValueError(f"unknown validate mode {self.validate!r}; "
                             f"expected one of {VALIDATE_MODES}")
        if not self.axis_name:
            raise ValueError("axis_name must be non-empty")
        if self.telemetry_capacity < 1:
            raise ValueError("telemetry_capacity must be >= 1, got "
                             f"{self.telemetry_capacity}")
        if self.collective_strategy not in COLLECTIVE_STRATEGIES:
            raise ValueError(
                f"unknown collective strategy {self.collective_strategy!r}; "
                f"expected one of {COLLECTIVE_STRATEGIES}")
        if self.droop_threshold <= 0:
            raise ValueError("droop_threshold must be > 0, got "
                             f"{self.droop_threshold}")
        if self.droop_samples < 1:
            raise ValueError(
                f"droop_samples must be >= 1, got {self.droop_samples}")
        if self.retry_limit < 0:
            raise ValueError(
                f"retry_limit must be >= 0, got {self.retry_limit}")
        if self.backoff_base_s < 0:
            raise ValueError(
                f"backoff_base_s must be >= 0, got {self.backoff_base_s}")
        if self.probe_healthy < 1:
            raise ValueError(
                f"probe_healthy must be >= 1, got {self.probe_healthy}")
        if self.probe_interval < 1:
            raise ValueError(
                f"probe_interval must be >= 1, got {self.probe_interval}")
        if not 0.0 < self.recovery_ratio <= 1.0:
            raise ValueError("recovery_ratio must be in (0, 1], got "
                             f"{self.recovery_ratio}")

    @classmethod
    def from_env(cls, **overrides) -> "CommConfig":
        """Build a config from the legacy ``REPRO_MP_*`` environment.

        Keyword ``overrides`` take precedence over the environment, which
        takes precedence over the defaults.
        """
        values = dict(
            max_paths=_env_int("REPRO_MP_MAX_PATHS", cls.max_paths),
            chunk_bytes=_env_int("REPRO_MP_CHUNK_BYTES", cls.chunk_bytes),
            max_chunks=_env_int("REPRO_MP_MAX_CHUNKS", cls.max_chunks),
            include_host=_env_bool("REPRO_MP_HOST_PATH", cls.include_host),
            multipath_threshold=_env_int("REPRO_MP_THRESHOLD",
                                         cls.multipath_threshold),
            window=_env_int("REPRO_MP_WINDOW", cls.window),
            policy=os.environ.get("REPRO_MP_POLICY", cls.policy),
            schedule=os.environ.get("REPRO_MP_SCHEDULE", cls.schedule),
            fastpath=_env_bool("REPRO_MP_FASTPATH", cls.fastpath),
            validate=os.environ.get("REPRO_MP_VALIDATE", cls.validate),
            cache_capacity=_env_int("REPRO_PLAN_CACHE_SIZE",
                                    cls.cache_capacity),
            telemetry=_env_bool("REPRO_MP_TELEMETRY", cls.telemetry),
            telemetry_capacity=_env_int("REPRO_MP_TELEMETRY_CAPACITY",
                                        cls.telemetry_capacity),
            profile_dir=os.environ.get("REPRO_MP_PROFILE_DIR",
                                       cls.profile_dir),
            collective_strategy=os.environ.get("REPRO_MP_COLLECTIVES",
                                               cls.collective_strategy),
            health=_env_bool("REPRO_MP_HEALTH", cls.health),
            faults=os.environ.get("REPRO_MP_FAULTS", cls.faults),
            droop_threshold=_env_float("REPRO_MP_DROOP_THRESHOLD",
                                       cls.droop_threshold),
            droop_samples=_env_int("REPRO_MP_DROOP_SAMPLES",
                                   cls.droop_samples),
            retry_limit=_env_int("REPRO_MP_RETRY_LIMIT", cls.retry_limit),
            backoff_base_s=_env_float("REPRO_MP_BACKOFF_S",
                                      cls.backoff_base_s),
            probe_healthy=_env_int("REPRO_MP_PROBE_HEALTHY",
                                   cls.probe_healthy),
            probe_interval=_env_int("REPRO_MP_PROBE_INTERVAL",
                                    cls.probe_interval),
            recovery_ratio=_env_float("REPRO_MP_RECOVERY_RATIO",
                                      cls.recovery_ratio),
        )
        values.update(overrides)
        return cls(**values)

    def replace(self, **changes) -> "CommConfig":
        return dataclasses.replace(self, **changes)
