"""Per-dispatch timeline telemetry — the measurement half of DESIGN §4.4c.

The §4.4 analytic model arbitrates schedules and path splits from
calibration constants; this module records what the machine *actually*
did so :mod:`repro.comm.calibration` can fit those terms from evidence.
The engine attributes each dispatch's wall time to pipeline stages
(plan / lower / schedule / compile / staging / launch / execute) in a
:class:`StageTimings`, tags it with the route/chunk/schedule identity it
ran under (:class:`DispatchSample`), and appends it to a ring-buffered
:class:`TimelineRecorder`.

Contract (the observability invariant): telemetry is *passive*. Samples
are measurements only — they must never feed cache keys, plan digests,
or epoch tokens, and recording must preserve dispatch behaviour exactly.
When the recorder is disabled (the default; enable with
``REPRO_MP_TELEMETRY=1``) the engine's only cost is one boolean check
per dispatch, which is what keeps the §2.3 fast path's setup cost
unchanged — the guarantee ``benchmarks/bench_calibration.py`` and the
CI smoke assertion watch.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

from repro.comm.config import _env_bool

#: Environment toggle read by :class:`TimelineRecorder` when ``enabled``
#: is not given explicitly. Off by default — the zero-overhead contract.
TELEMETRY_ENV = "REPRO_MP_TELEMETRY"

#: Default ring capacity: old samples are dropped, never the dispatch.
DEFAULT_CAPACITY = 2048

#: Stage names in pipeline order — the attribution schema (§4.4c).
STAGES = ("plan", "lower", "schedule", "compile", "staging", "launch",
          "execute")


@dataclasses.dataclass
class StageTimings:
    """Wall time of one dispatch attributed to pipeline stages, in ns.

    The attribution invariant: every field is measured around exactly one
    stage of the §2.3 dispatch pipeline, so ``plan+lower+schedule+compile``
    is the (fast-path-skippable) setup cost and ``staging+launch+execute``
    the per-dispatch cost. Fast-path hits preserve zeros in the setup
    fields — that is evidence, not a gap. Mutable on purpose: the engine
    fills stages in as the dispatch proceeds, then freezes the result
    into a :class:`DispatchSample`.
    """

    plan_ns: int = 0      # planner: route enumeration + path split
    lower_ns: int = 0     # graph lowering (plan -> copy-node DAG)
    schedule_ns: int = 0  # scheduler pass (§2.2 pipeline)
    compile_ns: int = 0   # jit trace + lower + compile (build_ns)
    staging_ns: int = 0   # pooled staging-buffer preparation
    launch_ns: int = 0    # dispatch call until control returns
    execute_ns: int = 0   # block_until_ready tail after dispatch

    @property
    def total_ns(self) -> int:
        """Sum over every stage — the invariant check that attribution
        covers the dispatch: stages are disjoint, so their sum is the
        attributed wall time."""
        return (self.plan_ns + self.lower_ns + self.schedule_ns
                + self.compile_ns + self.staging_ns + self.launch_ns
                + self.execute_ns)

    def as_dict(self) -> dict[str, int]:
        """Stage name -> ns, in :data:`STAGES` order — the stable schema
        contract that ``session.describe()`` / ``--json`` benchmark rows
        serialize."""
        return {name: getattr(self, f"{name}_ns") for name in STAGES}


@dataclasses.dataclass(frozen=True)
class DispatchSample:
    """One dispatch's identity + measured stage timings (frozen record).

    ``routes`` is the per-message, per-path shape the calibration fitter
    prices: each path is ``(directional_links, nbytes, num_chunks)``.
    The identity invariant: two samples with equal :attr:`signature` ran
    the *same* routed/chunked/scheduled transfer, so the fitter may pool
    them (warmup dropping, medians) — the sample must therefore preserve
    everything the §4.4 model needs to re-price it, and nothing tied to
    live objects (no plans, no graphs, no topology references).

    ``compute`` is the compute-node identity of a captured-step dispatch
    — one ``(kernel, flops, cost_ns)`` triple per
    :class:`~repro.comm.graph.ComputeNode` — and is part of
    :attr:`signature`, so the calibration fitter can never pool a
    captured-step sample (whose execute time includes kernel work) with
    a pure-comm sample of the same route shape.
    """

    routes: tuple[tuple[tuple[tuple[tuple[int, int], ...], int, int],
                        ...], ...]
    nbytes: int
    num_nodes: int
    window: int
    schedule: str
    stages: StageTimings
    fastpath_hit: bool
    compute: tuple[tuple[str, int, int], ...] = ()

    @property
    def signature(self) -> tuple:
        """Hashable pooling key ``(routes, window, schedule, compute)``
        — the contract key the fitter groups warmup/median statistics
        by. Compute identity keeps captured-step samples apart from
        pure-comm samples with the same routes (§4.4c invariant)."""
        return (self.routes, self.window, self.schedule, self.compute)

    @property
    def num_paths(self) -> int:
        """Total path count across the sample's messages (validates the
        §4.4 sync-per-path pricing against the recorded shape)."""
        return sum(len(msg) for msg in self.routes)

    @property
    def links(self) -> tuple[tuple[int, int], ...]:
        """Sorted distinct directional links the sample exercised — the
        per-link attribution domain the bandwidth fitter updates."""
        seen = {ln for msg in self.routes for (lns, _, _) in msg
                for ln in lns}
        return tuple(sorted(seen))

    @property
    def measured_s(self) -> float:
        """Measured end-to-end dispatch seconds (launch + execute) — the
        quantity modeled estimates are validated against."""
        return (self.stages.launch_ns + self.stages.execute_ns) / 1e9


class TimelineRecorder:
    """Ring-buffered dispatch-sample sink with a hard zero-cost-off contract.

    * **Off** (default, or ``REPRO_MP_TELEMETRY`` falsy): :attr:`enabled`
      is ``False`` and :meth:`record` is never even called by the engine
      — the dispatch path pays one boolean check. This invariant is what
      the CI overhead smoke assertion enforces.
    * **On**: samples append to a bounded ``deque``; when full, the
      *oldest* sample is dropped (counted in :attr:`dropped`) so memory
      stays bounded on long-running sessions. Recording never raises into
      the dispatch path and never mutates the sample.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 enabled: bool | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = (_env_bool(TELEMETRY_ENV, False)
                        if enabled is None else bool(enabled))
        self._ring: deque[DispatchSample] = deque(maxlen=capacity)
        self._kernels: dict[str, deque[float]] = {}
        self.recorded = 0
        self.dropped = 0
        #: Optional observer fired with each recorded sample — the hook
        #: the health monitor's droop detection rides (DESIGN §4.6). It
        #: runs AFTER the enabled check, preserving the zero-cost-off
        #: contract, and its exceptions are swallowed: observation must
        #: never fail a dispatch.
        self.on_record = None

    def record(self, sample: DispatchSample) -> None:
        """Append one sample (no-op while disabled). Preserves the ring
        bound: at capacity the oldest sample is evicted and counted in
        :attr:`dropped` — the dispatch is never blocked or failed. Fires
        :attr:`on_record` (when set) with the sample; observer errors
        are contained here."""
        if not self.enabled:
            return
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1
        self._ring.append(sample)
        self.recorded += 1
        if self.on_record is not None:
            try:
                self.on_record(sample)
            except Exception:
                pass  # observation must never fail the dispatch

    def record_kernel(self, name: str, execute_ns: float) -> None:
        """Append one per-kernel execute measurement (no-op while
        disabled — the same zero-cost-off contract as :meth:`record`).
        Measurements accumulate per kernel name in bounded rings
        (``capacity`` each, oldest dropped) so the calibration fitter
        can replace the ``COMPUTE_GFLOPS`` guess with a fitted
        per-kernel compute term. Non-positive durations are ignored —
        a clock misread must never poison the fit."""
        if not self.enabled:
            return
        if execute_ns <= 0:
            return
        ring = self._kernels.get(name)
        if ring is None:
            ring = self._kernels[name] = deque(maxlen=self.capacity)
        ring.append(float(execute_ns))

    def kernel_samples(self) -> dict[str, tuple[float, ...]]:
        """Snapshot ``{kernel name: (execute_ns, ...)}`` oldest first —
        the evidence channel ``CalibrationFitter.fit(kernels=...)``
        consumes. Deliberately separate from :meth:`samples`: kernel
        timings are compute-side measurements and must never pool with
        transfer-stage :class:`DispatchSample` records (§4.4c)."""
        return {name: tuple(ring) for name, ring in self._kernels.items()
                if ring}

    def kernel_cost_ns(self, name: str) -> float:
        """Median recorded execute time for ``name`` in ns, or ``0.0``
        when nothing was recorded — the value capture adopters stamp
        into ``ComputeNode.cost_ns`` so the lane model prices measured
        rather than guessed kernel durations."""
        ring = self._kernels.get(name)
        if not ring:
            return 0.0
        ordered = sorted(ring)
        mid = len(ordered) // 2
        if len(ordered) % 2:
            return ordered[mid]
        return (ordered[mid - 1] + ordered[mid]) / 2.0

    def samples(self) -> tuple[DispatchSample, ...]:
        """Snapshot of retained samples, oldest first (chronological —
        the order the fitter's exponential-decay update contract
        requires)."""
        return tuple(self._ring)

    def clear(self) -> None:
        """Drop retained samples and zero the counters (the windowed
        ``stats(reset=True)`` semantics; capacity/enabled preserved).
        Per-kernel execute rings are cleared too."""
        self._ring.clear()
        self._kernels.clear()
        self.recorded = 0
        self.dropped = 0

    def stats(self) -> dict:
        """Counter snapshot ``{enabled, capacity, retained, recorded,
        dropped}`` — the stable schema ``session.stats()`` embeds."""
        return {"enabled": self.enabled, "capacity": self.capacity,
                "retained": len(self._ring), "recorded": self.recorded,
                "dropped": self.dropped}

    def extend(self, samples: Iterable[DispatchSample]) -> None:
        """Bulk :meth:`record` (test/benchmark convenience; preserves
        the same ring-bound and disabled-no-op contract)."""
        for s in samples:
            self.record(s)

    def __len__(self) -> int:
        return len(self._ring)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"TimelineRecorder(enabled={self.enabled}, "
                f"retained={len(self._ring)}/{self.capacity}, "
                f"recorded={self.recorded})")
