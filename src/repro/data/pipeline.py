"""Synthetic, deterministic, shardable data pipeline.

Produces LM batches (tokens/labels/mask) or audio-frontend batches
(features/labels) with content that is a pure function of ``(seed, step)`` —
so a restarted/elastically-rescaled job replays the exact stream from its
checkpointed step (the fault-tolerance tests rely on this bit-for-bit
determinism). A background prefetch thread keeps ``prefetch`` batches ahead
of the training loop.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic LM task: token t+1 = (a*t + b) mod vocab on easy positions,
    # noise elsewhere — learnable but non-trivial.
    noise_prob: float = 0.2


class SyntheticDataset:
    """Deterministic synthetic stream for an architecture."""

    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step) → numpy batch."""
        d, c = self.data, self.cfg
        rng = np.random.RandomState((d.seed * 1_000_003 + step) % 2**31)
        b, s = d.global_batch, d.seq_len
        if c.frontend == "audio":
            feats = rng.randn(b, s, c.frontend_dim).astype(np.float32)
            labels = rng.randint(0, c.vocab_size, (b, s)).astype(np.int32)
            return {"features": feats, "labels": labels,
                    "mask": np.ones((b, s), np.float32)}
        vocab = c.vocab_size
        a = rng.randint(1, min(vocab, 641))
        start = rng.randint(0, vocab, (b, 1))
        seq = (start + a * np.arange(s + 1)[None, :]) % vocab
        noise = rng.rand(b, s + 1) < d.noise_prob
        seq = np.where(noise, rng.randint(0, vocab, (b, s + 1)), seq)
        return {"tokens": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32),
                "mask": np.ones((b, s), np.float32)}

    def iter_batches(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Background-thread prefetcher with device placement."""

    def __init__(self, dataset: SyntheticDataset, sharding=None,
                 start_step: int = 0, prefetch: int = 2):
        self.dataset = dataset
        self.sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch_at(step)
            if self.sharding is not None:
                batch = {k: jax.device_put(v, self.sharding[k])
                         for k, v in batch.items()}
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
