from repro.optim.adamw import (  # noqa: F401
    OptimConfig, apply_updates, global_norm, init_opt_state, lr_schedule,
    opt_state_shapes)
from repro.optim.compression import (  # noqa: F401
    compressed_psum, compressed_psum_tree, compressed_psum_with_feedback)
