"""AdamW with dtype-configurable moment storage (fp32 / bf16 / int8).

The int8 mode stores both moments as per-tensor absmax-quantized int8 with a
float32 scale — the standard 8-bit-Adam memory trick that the kimi-k2 (1T)
configuration needs to fit 16 GB/chip at 256 chips (6 bytes/param total
instead of 10). Quantization error is bounded by the per-step re-quantize
(state is dequantized, updated in fp32, re-quantized each step).

Moment trees mirror the parameter sharding exactly (the launcher applies
the same PartitionSpecs), so optimizer state is always FSDP/TP-sharded
alongside its parameters.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8


def lr_schedule(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to ``min_lr_ratio``."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    decay_steps = jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * jnp.where(step < cfg.warmup_steps, warm, cos)


# -- int8 moment codec ---------------------------------------------------------
def _quantize(x: jax.Array) -> dict:
    if x.size == 0:  # zero-layer probe configs stack empty leaves
        return {"q": jnp.zeros(x.shape, jnp.int8),
                "scale": jnp.ones((), jnp.float32)}
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    return {"q": jnp.round(x / scale).astype(jnp.int8),
            "scale": scale.astype(jnp.float32)}


def _dequantize(q: dict) -> jax.Array:
    return q["q"].astype(jnp.float32) * q["scale"]


def _moment_zeros(leaf: jax.Array, dtype: str):
    if dtype == "int8":
        return {"q": jnp.zeros(leaf.shape, jnp.int8),
                "scale": jnp.zeros((), jnp.float32)}
    return jnp.zeros(leaf.shape, jnp.dtype(dtype))


def _moment_read(m, dtype: str) -> jax.Array:
    if dtype == "int8":
        return _dequantize(m)
    return m.astype(jnp.float32)


def _moment_write(x: jax.Array, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.dtype(dtype))


def _is_moment_leaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def init_opt_state(params, cfg: OptimConfig) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda l: _moment_zeros(l, cfg.moment_dtype), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_shapes(abstract_params, cfg: OptimConfig):
    return jax.eval_shape(lambda p: init_opt_state(p, cfg), abstract_params)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                     tree), jnp.float32(0.0))
    return jnp.sqrt(sq)


def apply_updates(params, grads, state, cfg: OptimConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    md = cfg.moment_dtype

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = b1 * _moment_read(m, md) + (1 - b1) * g
        v_f = b2 * _moment_read(v, md) + (1 - b2) * jnp.square(g)
        mh = m_f / bc1
        vh = v_f / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, _moment_write(m_f, md), _moment_write(v_f, md)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
