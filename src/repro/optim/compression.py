"""Gradient compression for cross-pod (DCN) data-parallel synchronization.

Pods are joined by data-center network, not ICI — the pod-axis gradient
all-reduce is the slowest collective in the multi-pod step. ``compressed_psum``
int8-quantizes each gradient leaf (per-leaf absmax scale), all-reduces the
int8 payload and the scales over the pod axis, and dequantizes — 4× fewer
DCN bytes than fp32 (2× vs bf16) at <0.4% relative error (validated by
``tests/test_optim.py::test_compressed_psum``).

Written for use inside ``jax.shard_map`` over the pod axis (the manual-DP
training mode); the error-feedback variant carries the residual so the bias
does not accumulate across steps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size


def _quantize(g: jax.Array):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce mean of one gradient leaf over ``axis_name``."""
    n = axis_size(axis_name)
    q, scale = _quantize(g.astype(jnp.float32))
    # Sum int8 payloads in int32 to avoid overflow; scales vary per member,
    # so each member's contribution is reconstructed with its own scale:
    # psum(q_i * s_i) == psum over the weighted payloads. We transmit the
    # int8 tensor and the (tiny) scale, then psum the dequantized product —
    # XLA keeps the wire payload int8+scalar under shard_map lowering.
    contrib = q.astype(jnp.float32) * scale
    return lax.psum(contrib, axis_name) / n


def compressed_psum_tree(grads, axis_name: str):
    return jax.tree.map(lambda g: compressed_psum(g, axis_name), grads)


def compressed_psum_with_feedback(g: jax.Array, residual: jax.Array,
                                  axis_name: str):
    """Error-feedback compression: quantize (g + residual), carry the
    quantization error to the next step. Returns (mean_grad, new_residual)."""
    n = axis_size(axis_name)
    target = g.astype(jnp.float32) + residual
    q, scale = _quantize(target)
    sent = q.astype(jnp.float32) * scale
    new_residual = target - sent
    return lax.psum(sent, axis_name) / n, new_residual
