"""Pure-jnp oracle for the multipath DMA kernel.

Semantics of one plan execution over the device-stacked buffer
``x: (num_devices, nelems)``:

* destination device ends with the source's message,
* every other device keeps its own buffer (identity — the kernel's local
  init copy),
* chunk moves are also replayed hop-by-hop (``replay_schedule``) so property
  tests can check the §4.5 invariants at every intermediate step.
"""

from __future__ import annotations

import numpy as np

from repro.comm.plan import TransferPlan
from repro.core.pipelining import build_schedule


def multipath_transfer_ref(x: np.ndarray, plan: TransferPlan) -> np.ndarray:
    """End-state oracle: x -> y with y[dst] = x[src], rest identity."""
    y = np.array(x, copy=True)
    y[plan.dst] = x[plan.src]
    return y


def replay_schedule(x: np.ndarray, plan: TransferPlan,
                    itemsize: int) -> np.ndarray:
    """Hop-by-hop replay through explicit staging buffers.

    Validates that executing the chunk schedule literally (each chunk moving
    through its route's staging stops) reconstructs the message — i.e. the
    schedule itself is correct, independent of the kernel.
    """
    y = np.array(x, copy=True)
    stage: dict[tuple[int, int, int], np.ndarray] = {}
    for task in build_schedule(plan):
        off = task.offset // itemsize
        size = task.nbytes // itemsize
        payload = x[plan.src, off:off + size]
        for hop_idx, (a, b) in enumerate(task.hops):
            key = (task.path_idx, task.chunk_idx, hop_idx)
            if hop_idx == 0:
                moving = payload
            else:
                moving = stage[(task.path_idx, task.chunk_idx, hop_idx - 1)]
            if hop_idx == len(task.hops) - 1:
                y[b, off:off + size] = moving
            else:
                stage[key] = moving.copy()
    return y
