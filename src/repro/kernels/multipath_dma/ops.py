"""Jit'd wrapper for the multipath DMA kernel (kernel-backed transfers).

``multipath_dma_transfer`` is the drop-in kernel-backed equivalent of
``repro.core.multipath.multipath_send_local``'s engine: same plans, same
cache key space, but the copy nodes execute as Pallas remote DMAs instead of
XLA collective-permutes. On CPU it runs the TPU interpreter
(``pltpu.InterpretParams``); on TPU set ``interpret=False``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.comm.plan import TransferPlan
from repro.kernels.multipath_dma.kernel import build_multipath_dma

AXIS = "dev"


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def multipath_dma_transfer(x: jax.Array, plan: TransferPlan,
                           mesh: jax.sharding.Mesh, *,
                           interpret: bool | None = None) -> jax.Array:
    """Execute ``plan`` on ``x: (num_devices, nelems)`` sharded over ``dev``.

    Returns the same-shape array with ``y[dst] = x[src]`` and identity
    elsewhere.
    """
    if interpret is None:
        interpret = _is_cpu()
    num_devices = mesh.devices.size
    nelems = x.shape[-1]
    inner = build_multipath_dma(plan, nelems, x.dtype, num_devices,
                                axis_name=AXIS, interpret=interpret)

    def local(xl):  # (1, nelems) per device
        return inner(xl[0])[None]

    fn = jax.jit(shard_map(local, mesh=mesh, in_specs=P(AXIS),
                           out_specs=P(AXIS), check_vma=False))
    x = jax.device_put(x, NamedSharding(mesh, P(AXIS)))
    return fn(x)


def captured_multipath_dma(cap, x, plan: TransferPlan, num_devices: int, *,
                           name: str = "multipath_dma",
                           axis_name: str = AXIS, telemetry=None,
                           interpret: bool | None = None):
    """Record the kernel-backed multipath DMA on a ``session.capture``
    step.

    ``x`` is a capture ref with local shape ``(nelems,)``; returns the
    same-shape ref with ``y[dst] = x[src]`` (identity elsewhere),
    executing ``plan``'s copy schedule as Pallas remote DMAs inside the
    captured program. The result spec is declared explicitly (``out=``)
    because the kernel's axis collectives cannot be abstractly
    evaluated outside the mesh. ``cost_ns`` is stamped from
    ``telemetry``'s recorded median for ``name`` when a recorder is
    passed, so the lane model prices the DMA kernel's measured
    duration.
    """
    if interpret is None:
        interpret = _is_cpu()
    from repro.comm.capture import BufferSpec
    spec = cap.buffers[cap._resolve(x)]
    (nelems,) = spec.shape
    inner = build_multipath_dma(plan, nelems, jnp.dtype(spec.dtype),
                                num_devices, axis_name=axis_name,
                                interpret=interpret)
    cost = int(telemetry.kernel_cost_ns(name)) if telemetry is not None \
        else 0
    return cap.kernel(inner, x, name=name,
                      out=BufferSpec((nelems,), spec.dtype),
                      cost_ns=cost)
