"""Pallas TPU kernel: plan-driven multi-path chunked remote-DMA transfer.

This is the TPU-native realization of the paper's CUDA Graph (Fig. 5): the
:class:`~repro.core.paths.TransferPlan` is compiled into ONE kernel whose
DMA ops are the graph's copy nodes and whose semaphore waits are its
dependency edges:

* a **direct path** chunk is one ``make_async_remote_copy`` src→dst
  (= one ``PeerToPeerCopy`` node, Alg. 2),
* a **staged path** chunk is hop-1 src→staging-VMEM-on-via plus hop-2
  via→dst, where hop-2 waits only on its own hop-1 recv semaphore
  (= ``StageGPUCopy`` with the Alg. 2 line-19 dependency),
* per-path semaphore pairs play the role of the paper's per-path CUDA
  streams: chunks on different paths proceed fully independently.

The kernel body is SPMD over the mesh axis: every device executes it, and
``pl.when(my_id == …)`` selects the src/via/dst roles (senders start DMAs,
receivers wait on recv semaphores). A global barrier after the local
init-copy guarantees no remote write lands before the destination buffer is
initialized (§4.5 final-synchronization analogue).

Adaptation note (DESIGN.md §2): the paper's host path has no executable TPU
analogue and is rejected; staging buffers live in the via-chip's VMEM,
sized per-chunk — hop-granular flow control comes from the per-chunk
staging slots (a production kernel would credit-signal to reuse two slots;
we allocate ``num_chunks`` slots which bounds VMEM by the path share).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import pallas_tpu_compiler_params, pallas_interpret_flag

from repro.comm.plan import TransferPlan
from repro.core.topology import HOST


def _element_bounds(plan: TransferPlan, itemsize: int):
    """Static (path -> [(offset_elems, size_elems)]) chunk table."""
    table = []
    for pa in plan.paths:
        if pa.route.via == HOST:
            raise ValueError("host-staged path not executable on TPU mesh")
        chunks = []
        for off_b, size_b in pa.chunk_bounds():
            if off_b % itemsize or size_b % itemsize:
                raise ValueError("plan not element-aligned; use "
                                 "granularity=itemsize")
            chunks.append((off_b // itemsize, size_b // itemsize))
        table.append(chunks)
    return table


def _multipath_dma_kernel(x_ref, o_ref, *scratch, plan: TransferPlan,
                          chunk_table, num_devices: int, axis_name: str):
    npaths = len(plan.paths)
    stage_refs = scratch[:npaths]
    (init_sem, h1_send, h1_recv, h2_send, h2_recv) = scratch[npaths:]
    my = lax.axis_index(axis_name)
    src, dst = plan.src, plan.dst

    # 1) local init: every device's output starts as its input, so the
    #    transfer is an identity for non-participants and the destination
    #    region is defined before remote chunks land.
    init = pltpu.make_async_copy(x_ref, o_ref, init_sem)
    init.start()
    init.wait()

    # 2) global barrier: no remote write may precede any init completion.
    bar = pltpu.get_barrier_semaphore()
    for d in range(num_devices):
        pltpu.semaphore_signal(bar, 1, device_id=(d,),
                               device_id_type=pltpu.DeviceIdType.MESH)
    pltpu.semaphore_wait(bar, num_devices)

    # 3) the transfer graph. Python loops are static — each iteration emits
    #    one copy node, exactly like the explicit CUDA Graph API in Alg. 2.
    for p, (pa, chunks) in enumerate(zip(plan.paths, chunk_table)):
        via = pa.route.via
        if via is None:
            # ---- direct path: one node per chunk --------------------------
            for c, (off, size) in enumerate(chunks):
                node = pltpu.make_async_remote_copy(
                    src_ref=x_ref.at[pl.ds(off, size)],
                    dst_ref=o_ref.at[pl.ds(off, size)],
                    send_sem=h1_send.at[p, c], recv_sem=h1_recv.at[p, c],
                    device_id=(dst,),
                    device_id_type=pltpu.DeviceIdType.MESH)

                @pl.when(my == src)
                def _(node=node):
                    node.start()

                @pl.when(my == dst)
                def _(node=node):
                    node.wait_recv()

            @pl.when(my == src)
            def _(p=p, chunks=chunks):
                for c, (off, size) in enumerate(chunks):
                    pltpu.make_async_remote_copy(
                        src_ref=x_ref.at[pl.ds(off, size)],
                        dst_ref=o_ref.at[pl.ds(off, size)],
                        send_sem=h1_send.at[p, c], recv_sem=h1_recv.at[p, c],
                        device_id=(dst,),
                        device_id_type=pltpu.DeviceIdType.MESH).wait_send()
        else:
            # ---- staged path: hop-1 into via's staging slot, hop-2 out ----
            stage = stage_refs[p]
            for c, (off, size) in enumerate(chunks):
                h1 = pltpu.make_async_remote_copy(
                    src_ref=x_ref.at[pl.ds(off, size)],
                    dst_ref=stage.at[c, pl.ds(0, size)],
                    send_sem=h1_send.at[p, c], recv_sem=h1_recv.at[p, c],
                    device_id=(via,),
                    device_id_type=pltpu.DeviceIdType.MESH)
                h2 = pltpu.make_async_remote_copy(
                    src_ref=stage.at[c, pl.ds(0, size)],
                    dst_ref=o_ref.at[pl.ds(off, size)],
                    send_sem=h2_send.at[p, c], recv_sem=h2_recv.at[p, c],
                    device_id=(dst,),
                    device_id_type=pltpu.DeviceIdType.MESH)

                @pl.when(my == src)
                def _(h1=h1):
                    h1.start()

                @pl.when(my == via)
                def _(h1=h1, h2=h2):
                    h1.wait_recv()   # dependency edge (Alg. 2 line 19)
                    h2.start()

                @pl.when(my == dst)
                def _(h2=h2):
                    h2.wait_recv()

            @pl.when(my == src)
            def _(p=p, chunks=chunks, via=via, stage=stage):
                for c, (off, size) in enumerate(chunks):
                    pltpu.make_async_remote_copy(
                        src_ref=x_ref.at[pl.ds(off, size)],
                        dst_ref=stage.at[c, pl.ds(0, size)],
                        send_sem=h1_send.at[p, c], recv_sem=h1_recv.at[p, c],
                        device_id=(via,),
                        device_id_type=pltpu.DeviceIdType.MESH).wait_send()

            @pl.when(my == via)
            def _(p=p, chunks=chunks, stage=stage):
                for c, (off, size) in enumerate(chunks):
                    pltpu.make_async_remote_copy(
                        src_ref=stage.at[c, pl.ds(0, size)],
                        dst_ref=o_ref.at[pl.ds(off, size)],
                        send_sem=h2_send.at[p, c], recv_sem=h2_recv.at[p, c],
                        device_id=(dst,),
                        device_id_type=pltpu.DeviceIdType.MESH).wait_send()


def build_multipath_dma(plan: TransferPlan, nelems: int, dtype,
                        num_devices: int, *, axis_name: str = "dev",
                        interpret: bool = True, collective_id: int = 7):
    """Return ``fn(x_local) -> y_local`` executing ``plan``, for use inside
    ``jax.shard_map`` over ``axis_name``. ``x_local`` shape ``(nelems,)``."""
    dtype = jnp.dtype(dtype)
    for pa in plan.paths:
        if pa.route.num_hops > 2:
            raise NotImplementedError(
                "the DMA kernel implements direct and 2-hop staged routes "
                "(paper Alg. 2); 3-hop torus detours run on the ppermute "
                "engine (repro.core.multipath)")
    chunk_table = _element_bounds(plan, dtype.itemsize)
    npaths = len(plan.paths)
    max_chunks = max(len(c) for c in chunk_table)

    scratch = []
    for pa, chunks in zip(plan.paths, chunk_table):
        max_size = max((s for _, s in chunks), default=1)
        # staging slots only used on staged paths; direct paths get a
        # minimal placeholder so scratch indices stay aligned with paths.
        slots = len(chunks) if pa.route.via is not None else 1
        size = max_size if pa.route.via is not None else 8
        scratch.append(pltpu.VMEM((slots, size), dtype))
    scratch += [
        pltpu.SemaphoreType.DMA,                        # init
        pltpu.SemaphoreType.DMA((npaths, max_chunks)),  # h1 send
        pltpu.SemaphoreType.DMA((npaths, max_chunks)),  # h1 recv
        pltpu.SemaphoreType.DMA((npaths, max_chunks)),  # h2 send
        pltpu.SemaphoreType.DMA((npaths, max_chunks)),  # h2 recv
    ]

    kernel = functools.partial(
        _multipath_dma_kernel, plan=plan, chunk_table=chunk_table,
        num_devices=num_devices, axis_name=axis_name)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((nelems,), dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=scratch,
        compiler_params=pallas_tpu_compiler_params(collective_id=collective_id),
        interpret=pallas_interpret_flag(interpret),
    )
