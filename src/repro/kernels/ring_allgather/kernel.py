"""Pallas TPU kernel: bidirectional-ring all-gather over remote DMA.

Kernel-level realization of the beyond-paper multipath collectives
(EXPERIMENTS.md §Perf N4): every step drives BOTH directional ICI links —
the clockwise chain carries the first half of each shard, the
counter-clockwise chain the second half — so the busiest-link bytes halve
vs a unidirectional ring (`core/collectives.py` is the XLA-level
equivalent; this is the hand-scheduled DMA version).

Structure per device (N-1 steps):

* init: local DMA of the own shard into output slot ``i``; global barrier,
* step s: send slot ``(i−s) mod N`` [:half] right and slot ``(i+s) mod N``
  [half:] left — two concurrent remote DMAs on distinct links with
  independent semaphore pairs (the paper's per-path streams) — then wait
  the two incoming slots.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import pallas_tpu_compiler_params, pallas_interpret_flag


def _ring_ag_kernel(x_ref, o_ref, init_sem, cw_send, cw_recv, ccw_send,
                    ccw_recv, *, num_devices: int, axis_name: str,
                    half: int):
    n = num_devices
    me = lax.axis_index(axis_name)
    right = lax.rem(me + 1, n)
    left = lax.rem(me + n - 1, n)

    # own shard into own slot, then barrier before any remote write
    init = pltpu.make_async_copy(x_ref, o_ref.at[me], init_sem)
    init.start()
    init.wait()
    bar = pltpu.get_barrier_semaphore()
    for d in range(n):
        pltpu.semaphore_signal(bar, 1, device_id=(d,),
                               device_id_type=pltpu.DeviceIdType.MESH)
    pltpu.semaphore_wait(bar, n)

    f = o_ref.shape[-1]
    for s in range(n - 1):
        cw_slot = lax.rem(me - s + n, n)       # block travelling clockwise
        ccw_slot = lax.rem(me + s, n)          # block travelling ccw
        cw = pltpu.make_async_remote_copy(
            src_ref=o_ref.at[cw_slot, :, pl.ds(0, half)],
            dst_ref=o_ref.at[cw_slot, :, pl.ds(0, half)],
            send_sem=cw_send.at[s], recv_sem=cw_recv.at[s],
            device_id=(right,), device_id_type=pltpu.DeviceIdType.MESH)
        ccw = pltpu.make_async_remote_copy(
            src_ref=o_ref.at[ccw_slot, :, pl.ds(half, f - half)],
            dst_ref=o_ref.at[ccw_slot, :, pl.ds(half, f - half)],
            send_sem=ccw_send.at[s], recv_sem=ccw_recv.at[s],
            device_id=(left,), device_id_type=pltpu.DeviceIdType.MESH)
        cw.start()                             # both links active
        ccw.start()
        cw.wait_send()
        ccw.wait_send()
        # incoming: cw block from left lands in slot (me-s-1); ccw block
        # from right lands in slot (me+s+1)
        cw.wait_recv()
        ccw.wait_recv()


def build_ring_allgather(shard_shape: tuple, dtype, num_devices: int, *,
                         axis_name: str = "dev", interpret: bool = True,
                         collective_id: int = 11):
    """Returns fn(x_local (rows, f)) -> (N*rows, f) for use in shard_map."""
    rows, f = shard_shape
    half = f // 2
    if half == 0:
        half = f  # degenerate narrow case: single direction

    kernel = functools.partial(
        _ring_ag_kernel, num_devices=num_devices, axis_name=axis_name,
        half=half)

    call = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((num_devices, rows, f), dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[pltpu.SemaphoreType.DMA]
        + [pltpu.SemaphoreType.DMA((max(1, num_devices - 1),))] * 4,
        compiler_params=pallas_tpu_compiler_params(collective_id=collective_id),
        interpret=pallas_interpret_flag(interpret),
    )

    def fn(x_local):
        return call(x_local).reshape(num_devices * rows, f)

    return fn
