"""Oracle for the ring all-gather kernel: lax.all_gather(tiled)."""

from __future__ import annotations

import jax
from jax import lax


def ring_allgather_ref(x_local: jax.Array, axis_name: str) -> jax.Array:
    return lax.all_gather(x_local, axis_name, tiled=True)
