"""Jit'd wrapper for the bidirectional ring all-gather kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.kernels.ring_allgather.kernel import build_ring_allgather

AXIS = "dev"


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def ring_allgather(x: jax.Array, mesh: jax.sharding.Mesh, *,
                   interpret: bool | None = None) -> jax.Array:
    """x: (N*rows, f) sharded over 'dev' → fully gathered (N*rows, f) on
    every device (replicated)."""
    if interpret is None:
        interpret = _is_cpu()
    n = mesh.devices.size
    rows = x.shape[0] // n
    inner = build_ring_allgather((rows, x.shape[1]), x.dtype, n,
                                 axis_name=AXIS, interpret=interpret)
    fn = jax.jit(shard_map(inner, mesh=mesh, in_specs=P(AXIS),
                           out_specs=P(None), check_vma=False))
    x = jax.device_put(x, NamedSharding(mesh, P(AXIS)))
    return fn(x)


def captured_ring_allgather(cap, x, num_devices: int, *,
                            name: str = "ring_allgather",
                            axis_name: str = AXIS, telemetry=None,
                            interpret: bool | None = None):
    """Record the ring all-gather kernel on a ``session.capture`` step.

    ``x`` is a capture ref with local shape ``(rows, f)``; returns the
    gathered ``(num_devices * rows, f)`` ref (every device holds the
    full result). ``axis_name`` must equal the session's SPMD axis —
    the kernel's collective permutes run inside the captured program's
    mesh. The result spec is declared explicitly (``out=``): the kernel
    uses axis collectives that cannot be abstractly evaluated outside
    the mesh. ``flops`` stays 0 — this is wire work — but ``cost_ns``
    is stamped from ``telemetry``'s recorded median for ``name`` when a
    recorder is passed, so its measured duration occupies the lane
    model's compute lane honestly.
    """
    if interpret is None:
        interpret = _is_cpu()
    from repro.comm.capture import BufferSpec
    spec = cap.buffers[cap._resolve(x)]
    rows, f = spec.shape
    inner = build_ring_allgather((rows, f), jnp.dtype(spec.dtype),
                                 num_devices, axis_name=axis_name,
                                 interpret=interpret)
    cost = int(telemetry.kernel_cost_ns(name)) if telemetry is not None \
        else 0
    return cap.kernel(inner, x, name=name,
                      out=BufferSpec((num_devices * rows, f), spec.dtype),
                      cost_ns=cost)
