"""Jit'd wrapper for the bidirectional ring all-gather kernel."""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.kernels.ring_allgather.kernel import build_ring_allgather

AXIS = "dev"


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def ring_allgather(x: jax.Array, mesh: jax.sharding.Mesh, *,
                   interpret: bool | None = None) -> jax.Array:
    """x: (N*rows, f) sharded over 'dev' → fully gathered (N*rows, f) on
    every device (replicated)."""
    if interpret is None:
        interpret = _is_cpu()
    n = mesh.devices.size
    rows = x.shape[0] // n
    inner = build_ring_allgather((rows, x.shape[1]), x.dtype, n,
                                 axis_name=AXIS, interpret=interpret)
    fn = jax.jit(shard_map(inner, mesh=mesh, in_specs=P(AXIS),
                           out_specs=P(None), check_vma=False))
    x = jax.device_put(x, NamedSharding(mesh, P(AXIS)))
    return fn(x)
