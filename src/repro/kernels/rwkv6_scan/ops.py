"""Jit'd wrapper for the RWKV-6 chunked scan kernel (pads seq to chunk)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_kernel


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, *, chunk: int = 64,
               interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = _is_cpu()
    bh, s, _ = r.shape
    chunk = min(chunk, max(8, s))
    pad = (-s) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)),
                    constant_values=1.0)  # identity decay on padding
    out = rwkv6_scan_kernel(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return out[:, :s, :]
