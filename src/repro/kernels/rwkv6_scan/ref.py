"""Pure-jnp oracle for the RWKV-6 scan kernel: literal per-step recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def rwkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array) -> jax.Array:
    """r/k/w: (BH, S, dk); v: (BH, S, dv); u: (BH, dk) -> (BH, S, dv)."""
    rf, kf, vf, wf, uf = (x.astype(jnp.float32) for x in (r, k, v, w, u))

    def head(r_h, k_h, v_h, w_h, u_h):
        dk, dv = r_h.shape[-1], v_h.shape[-1]

        def step(s, inputs):
            r_t, k_t, v_t, w_t = inputs
            kv = jnp.outer(k_t, v_t)
            o_t = r_t @ (s + u_h[:, None] * kv)
            s_new = w_t[:, None] * s + kv
            return s_new, o_t

        _, o = lax.scan(step, jnp.zeros((dk, dv), jnp.float32),
                        (r_h, k_h, v_h, w_h))
        return o

    out = jax.vmap(head)(rf, kf, vf, wf, uf)
    return out.astype(r.dtype)
