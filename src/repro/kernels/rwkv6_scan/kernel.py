"""Pallas TPU kernel: chunked RWKV-6 (Finch) gated linear recurrence.

Recurrence per head (state ``S: (dk, dv)``, data-dependent decay ``w_t``,
bonus ``u``):

    o_t = r_t · (S_{t-1} + diag(u) kᵀ_t v_t)
    S_t = diag(w_t) S_{t-1} + kᵀ_t v_t

The kernel processes the sequence in chunks of length ``L`` (grid dim
sequential, state carried in VMEM scratch) and converts the recurrence into
MXU matmuls via the standard chunked factorization: with per-channel
log-decay cumsums ``c_t = Σ_{s≤t} log w_s``,

    q̃_t = r_t ⊙ exp(c_{t-1})       (decay since chunk start)
    k̃_s = k_s ⊙ exp(−c_s)          (inverse decay to chunk start)
    o_t  = q̃_t S_prev  +  Σ_{s<t} (q̃_t·k̃_s) v_s  +  (r_t·(u⊙k_t)) v_t
    S'   = diag(exp(c_L)) S_prev + (k̃ ⊙ exp(c_L))ᵀ V

Numerical-range note: the q̃/k̃ split is exact but bounded by
``exp(±|Σ log w|)`` over one chunk; with the RWKV-6 parameterization
(w = exp(−exp(x)), practical decays ≥ 0.8) chunk 64 stays well inside fp32
range. The chunk length is a BlockSpec tunable.

Grid: ``(batch*heads, seq//L)``; blocks ``(1, L, d)`` for r/k/v/w and
``(1, dk)`` for the per-head bonus ``u``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import pallas_tpu_compiler_params


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *,
                  chunk: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)        # (L, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)        # (L, dv)
    w = w_ref[0].astype(jnp.float32)        # (L, dk) decays in (0, 1]
    u = u_ref[0].astype(jnp.float32)        # (dk,)

    logw = jnp.log(w)
    cum = jnp.cumsum(logw, axis=0)          # c_t, inclusive
    cum_prev = cum - logw                   # c_{t-1}, exclusive

    qt = r * jnp.exp(cum_prev)              # q̃
    kt = k * jnp.exp(-cum)                  # k̃

    scores = lax.dot_general(qt, kt, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    row = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(col < row, scores, 0.0)          # strictly causal

    bonus = jnp.sum(r * u[None, :] * k, axis=-1)        # (L,) diagonal term
    o = (lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                         preferred_element_type=jnp.float32)
         + bonus[:, None] * v
         + lax.dot_general(qt, s_scr[...], (((1,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32))

    decay_l = jnp.exp(cum[-1])                           # (dk,)
    s_scr[...] = (s_scr[...] * decay_l[:, None]
                  + lax.dot_general(kt * decay_l[None, :], v,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32))
    o_ref[0] = o.astype(o_ref.dtype)


def rwkv6_scan_kernel(r: jax.Array, k: jax.Array, v: jax.Array,
                      w: jax.Array, u: jax.Array, *, chunk: int = 64,
                      interpret: bool = True) -> jax.Array:
    """r/k/w: (BH, S, dk); v: (BH, S, dv); u: (BH, dk). Returns (BH, S, dv).

    S must be a multiple of ``chunk`` (pad upstream; decays pad with 1.0).
    """
    bh, s, dk = r.shape
    dv = v.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} not a multiple of chunk {chunk}")
    nchunks = s // chunk

    kernel = functools.partial(_rwkv6_kernel, chunk=chunk)
    seq_spec_k = pl.BlockSpec((1, chunk, dk), lambda h, t: (h, t, 0))
    seq_spec_v = pl.BlockSpec((1, chunk, dv), lambda h, t: (h, t, 0))
    return pl.pallas_call(
        kernel,
        grid=(bh, nchunks),
        in_specs=[seq_spec_k, seq_spec_k, seq_spec_v, seq_spec_k,
                  pl.BlockSpec((1, dk), lambda h, t: (h, 0))],
        out_specs=seq_spec_v,
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
