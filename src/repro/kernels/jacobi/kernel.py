"""Pallas TPU kernel: 5-point Jacobi stencil sweep (the paper's application).

The domain is tall-and-narrow exactly as in the paper's evaluation (§5.4:
vertical dimension 8, horizontal up to 2^30, column-partitioned across
devices). Rows therefore stay resident per block and the kernel tiles the
wide column dimension: grid ``(W // TILE,)`` with three input views of the
halo-extended operand (left/center/right neighbour columns), each a
``(rows, TILE)`` VMEM block. TILE is a multiple of 128 to keep the lane
dimension MXU/VPU-aligned; vertical neighbours are row shifts inside the
block (rows are global — the column split means block edges are the true
domain boundary, handled with Dirichlet zeros).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 512


def _jacobi_kernel(l_ref, c_ref, r_ref, o_ref):
    c = c_ref[...]
    rows = c.shape[0]
    zero = jnp.zeros((1, c.shape[1]), c.dtype)
    up = jnp.concatenate([zero, c[:-1, :]], axis=0)      # Dirichlet top
    down = jnp.concatenate([c[1:, :], zero], axis=0)     # Dirichlet bottom
    o_ref[...] = 0.25 * (l_ref[...] + r_ref[...] + up + down)


def jacobi_sweep_kernel(ext: jax.Array, *, tile: int = TILE,
                        interpret: bool = True) -> jax.Array:
    """One sweep over a halo-extended block ``ext: (rows, W + 2)``.

    Returns the updated interior ``(rows, W)``. The three shifted views are
    materialized outside (XLA fuses the slices into the pallas_call copies).
    """
    rows, wp2 = ext.shape
    w = wp2 - 2
    left, center, right = ext[:, :-2], ext[:, 1:-1], ext[:, 2:]
    tile = min(tile, w)
    grid = (pl.cdiv(w, tile),)
    spec = pl.BlockSpec((rows, tile), lambda i: (0, i))
    return pl.pallas_call(
        _jacobi_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, w), ext.dtype),
        interpret=interpret,
    )(left, center, right)
