"""Pure-jnp oracle for the Jacobi stencil kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def jacobi_sweep_ref(ext: jax.Array) -> jax.Array:
    """5-point Jacobi update of the interior of ``ext: (rows, W + 2)`` with
    Dirichlet-zero top/bottom boundaries."""
    c = ext[:, 1:-1]
    up = jnp.pad(c[:-1, :], ((1, 0), (0, 0)))
    down = jnp.pad(c[1:, :], ((0, 1), (0, 0)))
    return 0.25 * (ext[:, :-2] + ext[:, 2:] + up + down)
