"""Jit'd wrapper for the Jacobi sweep kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.jacobi.kernel import jacobi_sweep_kernel


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def jacobi_sweep(ext: jax.Array, *, tile: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = _is_cpu()
    return jacobi_sweep_kernel(ext, tile=tile, interpret=interpret)
