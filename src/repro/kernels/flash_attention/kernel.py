"""Pallas TPU kernel: blockwise (flash) attention with GQA + sliding window.

Used by the framework's prefill path (32k contexts make materializing the
(S, S) score matrix infeasible: 32768² × 4B = 4 GiB per head). Canonical TPU
formulation:

* grid ``(batch, q_heads, q_blocks, kv_blocks)`` — the last dimension is
  sequential ("arbitrary"), carrying the online-softmax state in VMEM
  scratch across kv blocks,
* BlockSpecs tile Q/O as ``(1, 1, block_q, d)`` and K/V as
  ``(1, 1, block_k, d)``; the K/V index map folds the GQA group mapping
  (``kv_head = q_head // q_per_kv``) so grouped heads never materialize,
* block shapes default to 128×128: lane-dim and MXU-aligned,
* masking supports causal, sliding-window (Mistral/Gemma-style), and the
  sequence-padding tail in one predicate; masked probabilities are zeroed
  explicitly so fully-masked rows stay exact zeros (guarded normalization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  seq_len: int, block_q: int, block_k: int,
                  num_kv_blocks: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32) * scale

    row = i * block_q + lax.broadcasted_iota(jnp.int32,
                                             (block_q, block_k), 0)
    col = j * block_k + lax.broadcasted_iota(jnp.int32,
                                             (block_q, block_k), 1)
    mask = col < seq_len
    if causal:
        mask &= col <= row
    if window is not None:
        mask &= col > row - window

    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[:, :1]                            # (bq, 1)
    l_prev = l_scr[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new) * mask                    # zero masked lanes
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           scale: float | None = None, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D) with Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    qpk = hq // hkv
    if scale is None:
        scale = d ** -0.5

    block_q = min(block_q, s)
    block_k = min(block_k, s)
    pad_q = (-s) % block_q
    pad_k = (-s) % block_k
    if pad_q or pad_k:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    sq, sk = s + pad_q, s + pad_k
    nq, nk = sq // block_q, sk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        seq_len=s, block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, qpk=qpk: (b_, h // qpk, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, qpk=qpk: (b_, h // qpk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),     # accumulator
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :s, :]
