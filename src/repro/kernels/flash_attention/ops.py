"""Jit'd wrapper for the flash attention kernel.

On CPU the kernel runs in interpret mode; ``flash_attention`` transparently
falls back to the reference for head dims the kernel does not tile well
(d not a multiple of 8) so model code can call it unconditionally.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = _is_cpu()
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


def attention_flops(q_shape, k_shape) -> int:
    """Nominal FLOP count of one attention call: ``2·B·H·Sq·Sk·D`` for
    QKᵀ plus the same again for the value matmul."""
    b, h, sq, d = q_shape
    sk = k_shape[2]
    return 4 * b * h * sq * sk * d


def captured_flash_attention(cap, q, k, v, *, name: str = "flash_attention",
                             causal: bool = True, window: int | None = None,
                             scale: float | None = None,
                             telemetry=None, interpret: bool | None = None):
    """Record a flash-attention invocation on a ``session.capture`` step.

    ``cap`` is the :class:`~repro.comm.capture.StepCapture`; ``q``/``k``/
    ``v`` are capture refs with local shapes ``(B, H, S, D)``. Returns
    the attention output ref (q's shape). The node is priced for the
    lane model: ``flops`` from :func:`attention_flops`, and — when a
    :class:`~repro.comm.telemetry.TimelineRecorder` is passed as
    ``telemetry`` — ``cost_ns`` stamped from its recorded median for
    ``name``, so the overlap scheduler optimizes against measured
    kernel time. ``name`` is the capture's kernel identity: one adopter
    call per name per capture.
    """
    from repro.comm.capture import BufferSpec
    q_spec = cap.buffers[cap._resolve(q)]
    k_spec = cap.buffers[cap._resolve(k)]

    def attn(q_, k_, v_):
        return flash_attention(q_, k_, v_, causal=causal, window=window,
                               scale=scale, interpret=interpret)

    cost = int(telemetry.kernel_cost_ns(name)) if telemetry is not None \
        else 0
    return cap.kernel(attn, q, k, v, name=name,
                      out=BufferSpec(q_spec.shape, q_spec.dtype),
                      flops=attention_flops(q_spec.shape, k_spec.shape),
                      cost_ns=cost)
