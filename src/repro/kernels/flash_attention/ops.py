"""Jit'd wrapper for the flash attention kernel.

On CPU the kernel runs in interpret mode; ``flash_attention`` transparently
falls back to the reference for head dims the kernel does not tile well
(d not a multiple of 8) so model code can call it unconditionally.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = _is_cpu()
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)
