"""Pure-jnp oracle for the flash attention kernel (GQA + causal + window)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D). fp32 softmax, q.dtype out."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    qpk = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kk = jnp.repeat(k, qpk, axis=1)
    vv = jnp.repeat(v, qpk, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    row = jnp.arange(s)[:, None]
    col = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= col <= row
    if window is not None:
        mask &= col > row - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.any(mask, -1)[None, None, :, None], probs, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)
