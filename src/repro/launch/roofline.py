"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds (v5e constants):

* compute    = HLO_FLOPs / peak_bf16            (197 TFLOP/s per chip)
* memory     = HLO_bytes / HBM bandwidth        (819 GB/s per chip)
* collective = wire_bytes / (links × 50 GB/s)   per chip

``cost_analysis()`` supplies FLOPs/bytes of the per-device SPMD module.
Collective bytes are NOT in cost_analysis: we parse the compiled HLO and
apply ring-model wire multipliers per op kind (group size parsed from
``replica_groups``):

=================  ==========================================
op                 wire bytes per device (result size R)
=================  ==========================================
all-reduce         2·R·(n−1)/n
all-gather         R·(n−1)/n
reduce-scatter     R·(n−1)          (result is the scattered shard)
all-to-all         R·(n−1)/n
collective-permute R
=================  ==========================================

``links`` defaults to 1 (single-path baseline). The multipath collectives
(bidirectional ring / 2-axis striping — the paper's contribution applied to
collectives) raise the usable link count; §Perf records both the baseline
and the multipath-effective collective terms.
"""

from __future__ import annotations

import dataclasses
import json
import re

from repro.core.topology import HBM_GBPS, ICI_LINK_GBPS, PEAK_BF16_TFLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_OP_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _wire_multiplier(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op == "all-gather":
        return (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    total_wire_bytes: float = 0.0
    by_op: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, op: str, wire: float):
        self.total_wire_bytes += wire
        d = self.by_op.setdefault(op, {"count": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["wire_bytes"] += wire
        self.count += 1


def collective_bytes(hlo_text: str, default_group: int) -> CollectiveStats:
    """Per-device wire bytes from the (post-SPMD, per-device) HLO."""
    stats = CollectiveStats()
    seen_start: set[str] = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        # async pairs appear as op-start/op-done; count once
        if "-done(" in line:
            continue
        op = m.group("op")
        rb = _shape_bytes(m.group("type"))
        n = _group_size(line, default_group)
        stats.add(op, rb * _wire_multiplier(op, n))
    return stats


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float               # per-device HLO flops
    hbm_bytes: float           # per-device bytes accessed
    wire_bytes: float          # per-device collective bytes
    collective_by_op: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float         # 6·N·D (or 6·N_active·D) global
    useful_flops_ratio: float  # model_flops / (flops × chips)
    memory_per_device_gb: float
    peak_memory_gb: float | None = None
    links: int = 1
    note: str = ""

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(arch_name: str, shape_name: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops: float,
            memory_bytes: float, *, default_group: int,
            peak_memory_bytes: float | None = None,
            links: int = 1, note: str = "") -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text, default_group)
    compute_s = flops / (PEAK_BF16_TFLOPS * 1e12)
    memory_s = hbm / (HBM_GBPS * 1e9)
    collective_s = coll.total_wire_bytes / (links * ICI_LINK_GBPS * 1e9)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_hlo_flops = flops * chips
    ratio = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    return RooflineReport(
        arch=arch_name, shape=shape_name, mesh=mesh_name, chips=chips,
        flops=flops, hbm_bytes=hbm, wire_bytes=coll.total_wire_bytes,
        collective_by_op=coll.by_op, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bottleneck=bottleneck,
        model_flops=model_flops, useful_flops_ratio=ratio,
        memory_per_device_gb=memory_bytes / 2**30,
        peak_memory_gb=(peak_memory_bytes / 2**30
                        if peak_memory_bytes else None),
        links=links, note=note)


def train_model_flops(n_active_params: float, tokens: float) -> float:
    return 6.0 * n_active_params * tokens


def decode_model_flops(n_active_params: float, batch: int) -> float:
    """One decode step processes ``batch`` tokens."""
    return 2.0 * n_active_params * batch  # fwd only


def prefill_model_flops(n_active_params: float, tokens: float) -> float:
    return 2.0 * n_active_params * tokens
