"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from sweep JSON.

Renders every row kind the dry-run driver emits: model compilation cells,
``--comm`` transfer-graph rows (copy-node/edge counts, critical-path
depth, modeled bandwidth — see ``session.describe``), the ``--comm``
schedule-sweep rows (modeled time per chunk-interleaving scheduler,
DESIGN.md §2.2), and the ``--comm --fail-link`` rows (before/after
re-plan routes and ladder level under a failed link, DESIGN.md §4.6).

Usage: PYTHONPATH=src python -m repro.launch.report \
           experiments/dryrun_results.json > experiments/roofline.md
"""

from __future__ import annotations

import json
import sys


def fmt_table(rows: list[dict], mesh: str) -> str:
    out = [
        f"### Mesh `{mesh}`\n",
        "| arch | shape | kind | mem/dev GiB | compute s | memory s | "
        "collective s | bottleneck | MODEL_FLOPS | useful ratio | "
        "top collective |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                       f"| SKIP | — | — | {r['reason']} |")
            continue
        ops = r.get("collective_by_op", {})
        top = max(ops.items(), key=lambda kv: kv[1]["wire_bytes"],
                  default=(None, None))
        top_s = (f"{top[0]} {top[1]['wire_bytes']/1e9:.0f}GB"
                 if top[0] else "—")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['memory_per_device_gb']:.1f} "
            f"| {r['compute_s']:.2f} | {r['memory_s']:.2f} "
            f"| {r['collective_s']:.2f} | **{r['bottleneck']}** "
            f"| {r['model_flops']:.2e} | {r['useful_flops_ratio']:.2f} "
            f"| {top_s} |")
    return "\n".join(out) + "\n"


def fmt_comm_table(rows: list[dict]) -> str:
    """§Transfer graphs — one row per ``--comm`` dry-run lowering."""
    out = [
        "### Transfer graphs (`--comm` dry-run)\n",
        "| topology | MiB | paths | nodes | edges | critical path | "
        "launch µs (graph/per-node) | modeled GB/s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["topology"], r["nbytes"],
                                         r["max_paths"])):
        out.append(
            f"| {r['topology']} | {r['nbytes'] >> 20} | {r['num_paths']} "
            f"| {r['nodes']} | {r['edges']} | {r['critical_path_nodes']} "
            f"| {r['launch_overhead_ns'] / 1e3:.1f}/"
            f"{r['launch_overhead_nograph_ns'] / 1e3:.1f} "
            f"| {r['effective_gbps']:.1f} |")
    return "\n".join(out) + "\n"


def fmt_schedule_table(rows: list[dict]) -> str:
    """§Schedule sweep — modeled time per chunk-interleaving scheduler
    (DESIGN.md §2.2); delta is vs the ``round_robin`` baseline order."""
    out = [
        "### Schedule sweep (`--comm` dry-run)\n",
        "| topology | MiB | schedule | chosen | nodes | modeled µs | "
        "Δ vs round_robin ns |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["topology"], r["nbytes"],
                                         r["schedule"])):
        out.append(
            f"| {r['topology']} | {r['nbytes'] >> 20} | {r['schedule']} "
            f"| {r['chosen']} | {r['nodes']} "
            f"| {r['scheduled_time_s'] * 1e6:.1f} "
            f"| {r['delta_vs_round_robin_s'] * 1e9:+.0f} |")
    return "\n".join(out) + "\n"


def fmt_fault_table(rows: list[dict]) -> str:
    """§Link-fault re-plans — one before/after pair per ``--fail-link``
    dry-run cell (DESIGN.md §4.6): the steady-state routes, the
    surviving-routes re-plan once the link is down, and the ladder level
    each side runs at."""
    out = [
        "### Link-fault re-plans (`--comm --fail-link` dry-run)\n",
        "| topology | failed link | transfer | side | paths | routes | "
        "modeled GB/s | modeled µs | ladder |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: r["topology"]):
        link = "->".join(str(n) for n in r["failed_link"])
        xfer = f"{r['src']}->{r['dst']} {r['nbytes'] >> 20}MiB"
        for side in ("before", "after"):
            c = r[side]
            out.append(
                f"| {r['topology']} | {link} | {xfer} | {side} "
                f"| {c['num_paths']} | {', '.join(c['routes'])} "
                f"| {c['effective_gbps']:.1f} "
                f"| {c['scheduled_time_s'] * 1e6:.1f} | {c['level']} |")
    return "\n".join(out) + "\n"


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "experiments/dryrun_results.json"
    rows = json.load(open(path))
    comm = [r for r in rows if r.get("kind") == "comm_graph"]
    sched = [r for r in rows if r.get("kind") == "comm_schedule"]
    faults = [r for r in rows if r.get("kind") == "comm_fault"]
    rows = [r for r in rows
            if r.get("kind") not in ("comm_graph", "comm_schedule",
                                     "comm_fault")]
    ok = [r for r in rows if r["status"] == "ok"]
    sk = [r for r in rows if r["status"] == "skipped"]
    print(f"Cells: {len(ok)} compiled, {len(sk)} skipped, "
          f"{len(rows) - len(ok) - len(sk)} errors; "
          f"{len(comm)} transfer graphs; {len(sched)} schedule cells; "
          f"{len(faults)} fault cells.\n")
    for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
        sub = [r for r in rows if r["mesh"] == mesh]
        if sub:
            print(fmt_table(sub, mesh))
    if comm:
        print(fmt_comm_table(comm))
    if sched:
        print(fmt_schedule_table(sched))
    if faults:
        print(fmt_fault_table(faults))


if __name__ == "__main__":
    main()
