"""Production mesh + topology construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). The single-pod mesh is a 16×16 = 256-chip v5e pod
(data × model); the multi-pod mesh adds a leading pod axis (2 pods = 512
chips) carrying pure data parallelism across the DCN.

The comm-model side of the same decision lives here too:
``make_production_topology`` builds the matching :class:`Topology` — flat
16×16 ICI torus for one pod, or two torus islands joined by DCN links
(island-aware, DESIGN §3.1) for the multi-pod mesh — and
``production_launch_spec(arch)`` resolves both from an architecture's
``multi_pod`` hint, so the launcher, the dry-run, and the planner all
agree on which machine a config runs on.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh
from repro.configs.base import ArchConfig
from repro.core.topology import Topology

#: Per-chip DCN egress links joining two pods (v5e: a slice of hosts own
#: the data-center NICs), and the per-link DCN bandwidth class.
DCN_EGRESS_PER_POD = 4
DCN_LINK_GBPS = 25.0


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def production_mesh_shape(*, multi_pod: bool = False
                          ) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """The (shape, axis names) ``make_production_mesh`` would build —
    resolvable without 256/512 placeholder devices (tests, specs)."""
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")


def make_production_topology(*, multi_pod: bool = False) -> Topology:
    """The comm :class:`Topology` matching :func:`make_production_mesh`.

    Single pod: the flat 16×16 ICI torus (one island). Multi-pod: two
    such torus islands joined by :data:`DCN_EGRESS_PER_POD` DCN links —
    the planner's island-aware routing then keeps intra-pod traffic on
    ICI and stages cross-pod transfers through exactly one DCN hop.
    """
    if not multi_pod:
        return Topology.torus2d(16, 16, name="pod16x16")
    return Topology.hierarchical(
        2, 256, intra="torus", torus_shape=(16, 16),
        inter_gbps=DCN_LINK_GBPS, inter_kind="dcn",
        egress_per_island=DCN_EGRESS_PER_POD, name="pods2x16x16")


def production_launch_spec(arch: ArchConfig) -> dict:
    """Resolve the launch-time machine for ``arch``: mesh shape/axes plus
    the island-aware topology, all keyed off ``arch.multi_pod`` (the
    configs' honest statement of whether one pod's HBM suffices)."""
    shape, axes = production_mesh_shape(multi_pod=arch.multi_pod)
    return {
        "arch": arch.name,
        "multi_pod": arch.multi_pod,
        "mesh_shape": shape,
        "mesh_axes": axes,
        "topology": make_production_topology(multi_pod=arch.multi_pod),
    }


def make_host_mesh(shape=None, axes=("data", "model")) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return make_mesh(shape, axes)
