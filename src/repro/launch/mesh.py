"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). The single-pod mesh is a 16×16 = 256-chip v5e pod
(data × model); the multi-pod mesh adds a leading pod axis (2 pods = 512
chips) carrying pure data parallelism across the DCN.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return make_mesh(shape, axes)
