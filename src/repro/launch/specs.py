"""input_specs(): ShapeDtypeStruct stand-ins for every dry-run cell.

Weak-type-correct, sharding-attached, zero allocation — the same pattern a
production launcher uses to AOT-compile before touching the cluster.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models import transformer as tfm
from repro.optim import OptimConfig
from repro.serving.engine import make_serve_step, pick_kv_chunks
from repro.training import TrainStepConfig, make_train_step, state_shapes
from repro.training import sharding as shd


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(
            mesh, shd.safe_spec(shape, spec, mesh)))


def _attach(mesh, abstract, specs):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=NamedSharding(mesh, s)),
        abstract, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def optim_for(arch: ArchConfig) -> OptimConfig:
    return OptimConfig(moment_dtype=arch.optimizer_dtype)


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch × shape × mesh) cell."""
    fn: Callable
    abstract_args: tuple
    kind: str
    description: str


def batch_abstract(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                   seq_len: int | None = None, batch: int | None = None):
    dp = shd.dp_axes(mesh)
    b = batch if batch is not None else shape.global_batch
    s = seq_len if seq_len is not None else shape.seq_len
    if arch.frontend == "audio":
        batch_t = {
            "features": _sds((b, s, arch.frontend_dim), jnp.float32, mesh,
                             P(dp, None, None)),
            "labels": _sds((b, s), jnp.int32, mesh, P(dp, None)),
            "mask": _sds((b, s), jnp.float32, mesh, P(dp, None)),
        }
    else:
        batch_t = {
            "tokens": _sds((b, s), jnp.int32, mesh, P(dp, None)),
            "labels": _sds((b, s), jnp.int32, mesh, P(dp, None)),
            "mask": _sds((b, s), jnp.float32, mesh, P(dp, None)),
        }
    return batch_t


def input_specs(arch: ArchConfig, shape: ShapeConfig,
                mesh: Mesh) -> CellSpec:
    """Build the (step_fn, abstract args) for one cell."""
    dp = shd.dp_axes(mesh)
    if shape.kind == "train":
        opt = optim_for(arch)
        ts = TrainStepConfig()
        step = make_train_step(arch, ts, opt)
        abstract = state_shapes(arch, opt)
        p_specs = shd.param_specs(arch, mesh, abstract["params"])
        o_specs = shd.opt_state_specs(arch, mesh, abstract["opt"], p_specs)
        state_abs = {
            "params": _attach(mesh, abstract["params"], p_specs),
            "opt": _attach(mesh, abstract["opt"], o_specs),
        }
        batch_abs = batch_abstract(arch, shape, mesh)
        return CellSpec(step, (state_abs, batch_abs), "train",
                        f"train_step {arch.name} b{shape.global_batch} "
                        f"s{shape.seq_len}")

    if shape.kind == "prefill":
        def prefill(params, batch):
            logits, aux = tfm.forward(params, arch, batch)
            return logits
        abstract_p = tfm.param_shapes(arch)
        p_specs = shd.param_specs(arch, mesh, abstract_p)
        params_abs = _attach(mesh, abstract_p, p_specs)
        batch_abs = batch_abstract(arch, shape, mesh)
        batch_abs.pop("labels", None)
        batch_abs.pop("mask", None)
        return CellSpec(prefill, (params_abs, batch_abs), "prefill",
                        f"prefill {arch.name} b{shape.global_batch} "
                        f"s{shape.seq_len}")

    # decode
    b = shape.global_batch
    kv_chunks = pick_kv_chunks(arch, mesh, b, shape.seq_len)
    spec = tfm.cache_spec(arch, max_len=shape.seq_len, kv_chunks=kv_chunks)
    serve = make_serve_step(arch, spec)
    abstract_p = tfm.param_shapes(arch)
    p_specs = shd.param_specs(arch, mesh, abstract_p)
    params_abs = _attach(mesh, abstract_p, p_specs)
    cache_abs = tfm.cache_shapes(arch, b, spec)
    c_specs = shd.cache_specs(arch, mesh, cache_abs, b)
    cache_abs = _attach(mesh, cache_abs, c_specs)
    tokens = _sds((b, 1), jnp.int32, mesh, P(dp, None))
    cur_len = jax.ShapeDtypeStruct((), jnp.int32)
    return CellSpec(serve, (params_abs, cache_abs, tokens, cur_len),
                    "decode",
                    f"serve_step {arch.name} b{b} cache={shape.seq_len} "
                    f"C={spec.kv_chunks if spec.kind == 'chunked' else 'ring'}")
