import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

``--comm`` switches to the *transfer-graph* dry-run instead: plan-only
``session.describe(...)`` rows (copy-node/edge counts, critical-path
depth, modeled times) over the standard topologies — no jax device init,
no compilation. ``repro.launch.report`` renders both row kinds.

For every non-skipped cell this driver:

1. builds ``input_specs`` (ShapeDtypeStruct + shardings, no allocation),
2. ``jax.jit(step).lower(...).compile()`` on the 16×16 single-pod mesh AND
   the 2×16×16 multi-pod mesh — the full-depth compile is the pass/fail
   artifact and supplies ``memory_analysis()`` (buffer assignment is
   while-loop-aware, so it is the fits-on-chip proof),
3. derives roofline FLOPs/bytes/collective-bytes by **loop extrapolation**:
   XLA's ``cost_analysis()`` counts a ``while`` body once regardless of trip
   count, so scanned-layer models would be undercounted ×L. We compile L=0
   and L=1 probes of the same cell and extrapolate
   ``total = cost(L0) + Σ_bodies n_i · (cost(L1ᵢ) − cost(L0))`` — gemma3's
   local/global stack uses two body probes (n_local=52, n_global=10),
4. appends the row to ``experiments/dryrun_results.json``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun [--arch a] [--shape s]
        [--mesh single|multi|both] [--out f.json] [--skip-existing]
"""

import argparse
import dataclasses
import json
import time
import traceback

from repro.compat import set_mesh


def _cost_tuple(compiled, default_group):
    from repro.launch import roofline
    cost = compiled.cost_analysis()
    stats = roofline.collective_bytes(compiled.as_text(), default_group)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            stats.total_wire_bytes,
            stats.by_op)


def _merge_by_op(base, body, n):
    out = {k: dict(v) for k, v in base.items()}
    for k, v in body.items():
        d = out.setdefault(k, {"count": 0, "wire_bytes": 0.0})
        d["count"] += n * v["count"]
        d["wire_bytes"] += n * v["wire_bytes"]
    return out


def lower_and_compile(arch, shape, mesh):
    import jax
    from repro.launch.specs import input_specs
    cell = input_specs(arch, shape, mesh)
    # set_mesh (not the legacy `with mesh:`) — it installs the abstract mesh
    # so the model's activation sharding constraints resolve.
    with set_mesh(mesh):
        lowered = jax.jit(cell.fn).lower(*cell.abstract_args)
        compiled = lowered.compile()
    return cell, compiled


def body_probes(arch):
    """[(count, probe_cfg)] covering the layer stack's body types."""
    if arch.attention == "local_global":
        r = arch.local_global_ratio
        n_global = sum(1 for i in range(arch.num_layers) if i % (r + 1) == r)
        n_local = arch.num_layers - n_global
        local = dataclasses.replace(arch, num_layers=1)
        glob = dataclasses.replace(arch, num_layers=1, attention="full",
                                   local_global_ratio=0, window=None)
        return [(n_local, local), (n_global, glob)]
    return [(arch.num_layers, dataclasses.replace(arch, num_layers=1))]


def extrapolated_cost(arch, shape, mesh):
    """(flops, hbm_bytes, wire_bytes, by_op) per device, loop-corrected."""
    base_cfg = dataclasses.replace(arch, num_layers=0)
    _, c0 = lower_and_compile(base_cfg, shape, mesh)
    group = mesh.shape.get("model", 1)
    f0, b0, w0, op0 = _cost_tuple(c0, group)
    flops, bytes_, wire, by_op = f0, b0, w0, {k: dict(v)
                                              for k, v in op0.items()}
    for count, probe_cfg in body_probes(arch):
        _, c1 = lower_and_compile(probe_cfg, shape, mesh)
        f1, b1, w1, op1 = _cost_tuple(c1, group)
        flops += count * max(0.0, f1 - f0)
        bytes_ += count * max(0.0, b1 - b0)
        wire += count * max(0.0, w1 - w0)
        body_ops = {k: {"count": v["count"] - op0.get(k, {}).get("count", 0),
                        "wire_bytes": v["wire_bytes"] -
                        op0.get(k, {}).get("wire_bytes", 0.0)}
                    for k, v in op1.items()}
        by_op = _merge_by_op(by_op, body_ops, count)
    return flops, bytes_, wire, by_op


def run_cell(arch, shape, mesh, mesh_name):
    import jax
    from repro.launch import roofline

    cell, compiled = lower_and_compile(arch, shape, mesh)
    mem = compiled.memory_analysis()
    flops, hbm, wire, by_op = extrapolated_cost(arch, shape, mesh)
    chips = mesh.devices.size
    tokens = shape.global_batch * shape.seq_len
    nap = arch.active_param_count()
    if shape.kind == "train":
        mflops = roofline.train_model_flops(nap, tokens)
    elif shape.kind == "prefill":
        mflops = roofline.prefill_model_flops(nap, tokens)
    else:
        mflops = roofline.decode_model_flops(nap, shape.global_batch)
    mem_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    from repro.core.topology import (HBM_GBPS, ICI_LINK_GBPS,
                                     PEAK_BF16_TFLOPS)
    compute_s = flops / (PEAK_BF16_TFLOPS * 1e12)
    memory_s = hbm / (HBM_GBPS * 1e9)
    collective_s = wire / (ICI_LINK_GBPS * 1e9)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    row = {
        "arch": arch.name, "shape": shape.name, "mesh": mesh_name,
        "status": "ok", "kind": shape.kind, "chips": chips,
        "description": cell.description,
        "flops": flops, "hbm_bytes": hbm, "wire_bytes": wire,
        "collective_by_op": by_op,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "bottleneck": bottleneck,
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / (flops * chips)
                               if flops else 0.0),
        "memory_per_device_gb": mem_bytes / 2**30,
        "argument_gb": mem.argument_size_in_bytes / 2**30,
        "output_gb": mem.output_size_in_bytes / 2**30,
        "temp_gb": mem.temp_size_in_bytes / 2**30,
        "alias_gb": mem.alias_size_in_bytes / 2**30,
    }
    return row


#: (name, constructor) cells swept by the ``--comm`` transfer-graph dry-run.
def _comm_topologies():
    """(name, topology, (src, dst)) sweep cells; the hierarchical cell
    describes a cross-island transfer so the staged-routing and
    flat-vs-two-level model rows land in the dry-run artifact."""
    from repro.core.topology import Topology
    return [
        ("beluga4", Topology.full_mesh(4), (0, 1)),
        ("narval4", Topology.full_mesh(4, sublinks_per_pair=4,
                                       name="narval4"), (0, 1)),
        ("torus4x4", Topology.torus2d(4, 4), (0, 1)),
        ("hier2x4", Topology.hierarchical(2, 4, egress_per_island=2,
                                          name="hier2x4"), (1, 7)),
    ]


def _route_strs(plan) -> list[str]:
    """``src->via->dst`` strings, one per plan path, in share order."""
    return ["->".join(str(n) for n in (pa.route.hops[0].src,
                                       *(h.dst for h in pa.route.hops)))
            for pa in plan.paths]


def run_comm_dryrun(out_path: str,
                    fail_link: tuple[int, int] | None = None) -> list[dict]:
    """Plan-only sweep: ``session.describe`` over topology × size × paths,
    plus a schedule sweep over the shipped chunk-interleaving passes.

    Every ``comm_graph`` row is one transfer graph — node/edge counts,
    critical-path depth, canonical digest, and the analytic model's
    costs; every ``comm_schedule`` row is one (topology, size, scheduler)
    cell with the scheduled graph's modeled time and its delta vs the
    ``round_robin`` baseline (DESIGN.md §2.2). With ``fail_link`` every
    topology that carries that directional link additionally emits a
    ``comm_fault`` row: the steady-state plan before the fault and the
    surviving-routes re-plan after ``fail_link`` (routes, modeled
    bandwidth, DESIGN §4.6 ladder level), the restore leaving the
    topology untouched. Appended to ``out_path`` (replacing stale comm
    rows) next to the model-cell rows so one JSON feeds
    ``repro.launch.report``.
    """
    from repro.comm import SCHEDULE_NAMES, CommConfig, CommSession

    MiB = 1 << 20
    rows = []
    for topo_name, topo, (src, dst) in _comm_topologies():
        sess = CommSession(CommConfig(multipath_threshold=MiB),
                           topology=topo)
        for nbytes in (1 * MiB, 8 * MiB, 64 * MiB):
            for max_paths in (1, 3):
                d = sess.describe(src, dst, nbytes, max_paths=max_paths)
                row = {"kind": "comm_graph", "status": "ok",
                       "topology": topo_name,
                       "nbytes": nbytes, "max_paths": max_paths,
                       "num_paths": d["num_paths"], **d["graph"],
                       **d["model"],
                       "islands": d["hierarchy"]["islands"],
                       "cross_island": d["hierarchy"]["cross_island"]}
                rows.append(row)
                print(f"COMM {topo_name} {nbytes >> 20}MiB "
                      f"paths={d['num_paths']} nodes={d['graph']['nodes']} "
                      f"edges={d['graph']['edges']} "
                      f"cp={d['graph']['critical_path_nodes']} "
                      f"bw={d['model']['effective_gbps']:.1f}GB/s",
                      flush=True)
        for nbytes in (8 * MiB, 64 * MiB):
            for sched in SCHEDULE_NAMES:
                d = sess.describe(src, dst, nbytes, max_paths=3,
                                  schedule=sched)
                s = d["schedule"]
                rows.append({
                    "kind": "comm_schedule", "status": "ok",
                    "topology": topo_name, "nbytes": nbytes,
                    "schedule": sched, "chosen": s["chosen"],
                    "nodes": d["graph"]["nodes"],
                    "digest": d["graph"]["digest"],
                    "scheduled_time_s": s["scheduled_time_s"],
                    "delta_vs_round_robin_s":
                        s["delta_vs_round_robin_s"],
                })
                print(f"SCHED {topo_name} {nbytes >> 20}MiB "
                      f"{sched}->{s['chosen']} "
                      f"t={s['scheduled_time_s'] * 1e6:.1f}us "
                      f"d={s['delta_vs_round_robin_s'] * 1e9:.0f}ns",
                      flush=True)
        if fail_link is not None:
            fsrc, fdst = fail_link
            try:
                sess.topology.link(fsrc, fdst)
            except KeyError:
                print(f"FAULT {topo_name}: no link {fsrc}->{fdst}, skipped",
                      flush=True)
                continue

            def _cell(level_hint=None):
                d = sess.describe(src, dst, 8 * MiB, max_paths=3)
                plan = sess.plan(src, dst, 8 * MiB, max_paths=3)
                level = (level_hint if level_hint is not None
                         else (1 if d["num_paths"] > 1 else 2))
                return {"num_paths": d["num_paths"],
                        "routes": _route_strs(plan),
                        "effective_gbps": d["model"]["effective_gbps"],
                        "scheduled_time_s":
                            d["schedule"]["scheduled_time_s"],
                        "level": level}

            before = _cell(level_hint=0)
            sess.topology.fail_link(fsrc, fdst)
            after = _cell()
            sess.topology.restore_link(fsrc, fdst)
            rows.append({"kind": "comm_fault", "status": "ok",
                         "topology": topo_name, "nbytes": 8 * MiB,
                         "src": src, "dst": dst,
                         "failed_link": [fsrc, fdst],
                         "before": before, "after": after})
            print(f"FAULT {topo_name} link {fsrc}->{fdst} down: "
                  f"paths {before['num_paths']}->{after['num_paths']} "
                  f"bw {before['effective_gbps']:.1f}->"
                  f"{after['effective_gbps']:.1f}GB/s "
                  f"ladder {before['level']}->{after['level']}",
                  flush=True)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    results = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    results = [r for r in results
               if r.get("kind") not in ("comm_graph", "comm_schedule",
                                        "comm_fault")]
    results.extend(rows)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\ncomm dry-run complete: {len(rows)} rows")
    return rows


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--arch", default=None)
    parser.add_argument("--shape", default=None)
    parser.add_argument("--mesh", default="both",
                        choices=["single", "multi", "both"])
    parser.add_argument("--out", default="experiments/dryrun_results.json")
    parser.add_argument("--skip-existing", action="store_true")
    parser.add_argument("--comm", action="store_true",
                        help="transfer-graph dry-run (plan-only, no jax "
                             "device init)")
    parser.add_argument("--fail-link", metavar="SRC:DST", default=None,
                        help="with --comm: also emit before/after re-plan "
                             "rows with the directional link SRC:DST "
                             "failed (DESIGN §4.6 degraded mode)")
    args = parser.parse_args()

    if args.comm:
        fail = None
        if args.fail_link:
            try:
                a, b = args.fail_link.split(":")
                fail = (int(a), int(b))
            except ValueError:
                parser.error("--fail-link expects SRC:DST device ints, "
                             f"got {args.fail_link!r}")
        run_comm_dryrun(args.out, fail_link=fail)
        return
    if args.fail_link:
        parser.error("--fail-link only applies to the --comm dry-run")

    import jax

    from repro.configs import load_all, REGISTRY
    from repro.configs.shapes import SHAPES, skip_reason
    from repro.launch.mesh import make_production_mesh

    assert len(jax.devices()) == 512, (
        "dry-run needs 512 placeholder devices; do not import jax before "
        "this module sets XLA_FLAGS")

    load_all()
    archs = ([REGISTRY[args.arch.replace("-", "_")]] if args.arch
             else [REGISTRY[k] for k in sorted(REGISTRY)])
    shapes = ([SHAPES[args.shape]] if args.shape else list(SHAPES.values()))
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    for arch in archs:
        for shape in shapes:
            reason = skip_reason(arch, shape)
            for mesh_name, mesh in meshes:
                key = (arch.name, shape.name, mesh_name)
                if args.skip_existing and key in done:
                    print(f"SKIP(done) {key}", flush=True)
                    continue
                if reason:
                    row = {"arch": arch.name, "shape": shape.name,
                           "mesh": mesh_name, "status": "skipped",
                           "reason": reason}
                    print(f"SKIP {key}: {reason}", flush=True)
                else:
                    t0 = time.time()
                    try:
                        row = run_cell(arch, shape, mesh, mesh_name)
                        row["compile_s"] = round(time.time() - t0, 1)
                        print(f"OK   {key} compile={row['compile_s']}s "
                              f"mem/dev={row['memory_per_device_gb']:.2f}GiB "
                              f"bneck={row['bottleneck']} "
                              f"[c={row['compute_s']*1e3:.1f}ms "
                              f"m={row['memory_s']*1e3:.1f}ms "
                              f"n={row['collective_s']*1e3:.1f}ms] "
                              f"useful={row['useful_flops_ratio']:.2f}",
                              flush=True)
                    except Exception as e:  # noqa: BLE001
                        row = {"arch": arch.name, "shape": shape.name,
                               "mesh": mesh_name, "status": "error",
                               "error": f"{type(e).__name__}: {e}",
                               "traceback": traceback.format_exc()[-2000:],
                               "compile_s": round(time.time() - t0, 1)}
                        print(f"FAIL {key}: {row['error']}", flush=True)
                results = [r for r in results if
                           (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(row)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    ok = sum(1 for r in results if r.get("status") == "ok")
    sk = sum(1 for r in results if r.get("status") == "skipped")
    er = sum(1 for r in results if r.get("status") == "error")
    print(f"\ndry-run complete: ok={ok} skipped={sk} error={er}")
    if er:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
