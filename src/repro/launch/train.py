"""Training driver: data → sharded train loop → checkpoints → fault recovery.

Runs real steps on whatever devices exist (reduced configs on this CPU
container; the identical builder lowers the full configs in the dry-run).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
        --reduced --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time


def build(arch_name: str, *, reduced: bool, steps: int, batch: int,
          seq: int, lr: float, microbatches: int, ckpt_dir: str | None,
          mesh=None):
    import jax

    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticDataset
    from repro.optim import OptimConfig
    from repro.training import TrainStepConfig, init_state, make_train_step

    cfg = get_config(arch_name)
    if reduced:
        cfg = cfg.reduced()
    opt = OptimConfig(learning_rate=lr, warmup_steps=max(1, steps // 20),
                      total_steps=steps, moment_dtype=cfg.optimizer_dtype
                      if not reduced else "float32")
    ts = TrainStepConfig(microbatches=microbatches)
    step_fn = jax.jit(make_train_step(cfg, ts, opt), donate_argnums=(0,))
    state = init_state(cfg, opt, mesh=mesh)
    ds = SyntheticDataset(cfg, DataConfig(seq_len=seq, global_batch=batch))
    return cfg, step_fn, state, ds


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager
    from repro.runtime import StragglerDetector

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm_360m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args()

    cfg, step_fn, state, ds = build(
        args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt is not None:
        restored = ckpt.restore_latest(jax.eval_shape(lambda: state))
        if restored is not None:
            state, start, _ = restored[0], restored[1], restored[2]
            print(f"restored checkpoint at step {start}")

    straggler = StragglerDetector()
    t_begin = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if straggler.observe(step, dt):
            print(f"step {step}: straggler ({dt:.2f}s vs median "
                  f"{straggler.median_s:.2f}s)")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt is not None:
        ckpt.save(args.steps, state)
        ckpt.wait()
    print(f"done: {args.steps - start} steps in {time.time()-t_begin:.1f}s")


if __name__ == "__main__":
    main()
