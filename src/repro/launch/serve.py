"""Serving driver: batched prefill + decode on a reduced config.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b \
        --requests 4 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    import jax

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.serving import Request, ServeEngine

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3_8b")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=12)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--max-len", type=int, default=64)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    params = tfm.init_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, max_len=args.max_len,
                         kv_chunks=4, temperature=args.temperature)
    rng = jax.random.key(1)
    reqs = []
    for i in range(args.requests):
        rng, sub = jax.random.split(rng)
        prompt = jax.random.randint(
            sub, (args.prompt_len,), 0, cfg.vocab_size).tolist()
        reqs.append(Request(prompt=prompt, max_new_tokens=args.new_tokens))
    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    for i, r in enumerate(done):
        print(f"req{i}: prompt[:4]={r.prompt[:4]} -> out[:8]={r.out[:8]}")
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s batched)")


if __name__ == "__main__":
    main()
