"""Distributed MoE: explicit shard_map expert parallelism.

GSPMD cannot partition the capacity-dispatch scatter across (data × model)
without replicating terabytes (measured on kimi-k2: 857 GiB/device, 1.1e14
collective wire bytes). This module takes manual control:

* tokens enter replicated across the model axis (the natural state at the
  Megatron-SP boundary: the (B·S) dim is data-sharded, model-replicated),
* **dispatch is communication-free**: every model rank selects, sorts, and
  scatters only the tokens routed to ITS experts (EP) — or all tokens into
  its ff-shard (expert-TP fallback when E < model size),
* expert GEMMs run on local shards,
* **combine is one psum over the model axis** of the (T_local, d) output —
  each token's k expert contributions live on ≤k ranks, everyone else adds
  zeros. The psum also merges expert-TP partial sums for free.

Per-layer collective bytes drop from O(buffer × replication) to exactly one
(T_local × d) all-reduce — the same wire cost as a Megatron TP MLP.

The pure-jnp fallback (``repro.models.moe``) remains the reference; the two
paths agree to float tolerance (``tests/test_moe_dist.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models import pspec


def _mesh_info():
    mesh = pspec._ambient_mesh()
    if mesh is None:
        return None
    shape = dict(mesh.shape)
    model = shape.get("model", 1)
    if model <= 1:
        return None
    dp = tuple(a for a in ("pod", "data") if a in shape)
    return mesh, dp, model


def _local_moe(x, router, w1, w3, w2, *, top_k: int, kind: str,
               capacity: int, num_experts: int, model_size: int,
               ep: bool, fsdp: bool, dp_axes: tuple):
    """Per-device body. x: (Tl, d) local tokens (replicated over model)."""
    tl, d = x.shape
    e = num_experts

    # -- FSDP weight gathering (ZeRO-3 all-gather before use) -------------
    if fsdp and dp_axes:
        ax = dp_axes[-1]  # "data"
        w1 = lax.all_gather(w1, ax, axis=1, tiled=True)
        w2 = lax.all_gather(w2, ax, axis=2, tiled=True)
        if w3 is not None:
            w3 = lax.all_gather(w3, ax, axis=1, tiled=True)

    # -- routing (identical on every model rank) ---------------------------
    logits = x.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, top_k)          # (Tl, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    flat_ids = expert_ids.reshape(-1)                        # (Tl*k,)
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    token_of = order // top_k
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(e), side="left")
    pos = jnp.arange(tl * top_k) - seg_start[sorted_ids]
    keep = pos < capacity

    r = lax.axis_index("model")
    if ep:
        el = e // model_size
        e0 = r * el
        mine = keep & (sorted_ids >= e0) & (sorted_ids < e0 + el)
        local_e = jnp.where(mine, sorted_ids - e0, el)       # OOB ⇒ drop
        n_buf = el
    else:
        mine = keep
        local_e = jnp.where(mine, sorted_ids, e)
        n_buf = e
    safe_pos = jnp.where(mine, pos, capacity)

    buf = jnp.zeros((n_buf, capacity, d), x.dtype)
    buf = buf.at[local_e, safe_pos].set(x[token_of], mode="drop")

    h = jnp.einsum("ecd,edf->ecf", buf, w1, optimize=True)
    if kind in ("swiglu", "geglu"):
        u = jnp.einsum("ecd,edf->ecf", buf, w3, optimize=True)
        act = jax.nn.silu(h) if kind == "swiglu" else jax.nn.gelu(h)
        h = act * u
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("ecf,efd->ecd", h, w2, optimize=True)

    back = y.at[local_e, safe_pos].get(mode="fill", fill_value=0)
    weights = gate_vals.reshape(-1)[order] * mine
    out = jnp.zeros_like(x).at[token_of].add(
        (back * weights[:, None]).astype(x.dtype))
    # combine: sums each token's k expert contributions across their owner
    # ranks (EP) and/or the ff-shard partial sums (expert-TP).
    return lax.psum(out, "model")


def moe_apply_dist(x: jax.Array, params: dict, *, top_k: int, kind: str,
                   capacity_factor: float = 1.25, dropless: bool = False,
                   fsdp: bool = False):
    """shard_map MoE. x: (T, d) → (out, aux). Falls back to None when no
    model-parallel mesh is ambient (caller uses the pure-jnp path)."""
    info = _mesh_info()
    if info is None:
        return None
    mesh, dp, model = info
    t, d = x.shape
    e = params["router"].shape[-1]
    ndp = 1
    for a in dp:
        ndp *= dict(mesh.shape)[a]
    if t % max(1, ndp):
        return None
    tl = t // max(1, ndp)
    capacity = tl if dropless else max(
        1, int(tl * top_k / e * capacity_factor))
    ep = e % model == 0

    # aux loss from a (cheap) replicated routing pass outside the region
    logits = (x.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, expert_ids = lax.top_k(probs, top_k)
    density = jnp.mean(
        jax.nn.one_hot(expert_ids, e, dtype=jnp.float32).sum(1), 0)
    aux = e * jnp.sum(density * jnp.mean(probs, 0))

    fsdp = fsdp and "data" in dict(mesh.shape)
    w3 = params.get("w3")
    fs = "data" if fsdp else None
    w_spec = (P("model", fs, None) if ep else P(None, fs, "model"))
    w2_spec = (P("model", None, fs) if ep else P(None, "model", fs))

    body = functools.partial(
        _local_moe, top_k=top_k, kind=kind, capacity=capacity,
        num_experts=e, model_size=model, ep=ep, fsdp=fsdp, dp_axes=dp)

    def wrapped(xl, router, w1, w3_, w2):
        return body(xl, router, w1, w3_, w2)

    in_specs = (P(dp, None), P(None, None), w_spec,
                (w_spec if w3 is not None else P(None, None, None)),
                w2_spec)
    fn = shard_map(wrapped, mesh=mesh, in_specs=in_specs,
                   out_specs=P(dp, None), check_vma=False)
    if w3 is None:
        w3 = jnp.zeros((e, 1, 1), x.dtype)  # placeholder, unused by kinds
    out = fn(x, params["router"], params["w1"], w3, params["w2"])
    return out, aux
