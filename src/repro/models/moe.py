"""Mixture-of-Experts block: top-k routing with shard-local static dispatch.

Design constraints (kimi-k2: 384 experts → one-hot (T, E, C) dispatch
tensors are infeasible) and distribution constraints (the dispatch must not
force GSPMD to replicate or all-gather the token stream):

* the token stream is reshaped to ``(shards, T_local, d)`` where ``shards``
  is the data-parallel world size — routing, the capacity sort, and the
  scatter/gather all carry the shard dim, so under GSPMD every dispatch op
  is *local to its data shard* (no cross-shard collectives),
* position-within-expert comes from a searchsorted over the sorted ids
  (O(T·k) memory — no (T, E) one-hots),
* the capacity buffer is ``(shards, E, C_local, d)``; expert GEMMs are
  batched over shards.

Expert sharding (see ``training/sharding.py``): E over the model axis when
divisible (EP — kimi's 384 experts), otherwise per-expert tensor
parallelism on the FFN hidden dim (mixtral's 8 experts on a 16-wide axis);
the activation constraints in ``pspec.moe_buf``/``pspec.moe_hidden`` match.

Includes the Switch-style load-balancing auxiliary loss and optional shared
experts (kimi/DeepSeek recipe).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import pspec
from repro.models.layers import mlp_apply, mlp_init


def moe_init(key, d: int, ff: int, num_experts: int, kind: str,
             num_shared: int, dtype) -> dict:
    keys = jax.random.split(key, 4)
    scale_in, scale_out = d ** -0.5, ff ** -0.5
    p = {
        "router": jax.random.normal(keys[0], (d, num_experts),
                                    jnp.float32) * scale_in,
        "w1": jax.random.normal(keys[1], (num_experts, d, ff),
                                dtype) * scale_in,
        "w2": jax.random.normal(keys[2], (num_experts, ff, d),
                                dtype) * scale_out,
    }
    if kind in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(keys[3], (num_experts, d, ff),
                                    dtype) * scale_in
    if num_shared:
        p["shared"] = mlp_init(keys[3], d, ff * num_shared, kind, dtype)
    return p


def _num_shards(t: int) -> int:
    mesh = pspec._ambient_mesh()
    if mesh is None:
        return 1
    shape = dict(mesh.shape)
    n = 1
    for a in ("pod", "data"):
        n *= shape.get(a, 1)
    return n if (n > 1 and t % n == 0) else 1


def moe_apply(x: jax.Array, params: dict, *, top_k: int, kind: str,
              capacity_factor: float = 1.25, dropless: bool = False,
              ) -> tuple[jax.Array, jax.Array]:
    """x: (T, d) flattened tokens → (out (T, d), aux_loss scalar).

    ``dropless=True`` sets capacity to the worst case (T_local) — used on
    the decode path where token drops would corrupt generation; training
    uses the capacity factor (GShard-style dropping, applied per shard).
    """
    t, d = x.shape
    e = params["router"].shape[-1]
    ns = _num_shards(t)
    tl = t // ns                                   # tokens per data shard
    if dropless:
        capacity = tl
    else:
        capacity = max(1, int(tl * top_k / e * capacity_factor))

    xs = pspec.constrain(x.reshape(ns, tl, d), pspec.DP, None, None)

    logits = jnp.einsum("std,de->ste", xs.astype(jnp.float32),
                        params["router"], optimize=True)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)     # (s, Tl, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balance aux loss (per shard, then averaged): E · Σ_e f_e · p_e
    density = jnp.mean(
        jax.nn.one_hot(expert_ids, e, dtype=jnp.float32).sum(2), axis=1)
    aux = e * jnp.mean(jnp.sum(density * jnp.mean(probs, 1), -1))

    flat_ids = expert_ids.reshape(ns, tl * top_k)           # (s, Tl*k)
    order = jnp.argsort(flat_ids, axis=-1)                  # per-shard sort
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    token_of = order // top_k                               # (s, Tl*k)
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e), side="left"))(
            sorted_ids)                                     # (s, E)
    pos = (jnp.arange(tl * top_k)[None, :]
           - jnp.take_along_axis(seg_start, sorted_ids, axis=-1))
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, capacity)               # OOB ⇒ dropped

    sidx = jnp.broadcast_to(jnp.arange(ns)[:, None], sorted_ids.shape)
    gathered_tokens = jnp.take_along_axis(
        xs, token_of[..., None], axis=1)                    # (s, Tl*k, d)
    buf = jnp.zeros((ns, e, capacity, d), x.dtype)
    buf = buf.at[sidx, sorted_ids, safe_pos].set(gathered_tokens,
                                                 mode="drop")
    # the scatter is SHARD-LOCAL: buf leaves it data-sharded on dim 0 and
    # replicated over model. The EP reshard below (slice E per model rank)
    # is then communication-free; GSPMD handed the cross-(data×model)
    # scatter directly produced TB-scale update replication.
    buf = pspec.constrain(buf, pspec.DP, None, None, None)
    buf = pspec.moe_buf(buf, e)

    h = jnp.einsum("secd,edf->secf", buf, params["w1"], optimize=True)
    if kind in ("swiglu", "geglu"):
        u = jnp.einsum("secd,edf->secf", buf, params["w3"], optimize=True)
        act = jax.nn.silu(h) if kind == "swiglu" else jax.nn.gelu(h)
        h = act * u
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    h = pspec.moe_hidden(h, e)
    y = pspec.moe_buf(
        jnp.einsum("secf,efd->secd", h, params["w2"], optimize=True), e)
    # un-shard E for the shard-local gather-back (all-gather over model —
    # the baseline EP combine; §Perf replaces it with the explicit
    # multipath all-to-all, which only moves each token to its k owners).
    y = pspec.constrain(y, pspec.DP, None, None, None)

    back = y.at[sidx, sorted_ids, safe_pos].get(
        mode="fill", fill_value=0)                          # (s, Tl*k, d)
    weights = (jnp.take_along_axis(
        gate_vals.reshape(ns, tl * top_k), order, axis=-1) * keep)
    out = jnp.zeros_like(xs)
    out = out.at[sidx, token_of].add(
        (back * weights[..., None]).astype(x.dtype))
    out = pspec.constrain(out, pspec.DP, None, None).reshape(t, d)

    if "shared" in params:
        out = out + mlp_apply(x, params["shared"], kind)
    return out, aux
