"""Activation sharding constraints that degrade gracefully without a mesh.

GSPMD sharding propagation alone is not reliable through scanned layer
bodies — without anchors it happily re-shards activations from batch-split
to head-split (observed: 218 GiB/device temp on llama3-8b train). These
helpers pin the standard megatron-style activation layout:

* batch dims → (pod, data)
* head / hidden (TP) dims → model
* everything else replicated

``constrain`` is a no-op when no mesh is ambient (unit tests, single-CPU
smoke runs) and silently drops axes that do not divide (smollm's 15 heads).
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh

DP = ("pod", "data")   # logical batch axes (filtered per ambient mesh)


def _ambient_mesh():
    try:
        m = get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return None
    if m is None or not getattr(m, "axis_names", ()):
        return None
    return m


def _safe(shape, spec, mesh) -> P:
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        axes = [a for a in axes if a in mesh.axis_names]
        keep = []
        size = shape[i]
        for a in axes:
            n = mesh.shape[a]
            if n > 1 and size % n == 0:
                keep.append(a)
                size //= n
        out.append(tuple(keep) if len(keep) > 1 else
                   (keep[0] if keep else None))
    return P(*out)


def constrain(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint(x, P(*entries)) with fallback semantics."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    entries = list(entries) + [None] * (x.ndim - len(entries))
    spec = _safe(x.shape, P(*entries[:x.ndim]), mesh)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # noqa: BLE001 — no mesh context at trace time
        return x


def batch_first(x: jax.Array) -> jax.Array:
    """(B, ...) → batch over DP, rest replicated."""
    return constrain(x, DP)


def batch_heads(x: jax.Array) -> jax.Array:
    """(B, H, ...) → batch over DP, heads over model."""
    return constrain(x, DP, "model")


def batch_seq_heads(x: jax.Array) -> jax.Array:
    """(B, S, H, hd) or (B, H, S, hd): batch over DP, dim1... use explicit."""
    return constrain(x, DP, "model", None, None)


def batch_seq_hidden(x: jax.Array) -> jax.Array:
    """(B, S, ff): batch over DP, hidden over model (TP MLP)."""
    return constrain(x, DP, None, "model")


def hidden_last(x: jax.Array) -> jax.Array:
    """batch over DP on dim 0, TP on the last dim (MLP hidden)."""
    entries = [DP] + [None] * (x.ndim - 2) + ["model"]
    return constrain(x, *entries)


def seq_model(x: jax.Array) -> jax.Array:
    """(B, S, d): batch over DP, SEQUENCE over model (Megatron-SP layout).

    Used for the between-block residual stream: remat saves one carry per
    layer, and sequence-sharding it divides that stack by the model-axis
    size (llama3-8b train_4k: 16 GiB → 1 GiB/device).
    """
    return constrain(x, DP, "model", None)


def attn_qkv(x: jax.Array, role: str = "q") -> jax.Array:
    """(B, H, S, hd): heads over model when divisible. Fallbacks differ by
    role (§Perf iteration N1):

    * q (and k/v when q also can't head-shard): sequence over model —
      context parallelism (smollm's 15 / hymba's 25 heads),
    * k/v under GQA with head-sharded q: REPLICATE over model. Seq-sharding
      them against head-sharded q made the blockwise-attention scan
      re-gather every K/V block per step (nemotron: +TBs of all-gather);
      GQA k/v tensors are small — recomputing the projection everywhere is
      cheaper than any exchange.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    model = dict(mesh.shape).get("model", 1)
    if x.shape[1] % model == 0:
        return constrain(x, DP, "model", None, None)
    if role == "kv":
        return constrain(x, DP, None, None, None)
    return constrain(x, DP, None, "model", None)


def moe_buf(x: jax.Array, num_experts: int) -> jax.Array:
    """(shards, E, C, d) expert capacity buffers: shard dim over DP always;
    E over model under EP, replicated under the expert-TP fallback
    (E < model-axis size — mixtral)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    model = dict(mesh.shape).get("model", 1)
    if num_experts % model == 0:
        return constrain(x, DP, "model", None, None)
    return constrain(x, DP, None, None, None)


def moe_hidden(x: jax.Array, num_experts: int) -> jax.Array:
    """(shards, E, C, ff): under expert-TP the hidden dim carries the model
    axis (per-expert megatron split); under EP it follows the E dim."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    model = dict(mesh.shape).get("model", 1)
    if num_experts % model == 0:
        return constrain(x, DP, "model", None, None)
    return constrain(x, DP, None, None, "model")


def heads_shardable(num_heads: int) -> bool:
    """True when the q-head dim divides the ambient model axis."""
    mesh = _ambient_mesh()
    if mesh is None:
        return True
    model = dict(mesh.shape).get("model", 1)
    return num_heads % model == 0


def weight_gathered(w: jax.Array, tp_dim: int | None = None) -> jax.Array:
    """ZeRO-3 gather-before-use (§Perf iteration N3): FSDP-sharded weights
    flowing straight into a matmul make GSPMD bounce the ACTIVATIONS into
    d-sharded / batch-gathered layouts (nemotron: ~14 GB/layer of
    all-reduce + collective-permute on batch-replicated tensors). Gathering
    the weight to its TP-only layout first costs one weight-sized
    all-gather (0.7-2.7 GB/layer) instead.

    ``tp_dim`` is the dim that keeps the model axis (None = fully
    replicated).
    """
    entries = [None] * w.ndim
    if tp_dim is not None:
        entries[tp_dim] = "model"
    return constrain(w, *entries)
