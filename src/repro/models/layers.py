"""Shared model layers: norms, RoPE, attention variants, MLP variants.

Attention comes in three memory-honest flavours:

* ``naive_attention``     — materializes (Sq, Sk); used for short sequences
                            (smoke tests) where it is cheapest to compile.
* ``blockwise_attention`` — lax.scan over KV blocks with online softmax
                            (flash-attention structure in pure XLA). This is
                            what the dry-run lowers for 32k prefill; the
                            Pallas kernel in ``repro.kernels.flash_attention``
                            is the TPU fast path with identical semantics.
* ``chunked_decode_attention`` — flash-decoding split-KV for serve steps:
                            the cache carries an explicit chunk dim that the
                            launcher shards over the model axis; partial
                            (m, l, o) stats merge with a log-sum-exp
                            reduction over chunks (small collectives instead
                            of gathering the cache).

The sliding window is a *traced scalar* (−1 = full attention) so
local/global stacks (gemma3) scan over a per-layer window array with a
single code path.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import pspec

NEG_INF = -1e30


# -- norms -----------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with a hand-written VJP.

    Two dtype rules matter at scale (§Perf iteration N2):

    * never materialize an f32 copy of x — a wholesale ``x.astype(f32)``
      of the layer carry gets loop-hoisted by XLA into an f32 duplicate of
      the entire saved-activation stack (+32 GiB/device, llama3-8b train);
    * keep the x-cotangent in ``x.dtype`` — autodiff through an
      f32-accumulated variance reduction promotes the whole residual-stream
      cotangent to f32, doubling every backward collective (nemotron: TBs
      of f32 all-gathers). Row statistics still accumulate in f32.
    """
    y, _ = _rms_norm_fwd(x, weight, eps)
    return y


def _rms_stats(x):
    var = (jnp.einsum("...d,...d->...", x, x,
                      preferred_element_type=jnp.float32)[..., None]
           / x.shape[-1])
    return var


def _rms_norm_fwd(x, weight, eps):
    inv = lax.rsqrt(_rms_stats(x) + eps)               # (..., 1) f32
    y = x * inv.astype(x.dtype) * (1.0 + weight).astype(x.dtype)
    return y, (x, weight, inv)


def _rms_norm_bwd(eps, res, g):
    x, weight, inv = res
    d = x.shape[-1]
    w1 = (1.0 + weight).astype(x.dtype)
    t = g * w1                                          # (..., d) x.dtype
    # rowwise f32 accumulation; per-row scalars only
    s = jnp.einsum("...d,...d->...", t, x,
                   preferred_element_type=jnp.float32)[..., None]
    coef = (inv * inv * inv * s / d)
    dx = t * inv.astype(x.dtype) - x * coef.astype(x.dtype)
    dw = jnp.einsum("...d,...d->d", g.astype(jnp.float32),
                    (x * inv.astype(x.dtype)).astype(jnp.float32))
    return dx, dw.astype(weight.dtype)


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


# -- rotary embeddings --------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (..., S, hd); positions: (S,) or broadcastable int32.

    Angles (small (S, hd/2) tables) are f32; the rotation multiplies in
    ``x.dtype`` — upcasting x here doubled the activation bytes that cross
    the SP boundary collectives (§Perf iteration N2)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (S, hd/2)
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


# -- attention ----------------------------------------------------------------
def _window_mask(row: jax.Array, col: jax.Array, window: jax.Array,
                 causal: bool) -> jax.Array:
    """row/col: broadcastable global positions; window: traced scalar,
    window < 0 means unlimited."""
    mask = jnp.ones(jnp.broadcast_shapes(row.shape, col.shape), bool)
    if causal:
        mask &= col <= row
    mask &= (window < 0) | (col > row - window)
    return mask


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, window: jax.Array | int | None,
                    scale: float) -> jax.Array:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd) — GQA via head folding."""
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    qpk = hq // hkv
    window = jnp.asarray(-1 if window is None else window, jnp.int32)
    qg = q.reshape(b, hkv, qpk, sq, hd)
    s = jnp.einsum("bgqtd,bgsd->bgqts", qg.astype(jnp.float32),
                   k.astype(jnp.float32), optimize=True) * scale
    row = jnp.arange(sq)[:, None] + (sk - sq)   # right-aligned positions
    col = jnp.arange(sk)[None, :]
    mask = _window_mask(row, col, window, causal)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqts,bgsd->bgqtd", p, v.astype(jnp.float32),
                   optimize=True)
    return o.reshape(b, hq, sq, hd).astype(q.dtype)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: jax.Array | int | None,
                        scale: float, block_k: int = 1024) -> jax.Array:
    """Flash-structured attention: scan over KV blocks, online softmax.

    Never materializes more than (..., Sq, block_k) scores, making the
    compiled memory footprint honest for 32k prefill.
    """
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if sk <= block_k:
        return naive_attention(q, k, v, causal=causal, window=window,
                               scale=scale)
    qpk = hq // hkv
    window = jnp.asarray(-1 if window is None else window, jnp.int32)

    pad = (-sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nblk = (sk + pad) // block_k
    kb = jnp.moveaxis(k.reshape(b, hkv, nblk, block_k, hd), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, hkv, nblk, block_k, hd), 2, 0)

    qg = (q.reshape(b, hkv, qpk, sq, hd) * scale).astype(jnp.float32)
    row = jnp.arange(sq)[:, None] + (sk - sq)

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kblk, vblk, j = blk
        s = jnp.einsum("bgqtd,bgsd->bgqts", qg, kblk.astype(jnp.float32),
                       optimize=True)
        col = j * block_k + jnp.arange(block_k)[None, :]
        mask = _window_mask(row, col, window, causal) & (col < sk)
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, -1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new) * mask
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, -1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bgqts,bgsd->bgqtd", p,
                                       vblk.astype(jnp.float32),
                                       optimize=True)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hkv, qpk, sq, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, qpk, sq, 1), jnp.float32),
            jnp.zeros((b, hkv, qpk, sq, hd), jnp.float32))
    # checkpoint the block step: without it every block's (Sq, block_k)
    # score tensor becomes a backward residual — O(Sq·Sk) memory, defeating
    # the point of blockwise attention. unroll=True keeps the loop out of a
    # `while` op so XLA cost_analysis counts every block (the dry-run's
    # roofline extrapolation relies on loop-free layer bodies).
    (m, l, acc), _ = lax.scan(jax.checkpoint(step), init,
                              (kb, vb, jnp.arange(nblk)), unroll=True)
    o = acc / jnp.where(l == 0.0, 1.0, l)
    return o.reshape(b, hq, sq, hd).astype(q.dtype)


def chunked_decode_attention(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, cur_len: jax.Array, *,
                             window: jax.Array | int | None,
                             scale: float) -> jax.Array:
    """Single-token decode against a chunked cache (flash-decoding).

    q: (B, Hq, hd); k/v_cache: (B, Hkv, C, Sc, hd) — C is the split-KV chunk
    dim (sharded over 'model' by the launcher). ``cur_len`` is the number of
    valid cache positions. Returns (B, Hq, hd).
    """
    b, hq, hd = q.shape
    hkv, c, sc = k_cache.shape[1], k_cache.shape[2], k_cache.shape[3]
    qpk = hq // hkv
    window = jnp.asarray(-1 if window is None else window, jnp.int32)
    qg = (q.reshape(b, hkv, qpk, hd) * scale).astype(jnp.float32)

    s = jnp.einsum("bgqd,bgcsd->bgqcs", qg, k_cache.astype(jnp.float32),
                   optimize=True)
    pos = (jnp.arange(c)[:, None] * sc + jnp.arange(sc)[None, :])
    row = cur_len - 1
    valid = (pos < cur_len) & ((window < 0) | (pos > row - window))
    s = jnp.where(valid[None, None, None], s, NEG_INF)

    m_c = jnp.max(s, -1)                                  # (b,g,q,C)
    p = jnp.exp(s - m_c[..., None]) * valid[None, None, None]
    l_c = jnp.sum(p, -1)                                  # (b,g,q,C)
    o_c = jnp.einsum("bgqcs,bgcsd->bgqcd", p,
                     v_cache.astype(jnp.float32), optimize=True)

    m = jnp.max(m_c, -1, keepdims=True)                   # merge over C
    w = jnp.exp(m_c - m)
    l = jnp.sum(l_c * w, -1)
    o = jnp.einsum("bgqc,bgqcd->bgqd", w * l_c /
                   jnp.where(l[..., None] == 0, 1.0, l[..., None]),
                   o_c / jnp.where(l_c[..., None] == 0, 1.0,
                                   l_c[..., None]), optimize=True)
    return o.reshape(b, hq, hd).astype(q.dtype)


# -- MLP variants ---------------------------------------------------------------
def mlp_apply(x: jax.Array, params: dict, kind: str,
              gather_weights: bool = True) -> jax.Array:
    """x: (..., d). kinds: swiglu | geglu | gelu | relu2.

    ``gather_weights`` applies the ZeRO-3 gather-before-use layout (§Perf
    N3) — right for full-sequence steps, wrong for decode (batch≈1:
    activations are tiny, weights huge; the per-step weight all-gather
    cost 0.1 s on gemma long_500k before this flag existed).
    """
    if gather_weights:
        w1 = pspec.weight_gathered(params["w1"], 1)
        w2 = pspec.weight_gathered(params["w2"], 0)
    else:
        w1, w2 = params["w1"], params["w2"]
    if kind in ("swiglu", "geglu"):
        w3 = (pspec.weight_gathered(params["w3"], 1) if gather_weights
              else params["w3"])
        g = pspec.hidden_last(x @ w1)
        u = pspec.hidden_last(x @ w3)
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        return (act * u) @ w2
    h = pspec.hidden_last(x @ w1)
    if kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown mlp kind {kind}")
    return h @ w2


def mlp_init(key, d: int, ff: int, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d ** -0.5
    scale_out = ff ** -0.5
    p = {"w1": jax.random.normal(k1, (d, ff), dtype) * scale_in,
         "w2": jax.random.normal(k2, (ff, d), dtype) * scale_out}
    if kind in ("swiglu", "geglu"):
        p["w3"] = jax.random.normal(k3, (d, ff), dtype) * scale_in
    return p
