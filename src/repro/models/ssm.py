"""State-space mixers: Mamba (hymba's parallel SSM heads) and RWKV-6.

Both expose a full-sequence path (training / prefill — chunked or
associative scans, sub-quadratic) and a single-step path (decode — O(1)
state). States are returned explicitly so the serving cache can carry them.

The RWKV-6 chunk math mirrors ``repro.kernels.rwkv6_scan`` (the Pallas TPU
fast path); this XLA version is what the dry-run lowers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

CONV_K = 4


# =========================== Mamba (diagonal SSM) ===========================
def mamba_init(key, d: int, state: int, dtype) -> dict:
    d_i = d
    r = max(8, d // 64)
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "w_in": jax.random.normal(ks[0], (d, 2 * d_i), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (CONV_K, d_i), dtype) * 0.3,
        "conv_b": jnp.zeros((d_i,), dtype),
        "w_dt1": jax.random.normal(ks[2], (d_i, r), dtype) * s,
        "w_dt2": jax.random.normal(ks[3], (r, d_i), dtype) * r ** -0.5,
        "dt_bias": jnp.full((d_i,), -1.0, jnp.float32),
        "w_B": jax.random.normal(ks[4], (d_i, state), dtype) * s,
        "w_C": jax.random.normal(ks[5], (d_i, state), dtype) * s,
        "A_log": jnp.zeros((d_i, state), jnp.float32),
        "D": jnp.ones((d_i,), jnp.float32),
        "w_out": jax.random.normal(ks[6], (d_i, d), dtype) * s,
    }


def _mamba_gates(x1, p):
    """Shared projections: (dt, B, C) from the conv'd activation."""
    dt = jax.nn.softplus(
        (x1 @ p["w_dt1"]) @ p["w_dt2"] + p["dt_bias"])       # (..., d_i)
    bmat = x1 @ p["w_B"]                                     # (..., N)
    cmat = x1 @ p["w_C"]
    return dt, bmat, cmat


def mamba_apply(x: jax.Array, p: dict, return_state: bool = False):
    """Full-sequence Mamba mixer. x: (B, L, d) -> (B, L, d).

    Monolithic associative scan. §Perf iteration H1 tried a chunked
    unrolled variant (256-token windows, carry injection via cumprod):
    REFUTED — memory term 22.3 -> 32.8 s, collective 2.6 -> 10.7 s,
    compile 163 -> 1089 s: the unrolled chunk ops defeat XLA fusion and
    multiply GSPMD boundary collectives. The real fast path for this mixer
    is a fused chunked kernel (see repro/kernels/rwkv6_scan for the
    implemented pattern); kept as backlog.

    With ``return_state`` also returns ``(ssm_state, conv_state)`` for
    prefill-into-cache.
    """
    b, l, d = x.shape
    xz = x @ p["w_in"]
    x1_raw, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv, kernel CONV_K
    xp = jnp.pad(x1_raw, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    x1 = sum(xp[:, i:i + l] * p["conv_w"][i] for i in range(CONV_K))
    x1 = jax.nn.silu(x1 + p["conv_b"])

    dt, bmat, cmat = _mamba_gates(x1.astype(jnp.float32), p)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt[..., None])        # (B,L,d_i,N)
    drive = (dt * x1.astype(jnp.float32))[..., None] * bmat[..., None, :]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    _, h = lax.associative_scan(combine, (a, drive), axis=1)
    y = jnp.einsum("blds,bls->bld", h, cmat, optimize=True)
    y = y + p["D"] * x1.astype(jnp.float32)
    out = ((y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"])
    if not return_state:
        return out
    conv_state = xp[:, l:l + CONV_K - 1]         # last K-1 raw inputs
    return out, (h[:, -1], conv_state)


def mamba_decode(x: jax.Array, p: dict, state: jax.Array,
                 conv_state: jax.Array,
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One step. x: (B, d); state: (B, d_i, N); conv_state: (B, K-1, d_i)."""
    xz = x @ p["w_in"]
    x1, z = jnp.split(xz, 2, axis=-1)
    hist = jnp.concatenate([conv_state, x1[:, None]], axis=1)  # (B, K, d_i)
    conv_state = hist[:, 1:]
    x1 = sum(hist[:, i] * p["conv_w"][i] for i in range(CONV_K))
    x1 = jax.nn.silu(x1 + p["conv_b"])

    dt, bmat, cmat = _mamba_gates(x1.astype(jnp.float32), p)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt[..., None])          # (B,d_i,N)
    state = state * a + (dt * x1.astype(jnp.float32))[..., None] * \
        bmat[:, None, :]
    y = jnp.einsum("bds,bs->bd", state, cmat, optimize=True)
    y = y + p["D"] * x1.astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return out, state, conv_state


# ================================ RWKV-6 ====================================
def rwkv6_init(key, d: int, head_dim: int, dtype) -> dict:
    h = d // head_dim
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),
        "w_r": jax.random.normal(ks[1], (d, d), dtype) * s,
        "w_k": jax.random.normal(ks[2], (d, d), dtype) * s,
        "w_v": jax.random.normal(ks[3], (d, d), dtype) * s,
        "w_w": jax.random.normal(ks[4], (d, d), dtype) * s * 0.1,
        "w_g": jax.random.normal(ks[5], (d, d), dtype) * s,
        "u": jax.random.normal(ks[6], (h, head_dim), jnp.float32) * 0.3,
        "ln_x": jnp.zeros((d,), jnp.float32),
        "w_out": jax.random.normal(ks[7], (d, d), dtype) * s,
    }


def _rwkv6_project(x, shifted, p, head_dim):
    """Token-shift mix + projections → per-head r/k/v/w/g."""
    b = x.shape[:-1]
    d = x.shape[-1]
    h = d // head_dim
    delta = shifted - x
    mixed = [x + p["mu"][i].astype(x.dtype) * delta for i in range(5)]
    r = (mixed[0] @ p["w_r"]).reshape(*b, h, head_dim)
    k = (mixed[1] @ p["w_k"]).reshape(*b, h, head_dim)
    v = (mixed[2] @ p["w_v"]).reshape(*b, h, head_dim)
    w = jnp.exp(-jnp.exp(
        (mixed[3] @ p["w_w"]).astype(jnp.float32) - 2.0)
    ).reshape(*b, h, head_dim)                               # decay ∈ (0,1)
    g = mixed[4] @ p["w_g"]
    return r, k, v, w, g


def _rwkv6_finish(o, g, p, x_dtype):
    """Per-head group-norm → gate → output projection."""
    b = o.shape[:-2]
    d = o.shape[-2] * o.shape[-1]
    of = o.astype(jnp.float32)
    var = jnp.mean(of * of, axis=-1, keepdims=True)
    of = of * lax.rsqrt(var + 1e-6)
    of = of.reshape(*b, d) * (1.0 + p["ln_x"])
    return ((of.astype(x_dtype) * jax.nn.silu(g)) @ p["w_out"])


def rwkv6_apply(x: jax.Array, p: dict, *, head_dim: int,
                chunk: int = 128, return_state: bool = False):
    """Full-sequence RWKV-6 time-mix. x: (B, L, d) → (B, L, d).

    With ``return_state`` also returns ``(wkv_state, shift_state)``.
    Padding chunks carry identity decay (w=1) and zero k, so the final
    state is exact regardless of padding.
    """
    b, l, d = x.shape
    h = d // head_dim
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, g = _rwkv6_project(x, shifted, p, head_dim)

    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    nc = (l + pad) // chunk

    def reshape_chunks(t):
        return jnp.moveaxis(
            t.reshape(b, nc, chunk, h, -1), 1, 0)            # (nc,B,L,h,e)

    rc, kc, vc, wc = map(reshape_chunks, (r, k, v, w))
    u = p["u"]

    def step(state, inp):                                    # state (B,h,dk,dv)
        r_, k_, v_, w_ = (t.astype(jnp.float32) for t in inp)
        logw = jnp.log(w_)
        cum = jnp.cumsum(logw, axis=1)
        qt = r_ * jnp.exp(cum - logw)
        kt = k_ * jnp.exp(-cum)
        scores = jnp.einsum("blhd,bmhd->bhlm", qt, kt, optimize=True)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask, scores, 0.0)
        bonus = jnp.einsum("blhd,hd,blhd->blh", r_, u, k_, optimize=True)
        o = (jnp.einsum("bhlm,bmhe->blhe", scores, v_, optimize=True)
             + bonus[..., None] * v_
             + jnp.einsum("blhd,bhde->blhe", qt, state, optimize=True))
        dl = jnp.exp(cum[:, -1])                              # (B,h,dk)
        state = (state * dl[..., None]
                 + jnp.einsum("blhd,blhe->bhde", kt * dl[:, None], v_,
                              optimize=True))
        return state, o

    init = jnp.zeros((b, h, head_dim, v.shape[-1]), jnp.float32)
    final_state, o = lax.scan(step, init, (rc, kc, vc, wc))
    o = jnp.moveaxis(o, 0, 1).reshape(b, nc * chunk, h, -1)[:, :l]
    out = _rwkv6_finish(o, g, p, x.dtype)
    if not return_state:
        return out
    return out, (final_state, x[:, -1])          # (state, shift_state)


def rwkv6_decode(x: jax.Array, p: dict, state: jax.Array,
                 shift_state: jax.Array, *, head_dim: int,
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One step. x: (B, d); state: (B, h, dk, dv); shift_state: (B, d)."""
    r, k, v, w, g = _rwkv6_project(x, shift_state, p, head_dim)
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    kv = jnp.einsum("bhd,bhe->bhde", kf, vf, optimize=True)
    o = jnp.einsum("bhd,bhde->bhe", rf,
                   state + p["u"][None, :, :, None] * kv, optimize=True)
    state = state * wf[..., None] + kv
    out = _rwkv6_finish(o, g, p, x.dtype)
    return out, state, x
