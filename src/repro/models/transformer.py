"""Model assembly: blocks, scan-over-layers stacks, train + decode paths.

One builder covers all ten assigned architectures; ``ArchConfig`` selects the
mixer (attention / attention+SSM / RWKV), FFN (dense MLP / MoE), and the
attention pattern. Layer stacks always go through ``lax.scan`` over stacked
parameters — 66 dry-run compiles of up-to-96-layer models stay tractable
because the HLO contains ONE layer body.

Decode caches (serve path):

* full / local_global attention → chunked cache ``(L, B, Hkv, C, Sc, hd)``
  for flash-decoding; ``C`` is sharded over the model axis by the launcher,
* sliding-window attention → ring cache ``(L, B, Hkv, W, hd)`` (O(window)
  memory — this is what makes mixtral/hymba long_500k-eligible),
* SSM / RWKV → O(1) state tensors,
* gemma3's 5:1 local:global stack scans over a per-layer window vector with
  a single code path (window = −1 ⇒ global).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import pspec
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_rope, blockwise_attention,
                                 chunked_decode_attention, mlp_apply,
                                 mlp_init, naive_attention, rms_norm)

Params = dict
Cache = dict


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer window vector: -1 = full/global attention."""
    if cfg.attention == "swa":
        return jnp.full((cfg.num_layers,), cfg.window, jnp.int32)
    if cfg.attention == "local_global":
        r = cfg.local_global_ratio
        pat = [(cfg.window if (i % (r + 1)) != r else -1)
               for i in range(cfg.num_layers)]
        return jnp.asarray(pat, jnp.int32)
    return jnp.full((cfg.num_layers,), -1, jnp.int32)


# ============================ per-layer init =================================
def _attn_init(key, cfg: ArchConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    dt = _dtype(cfg)
    return {
        "wq": jax.random.normal(ks[0], (d, h * hd), dt) * s,
        "wk": jax.random.normal(ks[1], (d, kv * hd), dt) * s,
        "wv": jax.random.normal(ks[2], (d, kv * hd), dt) * s,
        "wo": jax.random.normal(ks[3], (h * hd, d), dt) * (h * hd) ** -0.5,
    }


def block_init(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, dt = cfg.d_model, _dtype(cfg)
    p: Params = {"ln1": jnp.zeros((d,), jnp.float32),
                 "ln2": jnp.zeros((d,), jnp.float32)}
    if cfg.family == "ssm":
        p["rwkv"] = ssm_lib.rwkv6_init(ks[0], d, cfg.rwkv_head_dim, dt)
    else:
        p["attn"] = _attn_init(ks[0], cfg)
        if cfg.family == "hybrid":
            p["ssm"] = ssm_lib.mamba_init(ks[1], d, cfg.ssm_state, dt)
            p["ln_a"] = jnp.zeros((d,), jnp.float32)
            p["ln_s"] = jnp.zeros((d,), jnp.float32)
    if cfg.num_experts:
        p["moe"] = moe_lib.moe_init(ks[2], d, cfg.d_ff, cfg.num_experts,
                                    cfg.mlp, cfg.num_shared_experts, dt)
    else:
        p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, cfg.mlp, dt)
    return p


def init_params(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, v, dt = cfg.d_model, cfg.vocab_size, _dtype(cfg)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    layers = jax.vmap(lambda k: block_init(k, cfg))(layer_keys)
    p = {
        "embed": jax.random.normal(ks[1], (v, d), dt) * d ** -0.5,
        "layers": layers,
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if cfg.decoder:
        p["lm_head"] = jax.random.normal(ks[2], (d, v), dt) * d ** -0.5
    else:
        p["head"] = jax.random.normal(ks[2], (d, v), dt) * d ** -0.5
    if cfg.frontend == "audio":
        p["frontend_proj"] = jax.random.normal(
            ks[3], (cfg.frontend_dim, d), dt) * cfg.frontend_dim ** -0.5
    return p


def param_shapes(cfg: ArchConfig):
    """abstract params (no allocation) — used by the dry-run."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.key(0))


# ============================ full-sequence path =============================
def _attention_full(x, ap, cfg: ArchConfig, window, positions,
                    return_kv: bool = False):
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    wq = pspec.weight_gathered(ap["wq"], 1)
    kv_tp = 1 if kv % 16 == 0 else None
    wk = pspec.weight_gathered(ap["wk"], kv_tp)
    wv = pspec.weight_gathered(ap["wv"], kv_tp)
    q = (x @ wq).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    q = pspec.attn_qkv(q, "q")
    kv_role = "kv" if pspec.heads_shardable(cfg.num_heads) else "q"
    k = pspec.attn_qkv(k, kv_role)
    v = pspec.attn_qkv(v, kv_role)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = blockwise_attention(q, k, v, causal=cfg.causal, window=window,
                            scale=hd ** -0.5)
    out = pspec.batch_first(
        o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
        @ pspec.weight_gathered(ap["wo"], 0))
    if return_kv:
        return out, (k, v)
    return out


def _ffn(x, lp, cfg: ArchConfig, dropless: bool = False,
         decode: bool = False):
    """Returns (out, aux)."""
    if cfg.num_experts:
        from repro.models import moe_dist
        b, s, d = x.shape
        flat = x.reshape(b * s, d)
        res = moe_dist.moe_apply_dist(
            flat, lp["moe"], top_k=cfg.top_k, kind=cfg.mlp,
            capacity_factor=cfg.capacity_factor, dropless=dropless,
            fsdp=cfg.fsdp)
        if res is not None:
            out, aux = res
            if "shared" in lp["moe"]:
                out = out + mlp_apply(flat, lp["moe"]["shared"], cfg.mlp,
                                      gather_weights=not decode)
        else:
            out, aux = moe_lib.moe_apply(
                flat, lp["moe"], top_k=cfg.top_k, kind=cfg.mlp,
                capacity_factor=cfg.capacity_factor, dropless=dropless)
        return out.reshape(b, s, d), aux
    return (mlp_apply(x, lp["mlp"], cfg.mlp, gather_weights=not decode),
            jnp.float32(0.0))


def block_apply(x, lp, cfg: ArchConfig, window, positions):
    """Full-sequence block. x: (B, S, d) → (x', aux)."""
    xin = rms_norm(x, lp["ln1"])
    if cfg.family == "ssm":
        mix = ssm_lib.rwkv6_apply(xin, lp["rwkv"],
                                  head_dim=cfg.rwkv_head_dim)
    elif cfg.family == "hybrid":
        a = _attention_full(xin, lp["attn"], cfg, window, positions)
        s = ssm_lib.mamba_apply(xin, lp["ssm"])
        mix = 0.5 * (rms_norm(a, lp["ln_a"]) + rms_norm(s, lp["ln_s"]))
    else:
        mix = _attention_full(xin, lp["attn"], cfg, window, positions)
    x = x + mix
    ff, aux = _ffn(rms_norm(x, lp["ln2"]), lp, cfg)
    return x + ff, aux


def embed_inputs(params: Params, cfg: ArchConfig, batch: dict) -> jax.Array:
    if cfg.frontend == "audio":
        return batch["features"].astype(_dtype(cfg)) @ params["frontend_proj"]
    return jnp.take(params["embed"], batch["tokens"], axis=0)


def forward(params: Params, cfg: ArchConfig, batch: dict,
            ) -> tuple[jax.Array, jax.Array]:
    """Training / prefill forward. batch: tokens (B, S) or features.

    Returns (logits (B, S, V), aux_loss).
    """
    x = pspec.seq_model(embed_inputs(params, cfg, batch))
    s = x.shape[1]
    positions = jnp.arange(s)
    windows = layer_windows(cfg)

    def layer_fn(x, scanned):
        lp, window = scanned
        x, aux = block_apply(x, lp, cfg, window, positions)
        return pspec.seq_model(x), aux

    if cfg.remat == "full":
        layer_fn = jax.checkpoint(layer_fn,
                                  policy=jax.checkpoint_policies.nothing_saveable)
    x, auxs = lax.scan(layer_fn, x, (params["layers"], windows))
    x = rms_norm(x, params["final_norm"])
    head = params["lm_head"] if cfg.decoder else params["head"]
    logits = pspec.constrain(x @ head, pspec.DP, None, "model")
    return logits, jnp.sum(auxs)


def loss_fn(params: Params, cfg: ArchConfig, batch: dict,
            aux_coef: float = 0.01) -> jax.Array:
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    return jnp.sum(nll) / denom + aux_coef * aux


# ============================ prefill-into-cache ============================
def _kv_to_chunked(k, spec: "CacheSpec"):
    """(B, Hkv, S, hd) → (B, Hkv, C, Sc, hd), zero-padded to max_len."""
    b, kv, s, hd = k.shape
    pad = spec.max_len - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return k.reshape(b, kv, spec.kv_chunks, spec.chunk_len, hd)


def _kv_to_ring(k, spec: "CacheSpec", s: int):
    """(B, Hkv, S, hd) → ring (B, Hkv, W, hd): slot j holds the largest
    position p < S with p ≡ j (mod W); slots from before position 0 zero."""
    w = spec.max_len
    j = jnp.arange(w)
    p = (s - 1) - ((s - 1 - j) % w)
    valid = p >= 0
    gathered = jnp.take(k, jnp.clip(p, 0, None), axis=2)
    return jnp.where(valid[None, None, :, None], gathered, 0)


def prefill_forward(params: Params, cfg: ArchConfig, batch: dict,
                    spec: "CacheSpec") -> tuple[jax.Array, Cache]:
    """Full-sequence forward that also emits the decode cache.

    Returns (logits (B, S, V), cache) with the cache positioned after the
    last prompt token (``cur_len = S`` for the subsequent decode_step).
    """
    x = pspec.seq_model(embed_inputs(params, cfg, batch))
    b, s = x.shape[0], x.shape[1]
    positions = jnp.arange(s)
    windows = layer_windows(cfg)

    def layer_fn(x, scanned):
        lp, window = scanned
        new_cache: dict = {}
        xin = rms_norm(x, lp["ln1"])
        if cfg.family == "ssm":
            mix, (st, sh) = ssm_lib.rwkv6_apply(
                xin, lp["rwkv"], head_dim=cfg.rwkv_head_dim,
                return_state=True)
            new_cache["rwkv_state"], new_cache["rwkv_shift"] = st, sh
        else:
            a, (k, v) = _attention_full(xin, lp["attn"], cfg, window,
                                        positions, return_kv=True)
            if spec.kind == "chunked":
                new_cache["k"] = _kv_to_chunked(k, spec)
                new_cache["v"] = _kv_to_chunked(v, spec)
            else:
                new_cache["k"] = _kv_to_ring(k, spec, s)
                new_cache["v"] = _kv_to_ring(v, spec, s)
            if cfg.family == "hybrid":
                sm, (st, conv) = ssm_lib.mamba_apply(xin, lp["ssm"],
                                                     return_state=True)
                new_cache["ssm"], new_cache["conv"] = st, conv
                mix = 0.5 * (rms_norm(a, lp["ln_a"]) +
                             rms_norm(sm, lp["ln_s"]))
            else:
                mix = a
        x = x + mix
        ff, _ = _ffn(rms_norm(x, lp["ln2"]), lp, cfg, dropless=True)
        return pspec.seq_model(x + ff), new_cache

    x, cache = lax.scan(layer_fn, x, (params["layers"], windows))
    x = rms_norm(x, params["final_norm"])
    logits = pspec.constrain(x @ params["lm_head"], pspec.DP, None, "model")
    return logits, cache


# ================================ decode path ================================
@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Static decode-cache geometry for one arch × shape."""
    kind: str            # "chunked" | "ring" | "none"
    max_len: int
    kv_chunks: int = 16  # C — sharded over 'model' by the launcher

    @property
    def chunk_len(self) -> int:
        return self.max_len // self.kv_chunks


def cache_spec(cfg: ArchConfig, max_len: int, kv_chunks: int = 16,
               ) -> CacheSpec:
    if cfg.family == "ssm":
        return CacheSpec("none", max_len)
    if cfg.attention == "swa":
        return CacheSpec("ring", min(cfg.window, max_len))
    return CacheSpec("chunked", max_len, kv_chunks)


def init_cache(cfg: ArchConfig, batch: int, spec: CacheSpec) -> Cache:
    l, kv, hd, d = (cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_,
                    cfg.d_model)
    dt = _dtype(cfg)
    c: Cache = {}
    if spec.kind == "chunked":
        shape = (l, batch, kv, spec.kv_chunks, spec.chunk_len, hd)
        c["k"] = jnp.zeros(shape, dt)
        c["v"] = jnp.zeros(shape, dt)
    elif spec.kind == "ring":
        shape = (l, batch, kv, spec.max_len, hd)
        c["k"] = jnp.zeros(shape, dt)
        c["v"] = jnp.zeros(shape, dt)
    if cfg.family == "hybrid":
        c["ssm"] = jnp.zeros((l, batch, d, cfg.ssm_state), jnp.float32)
        c["conv"] = jnp.zeros((l, batch, ssm_lib.CONV_K - 1, d), dt)
    if cfg.family == "ssm":
        h = d // cfg.rwkv_head_dim
        c["rwkv_state"] = jnp.zeros(
            (l, batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32)
        c["rwkv_shift"] = jnp.zeros((l, batch, d), dt)
    return c


def cache_shapes(cfg: ArchConfig, batch: int, spec: CacheSpec):
    return jax.eval_shape(lambda: init_cache(cfg, batch, spec))


def _attention_decode(x, ap, cfg: ArchConfig, window, cache_k, cache_v,
                      cur_len, spec: CacheSpec):
    """x: (B, d) one token. Returns (out (B, d), new_k, new_v)."""
    b, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = pspec.constrain((x @ ap["wq"]).reshape(b, h, hd),
                        pspec.DP, "model", None)
    k = pspec.constrain((x @ ap["wk"]).reshape(b, kv, hd),
                        pspec.DP, "model", None)
    v = pspec.constrain((x @ ap["wv"]).reshape(b, kv, hd),
                        pspec.DP, "model", None)
    pos = jnp.full((1,), cur_len, jnp.int32)
    q = apply_rope(q[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]
    k = apply_rope(k[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]

    if spec.kind == "ring":
        slot = cur_len % spec.max_len
        cache_k = lax.dynamic_update_slice(
            cache_k, k[:, :, None], (0, 0, slot, 0))
        cache_v = lax.dynamic_update_slice(
            cache_v, v[:, :, None], (0, 0, slot, 0))
        qpk = h // kv
        qg = (q.reshape(b, kv, qpk, hd) * hd ** -0.5).astype(jnp.float32)
        s = jnp.einsum("bgqd,bgsd->bgqs", qg,
                       cache_k.astype(jnp.float32), optimize=True)
        idx = jnp.arange(spec.max_len)
        # ring slot ``idx`` holds global position cur_len - ((slot - idx) % W)
        # (slot itself holds cur_len); entries from before position 0 are
        # uninitialized and masked out. Window validity is automatic: the
        # ring only ever holds the freshest W positions.
        p_stored = cur_len - ((slot - idx) % spec.max_len)
        valid = p_stored >= 0
        s = jnp.where(valid[None, None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgqs,bgsd->bgqd", pr,
                       cache_v.astype(jnp.float32), optimize=True)
        o = o.reshape(b, h, hd).astype(x.dtype)
    else:
        ci = cur_len // spec.chunk_len
        slot = cur_len % spec.chunk_len
        cache_k = lax.dynamic_update_slice(
            cache_k, k[:, :, None, None], (0, 0, ci, slot, 0))
        cache_v = lax.dynamic_update_slice(
            cache_v, v[:, :, None, None], (0, 0, ci, slot, 0))
        o = chunked_decode_attention(q, cache_k, cache_v, cur_len + 1,
                                     window=window, scale=hd ** -0.5)
    return o.reshape(b, h * hd) @ ap["wo"], cache_k, cache_v


def decode_block_apply(x, lp, cfg: ArchConfig, window, cache_l: dict,
                       cur_len, spec: CacheSpec):
    """One token through one block. x: (B, d)."""
    new_cache = dict(cache_l)
    xin = rms_norm(x, lp["ln1"])
    if cfg.family == "ssm":
        mix, st, sh = ssm_lib.rwkv6_decode(
            xin, lp["rwkv"], cache_l["rwkv_state"], cache_l["rwkv_shift"],
            head_dim=cfg.rwkv_head_dim)
        new_cache["rwkv_state"], new_cache["rwkv_shift"] = st, sh
    else:
        a, ck, cv = _attention_decode(xin, lp["attn"], cfg, window,
                                      cache_l["k"], cache_l["v"],
                                      cur_len, spec)
        new_cache["k"], new_cache["v"] = ck, cv
        if cfg.family == "hybrid":
            s, st, conv = ssm_lib.mamba_decode(
                xin, lp["ssm"], cache_l["ssm"], cache_l["conv"])
            new_cache["ssm"], new_cache["conv"] = st, conv
            mix = 0.5 * (rms_norm(a, lp["ln_a"]) + rms_norm(s, lp["ln_s"]))
        else:
            mix = a
    x = x + mix
    ff, _ = _ffn(rms_norm(x, lp["ln2"])[:, None, :], lp, cfg,
                 dropless=True, decode=True)
    return x + ff[:, 0], new_cache


def decode_step(params: Params, cfg: ArchConfig, cache: Cache,
                tokens: jax.Array, cur_len: jax.Array,
                spec: CacheSpec) -> tuple[jax.Array, Cache]:
    """One serve step: tokens (B, 1) int32 → (logits (B, V), new cache)."""
    x = pspec.batch_first(jnp.take(params["embed"], tokens[:, 0], axis=0))
    windows = layer_windows(cfg)

    def layer_fn(x, scanned):
        lp, window, cache_l = scanned
        x, new_cache_l = decode_block_apply(x, lp, cfg, window, cache_l,
                                            cur_len, spec)
        return pspec.batch_first(x), new_cache_l

    x, new_cache = lax.scan(layer_fn, x,
                            (params["layers"], windows, cache))
    x = rms_norm(x, params["final_norm"])
    logits = pspec.constrain(x @ params["lm_head"], pspec.DP, "model")
    return logits, new_cache
