"""Sharded checkpointing with async save, integrity hashes, and elastic
restore (the checkpoint/restart leg of fault tolerance).

Layout: one ``.npy`` per pytree leaf (path-derived filename) plus
``index.json`` holding the tree structure, shapes/dtypes, step, and a
sha256 per file. Saves are atomic (tmp dir + rename) and optionally run on
a background thread so the train loop never blocks on I/O.

Elastic restore: leaves are saved as *global* arrays and re-device_put
against whatever mesh/shardings the restoring job provides — a job may
restart on a different device count (tests restore an 8-device state onto
4 devices and keep training).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time

import jax
import numpy as np

_INDEX = "index.json"


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def _sha256(fn: str) -> str:
    h = hashlib.sha256()
    with open(fn, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(directory: str, step: int, state, *,
                    metadata: dict | None = None) -> str:
    """Write ``state`` (pytree of arrays) atomically to ``directory/step_N``."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(state)
    entries = []
    for path, leaf in leaves_with_paths:
        name = _leaf_name(path) + ".npy"
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name), arr)
        entries.append({"name": name, "path": _leaf_name(path),
                        "shape": list(arr.shape), "dtype": str(arr.dtype),
                        "sha256": _sha256(os.path.join(tmp, name))})
    index = {"step": step, "leaves": entries,
             "metadata": metadata or {}, "saved_at": time.time()}
    with open(os.path.join(tmp, _INDEX), "w") as f:
        json.dump(index, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_checkpoints(directory: str) -> list[tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in sorted(os.listdir(directory)):
        if d.startswith("step_") and not d.endswith(".tmp"):
            full = os.path.join(directory, d)
            if os.path.exists(os.path.join(full, _INDEX)):
                out.append((int(d.split("_")[1]), full))
    return sorted(out)


def latest_checkpoint(directory: str) -> str | None:
    cps = list_checkpoints(directory)
    return cps[-1][1] if cps else None


def restore_checkpoint(path: str, like, *, shardings=None,
                       verify: bool = True):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure) re-shards each leaf
    onto the restoring job's mesh — elastic restore."""
    with open(os.path.join(path, _INDEX)) as f:
        index = json.load(f)
    by_path = {e["path"]: e for e in index["leaves"]}
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(
                        leaves_with_paths))
    out = []
    for (p, leaf), shard in zip(leaves_with_paths, shard_leaves):
        entry = by_path[_leaf_name(p)]
        fn = os.path.join(path, entry["name"])
        if verify and _sha256(fn) != entry["sha256"]:
            raise IOError(f"checkpoint corruption detected in {fn}")
        arr = np.load(fn)
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {entry['path']}: "
                             f"ckpt {arr.shape} vs expected {leaf.shape}")
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out), index["step"], index["metadata"]


class CheckpointManager:
    """keep-last-k manager with optional async (background-thread) saves."""

    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, state, metadata: dict | None = None):
        # pull to host synchronously (cheap vs XLA step), write in background
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def _do():
            save_checkpoint(self.directory, step, host_state,
                            metadata=metadata)
            self._gc()

        self.wait()
        if self.async_save:
            self._pending = threading.Thread(target=_do, daemon=True)
            self._pending.start()
        else:
            _do()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        cps = list_checkpoints(self.directory)
        for step, path in cps[:-self.keep]:
            shutil.rmtree(path, ignore_errors=True)

    def restore_latest(self, like, shardings=None):
        self.wait()
        path = latest_checkpoint(self.directory)
        if path is None:
            return None
        return restore_checkpoint(path, like, shardings=shardings)
