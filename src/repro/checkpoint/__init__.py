from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager, latest_checkpoint, list_checkpoints,
    restore_checkpoint, save_checkpoint)
