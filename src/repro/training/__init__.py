from repro.training.train_step import (  # noqa: F401
    TrainStepConfig, init_state, make_captured_dp_train_step,
    make_dp_train_step, make_train_step, state_shapes, state_shardings)
from repro.training import sharding  # noqa: F401
