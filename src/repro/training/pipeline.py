"""Pipeline parallelism with multi-path stage-boundary transfers.

The stage-to-stage activation send in pipeline parallelism is exactly the
point-to-point transfer the paper accelerates: each microbatch handoff is a
large contiguous buffer moving between neighbouring devices while the
diagonal links idle. ``pipeline_apply`` implements a GPipe schedule under
``shard_map`` over the ``pipe`` axis; with ``multipath=True`` every handoff
is striped across the direct ring link and a 2-hop staged route through the
next-next stage (the Fig. 2(b) pattern), using the same split the core
engine plans.

The schedule runs ``M + P − 1`` ticks (fill + drain); activations for
microbatch *m* exit stage *P−1* at tick ``m + P − 1``. Correctness is
validated against sequential stage application in ``tests/test_pipeline.py``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

AXIS = "pipe"


def _shift_perm(n: int, shift: int):
    return [(i, (i + shift) % n) for i in range(n)]


def send_next_stage(h: jax.Array, num_stages: int, *,
                    multipath: bool = False,
                    axis_name: str = AXIS) -> jax.Array:
    """Move activations one stage forward (stage boundary P2P)."""
    if not multipath or num_stages < 3:
        return lax.ppermute(h, axis_name, _shift_perm(num_stages, 1))
    half = h.shape[-1] // 2
    direct = lax.ppermute(h[..., :half], axis_name,
                          _shift_perm(num_stages, 1))
    staged = lax.ppermute(h[..., half:], axis_name,
                          _shift_perm(num_stages, 2))       # hop-1: skip
    staged = lax.ppermute(staged, axis_name,
                          _shift_perm(num_stages, -1))      # hop-2: back
    return jnp.concatenate([direct, staged], axis=-1)


def pipeline_apply(stage_fn: Callable, stage_params, x: jax.Array,
                   mesh: Mesh, *, microbatches: int,
                   multipath: bool = False) -> jax.Array:
    """GPipe forward over the ``pipe`` mesh axis.

    ``stage_params``: pytree with leading stage dim (sharded over pipe).
    ``x``: (microbatches, mb, d) global inputs. Returns (microbatches, mb,
    d_out) — the last stage's outputs (other stages' slots are zero and the
    result is psum-gathered so every device returns the full output).
    """
    num_stages = mesh.shape[AXIS]
    m = microbatches

    def local(params_l, x_l):
        # params_l: stage-local params (leading dim 1); x_l: (M, mb, d) full
        # (replicated input stream — stage 0 consumes it).
        params_l = jax.tree.map(lambda p: p[0], params_l)
        sid = lax.axis_index(AXIS)
        mb_shape = x_l.shape[1:]
        h = jnp.zeros(mb_shape, x_l.dtype)
        outs = jnp.zeros((m,) + mb_shape, x_l.dtype)
        for t in range(m + num_stages - 1):
            # stage 0 ingests microbatch t during the fill phase
            feed = x_l[min(t, m - 1)]
            h_in = jnp.where(sid == 0,
                             jnp.where(t < m, feed, jnp.zeros_like(feed)),
                             h)
            h_out = stage_fn(params_l, h_in)
            # microbatch index flowing out of this stage at tick t
            mb_idx = t - sid
            emit = (sid == num_stages - 1) & (mb_idx >= 0) & (mb_idx < m)
            outs = lax.cond(
                emit,
                lambda o: lax.dynamic_update_slice(
                    o, h_out[None], (jnp.clip(mb_idx, 0, m - 1),) +
                    (0,) * len(mb_shape)),
                lambda o: o, outs)
            h = send_next_stage(h_out, num_stages, multipath=multipath)
        # surface the last stage's outputs everywhere
        return lax.psum(jnp.where(sid == num_stages - 1, outs, 0.0), AXIS)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(AXIS), P()), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, x)
