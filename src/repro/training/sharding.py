"""Logical sharding rules with divisibility fallback (MaxText-style).

Rules are keyed on parameter path + dim semantics. Every rule is filtered
through ``safe_spec``: an axis that does not divide its dim is dropped for
that tensor (partial replication), so all ten architectures — with head
counts 0/15/16/25/32/48/64/96 and kv heads 5/8/16 — shard without
special-casing.

Layout summary (mesh axes ``pod``/``data``/``model``):

* batch dims            → (pod, data)          [pure DP across pods]
* vocab / embed rows    → model
* attention q-projection cols (H·hd) and MLP hidden → model   [TP]
* MoE expert dim        → model                 [EP]
* param non-TP dim      → data when cfg.fsdp    [FSDP/ZeRO-3]
* decode KV chunk dim   → model (batch-shardable case) or every axis
                          (batch=1 long-context case)
* optimizer moments mirror their parameter specs (int8 scales replicated)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def safe_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop axis names that do not evenly divide their dimension."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        keep = []
        size = shape[i] if i < len(shape) else 1
        for a in axes:
            n = mesh.shape[a]
            if size % n == 0 and n > 1:
                keep.append(a)
                size //= n
        out.append(tuple(keep) if len(keep) > 1 else
                   (keep[0] if keep else None))
    return P(*out)


def _param_rule(path: str, ndim: int, cfg: ArchConfig,
                model_size: int = 1) -> P:
    """Logical spec before divisibility filtering. Paths are '/'-joined."""
    fs = "data" if cfg.fsdp else None
    leaf = path.split("/")[-1]
    if "moe" in path and "shared" not in path:
        # E % model == 0 → expert parallelism over the model axis;
        # otherwise (mixtral: 8 experts on a 16-wide axis) fall back to
        # per-expert tensor parallelism: shard the expert FFN hidden dim.
        ep = cfg.num_experts % max(1, model_size) == 0
        if leaf == "router":
            return P(None, None, "model") if ep else P(None, None, None)
        if leaf in ("w1", "w3"):
            return (P(None, "model", fs, None) if ep
                    else P(None, None, fs, "model"))
        if leaf == "w2":
            return (P(None, "model", None, fs) if ep
                    else P(None, None, "model", fs))
    if leaf == "embed":
        return P("model", fs)
    if leaf in ("lm_head", "head"):
        return P(fs, "model")
    if leaf == "wq":
        return P(None, fs, "model")
    if leaf in ("wk", "wv"):
        # §Perf iteration N1: column-sharding GQA k/v projections whose
        # kv_heads don't divide the model axis splits heads mid-boundary
        # and forces per-layer resharding; replicate the (small) weights
        # so k/v activations stay model-replicated.
        if cfg.num_kv_heads % max(1, model_size) == 0:
            return P(None, fs, "model")
        return P(None, fs, None)
    if leaf == "wo":
        return P(None, "model", fs)
    if "shared" in path and leaf in ("w1", "w3"):
        return P(None, fs, "model")
    if "shared" in path and leaf == "w2":
        return P(None, "model", fs)
    if leaf in ("w1", "w3"):            # dense mlp (L, d, ff)
        return P(None, fs, "model")
    if leaf == "w2":                    # (L, ff, d)
        return P(None, "model", fs)
    if leaf in ("w_in",):               # mamba (L, d, 2d_i)
        return P(None, fs, "model")
    if leaf in ("w_out",):              # (L, d_i, d)
        return P(None, "model", fs)
    if leaf in ("w_r", "w_k", "w_v", "w_w", "w_g"):   # rwkv (L, d, d)
        return P(None, fs, "model")
    if leaf == "frontend_proj":
        return P(None, None)
    return P(*([None] * ndim))          # norms, biases, small projections


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(cfg: ArchConfig, mesh: Mesh, abstract_params) -> Any:
    """Pytree of PartitionSpec matching ``abstract_params``."""
    model_size = mesh.shape.get("model", 1)

    def spec_of(path, leaf):
        raw = _param_rule(_path_str(path), leaf.ndim, cfg, model_size)
        # pad/truncate to leaf rank
        entries = list(raw) + [None] * leaf.ndim
        return safe_spec(leaf.shape, P(*entries[:leaf.ndim]), mesh)

    return jax.tree_util.tree_map_with_path(spec_of, abstract_params)


def param_shardings(cfg: ArchConfig, mesh: Mesh, abstract_params):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, mesh, abstract_params),
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(cfg: ArchConfig, mesh: Mesh, abstract_opt_state,
                    p_specs) -> Any:
    """Moments mirror param specs; int8 scale scalars replicate."""
    def mirror(moments):
        def spec_of(path, leaf):
            ps = _lookup(p_specs, path, leaf)
            return safe_spec(leaf.shape, ps, mesh)
        return jax.tree_util.tree_map_with_path(spec_of, moments)

    def _lookup(specs, path, leaf):
        # path may have trailing 'q'/'scale' for int8 moments
        node = specs
        for p in path:
            key = p.key if hasattr(p, "key") else getattr(p, "idx", None)
            if isinstance(node, dict) and key in node:
                node = node[key]
            elif isinstance(node, (list, tuple)) and isinstance(key, int):
                node = node[key]
            else:
                break
        if isinstance(node, P):
            if leaf.ndim == len(node):
                return node
            return P(*([None] * leaf.ndim))
        return P(*([None] * leaf.ndim))

    return {"m": mirror(abstract_opt_state["m"]),
            "v": mirror(abstract_opt_state["v"]),
            "step": P()}


def batch_specs(cfg: ArchConfig, mesh: Mesh, batch_shapes) -> Any:
    dp = dp_axes(mesh)
    def spec_of(path, leaf):
        return safe_spec(leaf.shape, P(dp, *([None] * (leaf.ndim - 1))),
                         mesh)
    return jax.tree_util.tree_map_with_path(spec_of, batch_shapes)


def cache_specs(cfg: ArchConfig, mesh: Mesh, cache_shapes, batch: int):
    """Decode-cache layout (DESIGN.md §5): batch→DP; chunk dim C→model
    (or every axis when batch is unshardable); ring window→model;
    SSM/RWKV states: batch→DP, feature dims→model."""
    dp = dp_axes(mesh)
    batch_shardable = batch % axis_size(mesh, dp) == 0 and batch > 1
    chunk_axes = "model" if batch_shardable else tuple(
        list(dp) + ["model"])
    bspec = dp if batch_shardable else None

    def spec_of(path, leaf):
        name = _path_str(path).split("/")[-1]
        if name in ("k", "v"):
            if leaf.ndim == 6:    # chunked (L,B,Hkv,C,Sc,hd)
                raw = P(None, bspec, None, chunk_axes, None, None)
            else:                 # ring (L,B,Hkv,W,hd)
                raw = P(None, bspec, None, "model", None)
        elif name == "ssm":       # (L,B,d_i,N)
            raw = P(None, bspec, "model", None)
        elif name == "conv":      # (L,B,K-1,d_i)
            raw = P(None, bspec, None, "model")
        elif name == "rwkv_state":  # (L,B,h,dk,dv)
            raw = P(None, bspec, "model", None, None)
        elif name == "rwkv_shift":  # (L,B,d)
            raw = P(None, bspec, "model")
        else:
            raw = P(*([None] * leaf.ndim))
        return safe_spec(leaf.shape, raw, mesh)

    return jax.tree_util.tree_map_with_path(spec_of, cache_shapes)


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
