"""Train-step builder: loss → grads → AdamW, with grad accumulation.

Produces jit-able step functions with explicit in/out shardings (the same
artifacts the multi-pod dry-run lowers). Gradient accumulation runs the
microbatch loop as a ``lax.scan`` so the HLO stays one-microbatch-sized.

Two communication modes:

* :func:`make_train_step` — auto-sharded: XLA inserts the collectives.
* :func:`make_dp_train_step` — manual data parallelism driven through a
  :class:`repro.comm.CommSession`: the step runs under ``shard_map`` over
  the session's axis and gradients are averaged with the session's
  multipath (bidirectional-ring) collectives instead of ``lax.psum``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from repro.optim import OptimConfig, apply_updates, init_opt_state
from repro.training import sharding as shd

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.comm.session import CommSession


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1          # gradient accumulation factor
    aux_coef: float = 0.01


def make_loss_fn(cfg: ArchConfig, ts: TrainStepConfig):
    def loss(params, batch):
        return tfm.loss_fn(params, cfg, batch, aux_coef=ts.aux_coef)
    return loss


def _make_grad_fn(cfg: ArchConfig, ts: TrainStepConfig) -> Callable:
    """``(params, batch) -> (loss, grads)`` with microbatch accumulation."""
    loss_fn = make_loss_fn(cfg, ts)
    grad_fn = jax.value_and_grad(loss_fn)

    def grads_of(params, batch):
        if ts.microbatches == 1:
            return grad_fn(params, batch)

        def split(x):
            b = x.shape[0]
            mb = b // ts.microbatches
            return x.reshape(ts.microbatches, mb, *x.shape[1:])
        micro = jax.tree.map(split, batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def accum(carry, mb):
            acc, loss_acc = carry
            loss, grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_acc + loss), None

        (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: g / ts.microbatches, gsum)
        return lsum / ts.microbatches, grads

    return grads_of


def make_train_step(cfg: ArchConfig, ts: TrainStepConfig,
                    opt: OptimConfig) -> Callable:
    """Returns ``step(state, batch) -> (state, metrics)`` (un-jitted).

    ``state = {"params": ..., "opt": ...}``. With ``ts.microbatches > 1``
    the batch's leading dim is split and gradients are accumulated in fp32
    via lax.scan (one-microbatch HLO).
    """
    grads_of = _make_grad_fn(cfg, ts)

    def step(state, batch):
        params = state["params"]
        loss, grads = grads_of(params, batch)
        new_params, new_opt, metrics = apply_updates(
            params, grads, state["opt"], opt)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def make_dp_train_step(cfg: ArchConfig, ts: TrainStepConfig,
                       opt: OptimConfig, comm: "CommSession") -> Callable:
    """Data-parallel step with manual multipath gradient collectives.

    The returned ``step(state, batch) -> (state, metrics)`` runs under
    ``shard_map`` over ``comm``'s mesh axis: params/opt state are
    replicated, the batch is sharded on its leading dim, and per-shard
    gradients (and the loss) are averaged with
    ``comm.collectives.pmean`` — the bidirectional-ring all-reduce that
    stripes every hop across both ring directions. Numerically equivalent
    to ``make_train_step`` under jit (mean-of-shard-means == global mean
    for equal shards).
    """
    grads_of = _make_grad_fn(cfg, ts)
    ax = comm.axis_name
    mesh = comm.mesh

    def local_step(state, batch):
        params = state["params"]
        loss, grads = grads_of(params, batch)
        grads = jax.tree.map(comm.collectives.pmean, grads)
        loss = comm.collectives.pmean(loss)
        new_params, new_opt, metrics = apply_updates(
            params, grads, state["opt"], opt)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return shard_map(local_step, mesh=mesh,
                     in_specs=(P(), P(ax)), out_specs=(P(), P()),
                     check_vma=False)


def make_captured_dp_train_step(cfg: ArchConfig, ts: TrainStepConfig,
                                opt: OptimConfig, comm: "CommSession",
                                state, batch, *,
                                schedule: str | None = None,
                                max_paths: int | None = None,
                                num_chunks: int | None = None) -> Callable:
    """Data-parallel step captured as ONE heterogeneous graph —
    grad compute, multipath ring all-reduce, and the optimizer update
    all inside a single compiled launch (``session.capture``).

    ``state``/``batch`` are example pytrees (concrete or abstract) fixing
    the shapes; the returned ``step(state, batch) -> (state, metrics)``
    matches :func:`make_dp_train_step` to numerical tolerance (the
    captured all-reduce sums in fp32 ring order, the eager path in
    bidirectional-ring order). Every call is ONE engine dispatch — grad
    kernel, ``n-1`` exchange rounds, combine kernels, and the update
    kernel are nodes of one scheduled transfer graph, so
    ``comm.stats()["dispatches"]`` increments by one per step.
    """
    import math

    from repro.comm.capture import captured_psum

    grads_of = _make_grad_fn(cfg, ts)
    n = comm.engine.num_devices
    params_leaves, params_def = jax.tree.flatten(state["params"])
    opt_leaves, opt_def = jax.tree.flatten(state["opt"])
    batch_leaves, batch_def = jax.tree.flatten(batch)
    npar, nopt = len(params_leaves), len(opt_leaves)
    for b in batch_leaves:
        if b.shape[0] % n:
            raise ValueError(f"global batch dim {b.shape[0]} not divisible "
                             f"by {n} devices")
    grad_sizes = [math.prod(p.shape) for p in params_leaves]
    total = sum(grad_sizes)
    m_shapes = jax.eval_shape(lambda p, g, s: apply_updates(p, g, s, opt)[2],
                              state["params"], state["params"],
                              state["opt"])
    metric_keys = tuple(sorted(m_shapes)) + ("loss",)

    def build(cap):
        p_refs = [cap.input(tuple(p.shape), p.dtype, replicated=True)
                  for p in params_leaves]
        o_refs = [cap.input(tuple(o.shape), o.dtype, replicated=True)
                  for o in opt_leaves]
        b_refs = [cap.input((b.shape[0] // n,) + tuple(b.shape[1:]),
                            b.dtype) for b in batch_leaves]

        def grad_kernel(*leaves):
            params = params_def.unflatten(list(leaves[:npar]))
            bt = batch_def.unflatten(list(leaves[npar:]))
            loss, grads = grads_of(params, bt)
            flat = [g.astype(jnp.float32).ravel()
                    for g in params_def.flatten_up_to(grads)]
            return jnp.concatenate(
                flat + [loss.astype(jnp.float32).reshape(1)])

        gvec = cap.kernel(grad_kernel, *p_refs, *b_refs, name="grad",
                          flops=6 * total)
        tot = captured_psum(cap, gvec, n, max_paths=max_paths,
                            num_chunks=num_chunks, name="gradsum")

        def update_kernel(tot_v, *leaves):
            params = params_def.unflatten(list(leaves[:npar]))
            opt_state = opt_def.unflatten(list(leaves[npar:]))
            mean = tot_v / n
            gleaves, off = [], 0
            for p, sz in zip(params_leaves, grad_sizes):
                gleaves.append(mean[off:off + sz].reshape(p.shape)
                               .astype(p.dtype))
                off += sz
            loss = mean[total]
            grads = params_def.unflatten(gleaves)
            new_params, new_opt, metrics = apply_updates(
                params, grads, opt_state, opt)
            metrics["loss"] = loss
            mvec = jnp.stack([metrics[k].astype(jnp.float32)
                              for k in metric_keys])
            return (tuple(jax.tree.leaves(new_params))
                    + tuple(jax.tree.leaves(new_opt)) + (mvec,))

        return cap.kernel(update_kernel, tot, *p_refs, *o_refs,
                          name="update", flops=10 * total)

    captured = comm.capture(build, schedule=schedule)

    def step(st, bt):
        p_l = params_def.flatten_up_to(st["params"])
        o_l = opt_def.flatten_up_to(st["opt"])
        b_l = [jnp.asarray(x).reshape((n, x.shape[0] // n) + x.shape[1:])
               for x in batch_def.flatten_up_to(bt)]
        outs = captured(*p_l, *o_l, *b_l)
        outs0 = [o[0] for o in outs]   # replicated results: rows identical
        new_params = params_def.unflatten(outs0[:npar])
        new_opt = opt_def.unflatten(outs0[npar:npar + nopt])
        mvec = outs0[-1]
        metrics = {k: mvec[i] for i, k in enumerate(metric_keys)}
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def state_shapes(cfg: ArchConfig, opt: OptimConfig):
    p = tfm.param_shapes(cfg)
    o = jax.eval_shape(lambda pp: init_opt_state(pp, opt), p)
    return {"params": p, "opt": o}


def state_shardings(cfg: ArchConfig, mesh: Mesh, opt: OptimConfig):
    abstract = state_shapes(cfg, opt)
    p_specs = shd.param_specs(cfg, mesh, abstract["params"])
    o_specs = shd.opt_state_specs(cfg, mesh, abstract["opt"], p_specs)
    return {
        "params": shd.to_shardings(mesh, p_specs),
        "opt": shd.to_shardings(mesh, o_specs),
    }, abstract


def init_state(cfg: ArchConfig, opt: OptimConfig, mesh: Mesh | None = None,
               seed: int = 0):
    """Materialize a sharded train state (smoke/e2e scale only)."""
    params = tfm.init_params(jax.random.key(seed), cfg)
    state = {"params": params, "opt": init_opt_state(params, opt)}
    if mesh is not None:
        shardings, _ = state_shardings(cfg, mesh, opt)
        state = jax.device_put(state, shardings)
    return state
