"""DEPRECATED shim — planning moved to :mod:`repro.comm`.

``PathPlanner`` now lives in :mod:`repro.comm.planner`, the plan dataclasses
in :mod:`repro.comm.plan`, and the ``REPRO_MP_*`` environment parsing in
:meth:`repro.comm.config.CommConfig.from_env`. Construct a
:class:`repro.comm.CommSession` instead of wiring planners by hand
(DESIGN.md §6 migration guide).
"""

import warnings

from repro.comm.config import CommConfig  # noqa: F401
from repro.comm.plan import PathAssignment, TransferPlan  # noqa: F401
from repro.comm.planner import PathPlanner  # noqa: F401

warnings.warn(
    "repro.core.paths is deprecated; use repro.comm (CommSession, "
    "PathPlanner, CommConfig.from_env)", DeprecationWarning, stacklevel=2)
