"""PathPlanner: route enumeration + per-message path configuration.

Implements the paper's Multi-Path Communication Handler + ``GetPathConfig``
(Algorithm 1, lines 4–11) and the offline topology tuner (§4.4):

* enumerate the direct route and all 2-hop staged routes (via idle peer
  devices, and optionally via the host),
* pick the best ``max_paths`` routes,
* assign each route a share of the message proportional to its bottleneck
  bandwidth (host path gets its lower PCIe share automatically),
* split each share into pipeline chunks (vertical split — chunk count is the
  tunable the paper fixes via offline tuning; default target chunk 1 MB,
  capped at ``max_chunks``).

Environment overrides (paper §4.4 "Environment Configuration"):

* ``REPRO_MP_MAX_PATHS``   — max concurrent paths (default 4)
* ``REPRO_MP_CHUNK_BYTES`` — target chunk size (default 1 MiB, paper §4.3)
* ``REPRO_MP_MAX_CHUNKS``  — max chunks per path (default 8)
* ``REPRO_MP_HOST_PATH``   — "1"/"0" include the host-staged path
"""

from __future__ import annotations

import dataclasses
import os

from repro.core.topology import HOST, Route, Topology

_MiB = 1 << 20


def _env_int(name: str, default: int) -> int:
    try:
        return int(os.environ.get(name, default))
    except ValueError:
        return default


def _env_bool(name: str, default: bool) -> bool:
    v = os.environ.get(name)
    if v is None:
        return default
    return v.strip() not in ("0", "false", "False", "")


@dataclasses.dataclass(frozen=True)
class PathAssignment:
    """One path of a transfer: a route, its byte range, and its chunking.

    ``granularity`` keeps every chunk boundary aligned (e.g. to the dtype
    itemsize when the engine moves typed arrays rather than raw bytes).
    """

    route: Route
    offset: int          # byte offset into the message (disjoint, §4.5)
    nbytes: int          # share of the message on this path
    num_chunks: int      # vertical split (pipelining)
    granularity: int = 1

    def chunk_bounds(self) -> list[tuple[int, int]]:
        """Disjoint (offset, size) per chunk; last chunk absorbs remainder."""
        if self.nbytes == 0:
            return []
        g = self.granularity
        base = (self.nbytes // self.num_chunks) // g * g
        bounds = []
        off = self.offset
        for i in range(self.num_chunks):
            size = base if i < self.num_chunks - 1 else (
                self.nbytes - base * (self.num_chunks - 1))
            bounds.append((off, size))
            off += size
        return bounds


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    """The full 2-D plan for one P2P message (horizontal × vertical split)."""

    src: int
    dst: int
    nbytes: int
    paths: tuple[PathAssignment, ...]
    topology_name: str

    @property
    def num_paths(self) -> int:
        return len(self.paths)

    @property
    def num_nodes(self) -> int:
        """Copy-node count of the equivalent CUDA Graph (paper Fig. 13/14):
        one node per chunk per hop."""
        return sum(p.num_chunks * p.route.num_hops for p in self.paths)

    def covered_bytes(self) -> int:
        return sum(p.nbytes for p in self.paths)


class PathPlanner:
    """Selects routes and builds :class:`TransferPlan` objects."""

    def __init__(self, topology: Topology, *,
                 max_paths: int | None = None,
                 chunk_bytes: int | None = None,
                 max_chunks: int | None = None,
                 include_host: bool | None = None,
                 multipath_threshold: int = 2 * _MiB):
        self.topology = topology
        self.max_paths = max_paths if max_paths is not None else _env_int(
            "REPRO_MP_MAX_PATHS", 4)
        self.chunk_bytes = chunk_bytes if chunk_bytes is not None else _env_int(
            "REPRO_MP_CHUNK_BYTES", _MiB)
        self.max_chunks = max_chunks if max_chunks is not None else _env_int(
            "REPRO_MP_MAX_CHUNKS", 8)
        self.include_host = include_host if include_host is not None else (
            _env_bool("REPRO_MP_HOST_PATH", False))
        # Paper §5.3: multi-pathing engages at 2 MB; below that the single
        # direct path wins (launch overhead dominates).
        self.multipath_threshold = multipath_threshold

    # -- route enumeration --------------------------------------------------
    def enumerate_routes(self, src: int, dst: int,
                         include_host: bool | None = None) -> list[Route]:
        """All 1- and 2-hop routes src→dst, best (direct, then by bw) first.

        Staged routes never reuse a directional link of the direct route, so
        per-link exclusivity (§4.5 contention avoidance) holds by construction.
        """
        if src == dst:
            raise ValueError("src == dst")
        topo = self.topology
        include_host = (self.include_host if include_host is None
                        else include_host)
        routes: list[Route] = []
        direct = topo.link(src, dst)
        if direct is not None:
            routes.append(Route(src, dst, None, (direct,),
                                direct.bandwidth_gbps))
        vias = [d for d in topo.devices() if d not in (src, dst)]
        if include_host:
            vias.append(HOST)
        for via in vias:
            h1, h2 = topo.link(src, via), topo.link(via, dst)
            if h1 is None or h2 is None:
                continue
            routes.append(Route(src, dst, via, (h1, h2),
                                min(h1.bandwidth_gbps, h2.bandwidth_gbps)))
        if len(routes) < self.max_paths:
            # Torus case: adjacent chips share no common neighbour (girth
            # 4), so alternative routes are 3-hop detours through a
            # perpendicular axis (src→v1→v2→dst) — the TPU analogue of the
            # paper's staged-GPU path (DESIGN.md §2). Only link-disjoint
            # detours (vs routes found so far) are admitted.
            used = {l for r in routes for l in r.directional_links()}
            for v1 in topo.neighbors(src):
                if v1 in (dst, src):
                    continue
                for v2 in topo.neighbors(dst):
                    if v2 in (src, dst, v1):
                        continue
                    h1, h2, h3 = (topo.link(src, v1), topo.link(v1, v2),
                                  topo.link(v2, dst))
                    if h1 is None or h2 is None or h3 is None:
                        continue
                    links = {(src, v1), (v1, v2), (v2, dst)}
                    if links & used:
                        continue
                    used |= links
                    routes.append(Route(
                        src, dst, v1, (h1, h2, h3),
                        min(h.bandwidth_gbps for h in (h1, h2, h3))))
        # direct first, then staged by hop count and bandwidth, host last
        # (paper: the host path is the marginal contributor).
        routes.sort(key=lambda r: (r.via is not None,
                                   r.via == HOST,
                                   r.num_hops,
                                   -r.bottleneck_gbps))
        return routes

    # -- plan construction ---------------------------------------------------
    def plan(self, src: int, dst: int, nbytes: int, *,
             max_paths: int | None = None,
             include_host: bool | None = None,
             num_chunks: int | None = None,
             granularity: int = 1) -> TransferPlan:
        """Build the 2-D transfer plan (Algorithm 1 lines 4–11)."""
        if nbytes <= 0:
            raise ValueError("nbytes must be positive")
        if nbytes % granularity:
            raise ValueError(f"nbytes {nbytes} not a multiple of "
                             f"granularity {granularity}")
        max_paths = max_paths or self.max_paths
        routes = self.enumerate_routes(src, dst, include_host=include_host)
        if not routes:
            raise ValueError(
                f"no route {src}->{dst} in topology {self.topology.name}")
        if nbytes < self.multipath_threshold:
            routes = routes[:1]
        else:
            routes = routes[:max_paths]

        total_bw = sum(r.bottleneck_gbps for r in routes)
        paths: list[PathAssignment] = []
        offset = 0
        for i, route in enumerate(routes):
            if i == len(routes) - 1:
                share = nbytes - offset  # remainder absorbs rounding (§4.5)
            else:
                share = (int(nbytes * route.bottleneck_gbps / total_bw)
                         // granularity * granularity)
            if share <= 0:
                continue
            if num_chunks is not None:
                chunks = num_chunks
            else:
                chunks = max(1, min(self.max_chunks,
                                    -(-share // self.chunk_bytes)))
            chunks = min(chunks, max(1, share // granularity))
            paths.append(PathAssignment(route, offset, share, chunks,
                                        granularity))
            offset += share
        return TransferPlan(src, dst, nbytes, tuple(paths),
                            self.topology.name)

    # -- offline tuner (paper §4.4) -------------------------------------------
    def tune(self, src: int, dst: int, nbytes: int, *,
             path_counts: tuple[int, ...] = (1, 2, 3, 4),
             chunk_counts: tuple[int, ...] = (1, 2, 4, 8, 16),
             include_host_options: tuple[bool, ...] = (False, True),
             use_compiled_plans: bool = True) -> TransferPlan:
        """Exhaustive offline search for the best (paths × chunks × host)
        configuration under the analytic pipeline model.

        The paper tunes separately for CUDA-Graph and non-graph modes because
        launch overheads differ; ``use_compiled_plans`` toggles which launch
        overhead model is applied.
        """
        from repro.core.pipelining import estimate_transfer_time_s

        best_plan, best_t = None, float("inf")
        for host in include_host_options:
            if host and not any(l.src == HOST or l.dst == HOST
                                for l in self.topology.links.values()):
                continue
            for npaths in path_counts:
                for nchunks in chunk_counts:
                    plan = self.plan(src, dst, nbytes, max_paths=npaths,
                                     include_host=host, num_chunks=nchunks)
                    t = estimate_transfer_time_s(
                        plan, self.topology,
                        compiled_plan=use_compiled_plans)
                    if t < best_t:
                        best_plan, best_t = plan, t
        assert best_plan is not None
        return best_plan
