"""Hardware topology model: devices, links, and multi-path route enumeration.

This is the TPU/JAX adaptation of the paper's Base Module (DESIGN.md §2/§3):
it probes the "hardware" (here: a declarative link model for a TPU ICI torus
or a Beluga/Narval-like NVLink full-mesh) and exposes the link graph that the
:class:`~repro.core.paths.PathPlanner` enumerates routes over.

Bandwidths are unidirectional per directional link, GB/s. The paper's hardware
constants (2 NVLink sublinks/pair on Beluga, 4 on Narval, PCIe host links) and
the TPU v5e constants (4 ICI links/chip, ~50 GB/s/link/direction) are both
representable.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Any, Iterable, Mapping

HOST = -1  # sentinel device id for the host (PCIe-staged) node

#: Process-wide source of topology/planner instance ids. Epoch tokens pair
#: a uid with a mutation counter so tokens from two different instances can
#: never collide (an ``id()``-based token could be reused after GC).
_UID_SOURCE = itertools.count()

#: TPU v5e calibration constants (per chip) used by the roofline model too.
ICI_LINK_GBPS = 50.0
HBM_GBPS = 819.0
PEAK_BF16_TFLOPS = 197.0


@dataclasses.dataclass(frozen=True)
class Link:
    """A directional link ``src -> dst`` with unidirectional bandwidth."""

    src: int
    dst: int
    kind: str  # "ici" | "nvlink" | "pcie"
    bandwidth_gbps: float

    def __post_init__(self) -> None:
        if self.bandwidth_gbps <= 0:
            raise ValueError(f"non-positive bandwidth on {self}")
        if self.src == self.dst:
            raise ValueError(f"self-link {self}")


@dataclasses.dataclass(frozen=True)
class Route:
    """A path from ``src`` to ``dst``: one hop (direct) or two (staged).

    ``via`` is the staging device (or :data:`HOST`); ``None`` means direct.
    ``bottleneck_gbps`` is the min link bandwidth along the route — the
    paper's per-path ``share[p]`` is proportional to it (§4.4).
    """

    src: int
    dst: int
    via: int | None
    hops: tuple[Link, ...]
    bottleneck_gbps: float

    @property
    def kind(self) -> str:
        if self.via is None:
            return "direct"
        return "staged_host" if self.via == HOST else "staged_device"

    @property
    def num_hops(self) -> int:
        return len(self.hops)

    def directional_links(self) -> tuple[tuple[int, int], ...]:
        return tuple((h.src, h.dst) for h in self.hops)


class Topology:
    """Directed link graph over ``num_devices`` accelerators (+ host)."""

    def __init__(self, num_devices: int, links: Iterable[Link],
                 name: str = "custom",
                 grid_shape: tuple[int, ...] | None = None):
        self.num_devices = int(num_devices)
        self.name = name
        self.grid_shape = grid_shape
        self._uid = next(_UID_SOURCE)
        self._epoch = 0
        self._links: dict[tuple[int, int], Link] = {}
        #: Measured-feedback overlay (DESIGN §4.4c): a calibration profile
        #: attached via :meth:`set_calibration` plus the per-link ``Link``
        #: shadows :meth:`link` serves while it is live.
        self._calibration: Any | None = None
        self._calibrated_links: dict[tuple[int, int], Link] = {}
        for link in links:
            self._register(link)

    def _register(self, link: Link) -> None:
        key = (link.src, link.dst)
        if key in self._links:
            # Multiple sublinks between a pair (e.g. 2 NVLinks on Beluga)
            # aggregate into one logical link with summed bandwidth.
            old = self._links[key]
            link = Link(link.src, link.dst, old.kind,
                        old.bandwidth_gbps + link.bandwidth_gbps)
        self._links[key] = link

    # -- mutation & epoch --------------------------------------------------
    @property
    def epoch(self) -> tuple[int, int]:
        """Plan-validity token ``(uid, mutations)`` for this topology.

        Cached plans and compiled fast-path entries
        (:class:`repro.comm.cache.FastPathCache`) are stamped with the
        epoch in force when they were built; any link mutation
        (:meth:`add_link`, :meth:`remove_link`, :meth:`bump_epoch`)
        changes the token, so stale routes can never be served.
        """
        return (self._uid, self._epoch)

    def bump_epoch(self) -> None:
        """Invalidate every plan derived from this topology.

        Call after mutating link state out-of-band (e.g. poking
        ``_links`` directly); :meth:`add_link` / :meth:`remove_link` call
        it for you. If a calibration profile is attached and the
        structural :meth:`digest` no longer matches it (links were added
        or removed), the profile is dropped — fitted terms for a topology
        that no longer exists must never survive a mutation.
        """
        self._epoch += 1
        if (self._calibration is not None
                and self._calibration.topology_digest != self.digest()):
            self._calibration = None
            self._calibrated_links = {}

    def add_link(self, link: Link) -> None:
        """Register a directional link after construction (aggregating
        sublinks like the constructor does) and bump the plan epoch."""
        self._register(link)
        self.bump_epoch()

    def remove_link(self, src: int, dst: int) -> None:
        """Drop the directional link ``src -> dst`` (e.g. a failed NVLink)
        and bump the plan epoch; raises ``KeyError`` if absent."""
        del self._links[(src, dst)]
        self.bump_epoch()

    # -- calibration (measured-feedback overlay, DESIGN §4.4c) -------------
    def digest(self) -> str:
        """Structural identity of this topology: a stable hash over the
        *nominal* link set ``(num_devices, sorted (src, dst, kind, bw))``.

        Calibration profiles are keyed by this digest so fitted terms can
        never be applied to a different machine shape. Deliberately
        ignores the calibrated overlay — attaching a profile does not
        change what machine this is.
        """
        payload = (self.num_devices,
                   tuple(sorted((k[0], k[1], ln.kind,
                                 round(ln.bandwidth_gbps, 6))
                                for k, ln in self._links.items())))
        return hashlib.sha256(repr(payload).encode()).hexdigest()[:32]

    @property
    def calibration(self) -> Any | None:
        """The live calibration profile, or ``None`` when the model runs
        on nominal constants. Set via :meth:`set_calibration`."""
        return self._calibration

    def set_calibration(self, profile: Any | None) -> None:
        """Attach (or with ``None`` detach) a calibration profile.

        ``profile`` duck-types :class:`repro.comm.calibration.\
        CalibrationProfile`: it must carry ``topology_digest``,
        ``link_bandwidth_gbps`` (``(src, dst) -> GB/s``) and ``launch``.
        Raises ``ValueError`` if the profile's digest does not match this
        topology's :meth:`digest` (fitted terms from another machine
        shape are refused, never silently misapplied). Attaching bumps
        the plan epoch: every cached plan and fast-path entry priced on
        the previous terms is invalidated.
        """
        if profile is not None:
            if profile.topology_digest != self.digest():
                raise ValueError(
                    f"calibration profile digest "
                    f"{profile.topology_digest!r} does not match topology "
                    f"{self.name!r} digest {self.digest()!r}")
            shadows = {}
            for key, bw in profile.link_bandwidth_gbps.items():
                nominal = self._links.get(tuple(key))
                if nominal is not None and bw > 0:
                    shadows[tuple(key)] = Link(
                        nominal.src, nominal.dst, nominal.kind, float(bw))
            self._calibration = profile
            self._calibrated_links = shadows
        else:
            self._calibration = None
            self._calibrated_links = {}
        self._epoch += 1  # not bump_epoch(): digest unchanged, keep profile

    # -- queries ----------------------------------------------------------
    @property
    def links(self) -> Mapping[tuple[int, int], Link]:
        return self._links

    def link(self, src: int, dst: int) -> Link | None:
        """The directional link ``src -> dst`` (or ``None``). When a
        calibration profile is live, returns the fitted-bandwidth shadow
        of the nominal link — every model evaluation that reads
        bandwidths through here consumes measured terms automatically."""
        key = (src, dst)
        if self._calibrated_links:
            hit = self._calibrated_links.get(key)
            if hit is not None:
                return hit
        return self._links.get(key)

    def has_link(self, src: int, dst: int) -> bool:
        return (src, dst) in self._links

    def neighbors(self, dev: int) -> list[int]:
        return sorted({d for (s, d) in self._links if s == dev})

    def devices(self) -> list[int]:
        return list(range(self.num_devices))

    # -- constructors ------------------------------------------------------
    @classmethod
    def full_mesh(cls, num_devices: int = 4, sublinks_per_pair: int = 2,
                  sublink_gbps: float = 25.0, host_gbps: float = 12.0,
                  with_host: bool = True, name: str = "beluga4") -> "Topology":
        """Beluga-like node: ``num_devices`` GPUs, NVLink full mesh + PCIe host.

        Beluga: 4×V100, 2 NVLink sublinks/pair (~25 GB/s each).
        Narval: 4×A100, pass ``sublinks_per_pair=4`` (name="narval4").
        """
        links = []
        for a, b in itertools.permutations(range(num_devices), 2):
            for _ in range(sublinks_per_pair):
                links.append(Link(a, b, "nvlink", sublink_gbps))
        if with_host:
            for d in range(num_devices):
                links.append(Link(d, HOST, "pcie", host_gbps))
                links.append(Link(HOST, d, "pcie", host_gbps))
        return cls(num_devices, links, name=name,
                   grid_shape=(num_devices,))

    @classmethod
    def torus2d(cls, nx: int, ny: int, link_gbps: float = ICI_LINK_GBPS,
                name: str | None = None) -> "Topology":
        """TPU-style 2-D torus: every chip has ±x, ±y ICI links (wraparound).

        For degenerate axes (size 2) the wraparound link is folded into the
        single neighbour link (doubled bandwidth), matching real ICI cabling.
        """
        links: list[Link] = []

        def dev(x: int, y: int) -> int:
            return (x % nx) * ny + (y % ny)

        for x in range(nx):
            for y in range(ny):
                s = dev(x, y)
                nbrs = []
                if nx > 1:
                    nbrs += [dev(x + 1, y), dev(x - 1, y)]
                if ny > 1:
                    nbrs += [dev(x, y + 1), dev(x, y - 1)]
                for n in nbrs:
                    if n != s:
                        links.append(Link(s, n, "ici", link_gbps))
        return cls(nx * ny, links, name=name or f"torus{nx}x{ny}",
                   grid_shape=(nx, ny))

    def coords(self, dev: int) -> tuple[int, ...]:
        if self.grid_shape is None or len(self.grid_shape) != 2:
            return (dev,)
        ny = self.grid_shape[1]
        return (dev // ny, dev % ny)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Topology(name={self.name!r}, devices={self.num_devices}, "
                f"links={len(self._links)})")
