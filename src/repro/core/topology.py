"""Hardware topology model: devices, links, and multi-path route enumeration.

This is the TPU/JAX adaptation of the paper's Base Module (DESIGN.md §2/§3):
it probes the "hardware" (here: a declarative link model for a TPU ICI torus
or a Beluga/Narval-like NVLink full-mesh) and exposes the link graph that the
:class:`~repro.core.paths.PathPlanner` enumerates routes over.

Bandwidths are unidirectional per directional link, GB/s. The paper's hardware
constants (2 NVLink sublinks/pair on Beluga, 4 on Narval, PCIe host links) and
the TPU v5e constants (4 ICI links/chip, ~50 GB/s/link/direction) are both
representable.

Hierarchy (DESIGN.md §3.1): every device belongs to exactly one *island*
(node). Flat topologies put all devices in island 0; :meth:`Topology.\
hierarchical` builds N islands of intra-node links joined by per-tier
inter-node links (e.g. ``"nvlink"`` inside, ``"ib"``/``"dcn"`` between).
The island assignment is part of the structural :meth:`Topology.digest`
and therefore of the plan-validity epoch: two topologies with identical
links but different node boundaries can never cross-serve cached plans or
calibration profiles.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Any, Iterable, Mapping

HOST = -1  # sentinel device id for the host (PCIe-staged) node

#: Process-wide source of topology/planner instance ids. Epoch tokens pair
#: a uid with a mutation counter so tokens from two different instances can
#: never collide (an ``id()``-based token could be reused after GC).
_UID_SOURCE = itertools.count()

#: TPU v5e calibration constants (per chip) used by the roofline model too.
ICI_LINK_GBPS = 50.0
HBM_GBPS = 819.0
PEAK_BF16_TFLOPS = 197.0


@dataclasses.dataclass(frozen=True)
class Link:
    """A directional link ``src -> dst`` with unidirectional bandwidth.

    Validated at construction (positive bandwidth, no self-links); the
    §4.4 model reads every bandwidth through links, so the invariant
    "a registered link is usable" holds everywhere downstream. ``kind``
    is the bandwidth class/tier — intra-node (``"nvlink"``, ``"ici"``),
    host (``"pcie"``) or inter-node (``"ib"``, ``"dcn"``).
    """

    src: int
    dst: int
    kind: str  # "ici" | "nvlink" | "pcie" | "ib" | "dcn"
    bandwidth_gbps: float

    def __post_init__(self) -> None:
        if self.bandwidth_gbps <= 0:
            raise ValueError(f"non-positive bandwidth on {self}")
        if self.src == self.dst:
            raise ValueError(f"self-link {self}")


@dataclasses.dataclass(frozen=True)
class Route:
    """A path from ``src`` to ``dst``: one hop (direct) or two (staged).

    ``via`` is the staging device (or :data:`HOST`); ``None`` means direct.
    ``bottleneck_gbps`` is the min link bandwidth along the route — the
    paper's per-path ``share[p]`` is proportional to it (§4.4). Routes in
    one plan are link-disjoint (the §4.5 contention invariant the
    planner preserves by construction).
    """

    src: int
    dst: int
    via: int | None
    hops: tuple[Link, ...]
    bottleneck_gbps: float

    @property
    def kind(self) -> str:
        """Route class: ``"direct"``, ``"staged_host"`` or
        ``"staged_device"`` (derived from ``via``)."""
        if self.via is None:
            return "direct"
        return "staged_host" if self.via == HOST else "staged_device"

    @property
    def num_hops(self) -> int:
        """Number of hops (links) along the route."""
        return len(self.hops)

    def directional_links(self) -> tuple[tuple[int, int], ...]:
        """The ``(src, dst)`` directional-link keys along the route, in
        hop order — the unit of §4.5 link-exclusivity accounting."""
        return tuple((h.src, h.dst) for h in self.hops)


class Topology:
    """Directed link graph over ``num_devices`` accelerators (+ host).

    Structural identity (links **and** island assignment) is captured by
    :meth:`digest`; any mutation bumps the :attr:`epoch` plan-validity
    token, so every cached plan / fast-path entry / calibration profile
    derived from a previous shape is invalidated, never silently reused.
    """

    def __init__(self, num_devices: int, links: Iterable[Link],
                 name: str = "custom",
                 grid_shape: tuple[int, ...] | None = None,
                 node_assignment: Iterable[int] | None = None):
        self.num_devices = int(num_devices)
        self.name = name
        self.grid_shape = grid_shape
        self._uid = next(_UID_SOURCE)
        self._epoch = 0
        self._links: dict[tuple[int, int], Link] = {}
        #: Island (node) membership, device -> island id. Flat topologies
        #: keep every device in island 0; the tuple is part of digest().
        self._node_assignment = self._check_assignment(node_assignment)
        #: Measured-feedback overlay (DESIGN §4.4c): a calibration profile
        #: attached via :meth:`set_calibration` plus the per-link ``Link``
        #: shadows :meth:`link` serves while it is live.
        self._calibration: Any | None = None
        self._calibrated_links: dict[tuple[int, int], Link] = {}
        #: Fault-model state (DESIGN §4.6): failed links are *removed*
        #: from the nominal set (stashed here for :meth:`restore_link`),
        #: degraded links keep their nominal entry but :meth:`link`
        #: serves a bandwidth-scaled shadow, and flaky marks are advisory
        #: metadata the health monitor reads for re-admission hysteresis.
        self._failed: dict[tuple[int, int], Link] = {}
        self._degraded: dict[tuple[int, int], float] = {}
        self._flaky: set[tuple[int, int]] = set()
        for link in links:
            self._register(link)

    def _check_assignment(self, node_assignment: Iterable[int] | None
                          ) -> tuple[int, ...]:
        if node_assignment is None:
            return (0,) * self.num_devices
        assignment = tuple(int(n) for n in node_assignment)
        if len(assignment) != self.num_devices:
            raise ValueError(
                f"node_assignment length {len(assignment)} != "
                f"num_devices {self.num_devices}")
        if any(n < 0 for n in assignment):
            raise ValueError(f"negative island id in {assignment}")
        return assignment

    def _register(self, link: Link) -> None:
        key = (link.src, link.dst)
        if key in self._links:
            # Multiple sublinks between a pair (e.g. 2 NVLinks on Beluga)
            # aggregate into one logical link with summed bandwidth.
            old = self._links[key]
            link = Link(link.src, link.dst, old.kind,
                        old.bandwidth_gbps + link.bandwidth_gbps)
        self._links[key] = link

    # -- mutation & epoch --------------------------------------------------
    @property
    def epoch(self) -> tuple[int, int]:
        """Plan-validity token ``(uid, mutations)`` for this topology.

        Cached plans and compiled fast-path entries
        (:class:`repro.comm.cache.FastPathCache`) are stamped with the
        epoch in force when they were built; any link mutation
        (:meth:`add_link`, :meth:`remove_link`, :meth:`bump_epoch`)
        changes the token, so stale routes can never be served.
        """
        return (self._uid, self._epoch)

    def bump_epoch(self) -> None:
        """Invalidate every plan derived from this topology.

        Call after mutating link state out-of-band (e.g. poking
        ``_links`` directly); :meth:`add_link` / :meth:`remove_link` call
        it for you. If a calibration profile is attached and the
        structural :meth:`digest` no longer matches it (links were added
        or removed), the profile is dropped — fitted terms for a topology
        that no longer exists must never survive a mutation.
        """
        self._epoch += 1
        if (self._calibration is not None
                and self._calibration.topology_digest != self.digest()):
            self._calibration = None
            self._calibrated_links = {}

    def add_link(self, link: Link) -> None:
        """Register a directional link after construction (aggregating
        sublinks like the constructor does) and bump the plan epoch.
        Re-adding a currently-failed pair drops the failure stash — the
        explicit registration supersedes the fault record, preserving
        the invariant that a key is never both live and failed."""
        self._failed.pop((link.src, link.dst), None)
        self._register(link)
        self.bump_epoch()

    def remove_link(self, src: int, dst: int) -> None:
        """Drop the directional link ``src -> dst`` permanently (unlike
        :meth:`fail_link` there is no restore stash) and bump the plan
        epoch; any droop/flaky overlay for the pair is cleared so no
        fault state outlives the link. Raises ``KeyError`` if absent."""
        del self._links[(src, dst)]
        self._degraded.pop((src, dst), None)
        self._flaky.discard((src, dst))
        self.bump_epoch()

    # -- calibration (measured-feedback overlay, DESIGN §4.4c) -------------
    def digest(self) -> str:
        """Structural identity of this topology: a stable hash over the
        *nominal* link set ``(num_devices, node assignment,
        sorted (src, dst, kind, bw))``.

        Calibration profiles are keyed by this digest so fitted terms can
        never be applied to a different machine shape. The island
        assignment is part of the payload: two topologies with identical
        links but different node boundaries route differently, so their
        plans/profiles must never cross-serve. Deliberately ignores the
        calibrated overlay — attaching a profile does not change what
        machine this is.
        """
        payload = (self.num_devices,
                   self._node_assignment,
                   tuple(sorted((k[0], k[1], ln.kind,
                                 round(ln.bandwidth_gbps, 6))
                                for k, ln in self._links.items())))
        return hashlib.sha256(repr(payload).encode()).hexdigest()[:32]

    @property
    def calibration(self) -> Any | None:
        """The live calibration profile, or ``None`` when the model runs
        on nominal constants. Set via :meth:`set_calibration`."""
        return self._calibration

    def set_calibration(self, profile: Any | None) -> None:
        """Attach (or with ``None`` detach) a calibration profile.

        ``profile`` duck-types :class:`repro.comm.calibration.\
        CalibrationProfile`: it must carry ``topology_digest``,
        ``link_bandwidth_gbps`` (``(src, dst) -> GB/s``) and ``launch``.
        Raises ``ValueError`` if the profile's digest does not match this
        topology's :meth:`digest` (fitted terms from another machine
        shape are refused, never silently misapplied). Attaching bumps
        the plan epoch: every cached plan and fast-path entry priced on
        the previous terms is invalidated.
        """
        if profile is not None:
            if profile.topology_digest != self.digest():
                raise ValueError(
                    f"calibration profile digest "
                    f"{profile.topology_digest!r} does not match topology "
                    f"{self.name!r} digest {self.digest()!r}")
            shadows = {}
            for key, bw in profile.link_bandwidth_gbps.items():
                nominal = self._links.get(tuple(key))
                if nominal is not None and bw > 0:
                    shadows[tuple(key)] = Link(
                        nominal.src, nominal.dst, nominal.kind, float(bw))
            self._calibration = profile
            self._calibrated_links = shadows
        else:
            self._calibration = None
            self._calibrated_links = {}
        self._epoch += 1  # not bump_epoch(): digest unchanged, keep profile

    # -- fault model (link health, DESIGN §4.6) ----------------------------
    def fail_link(self, src: int, dst: int) -> None:
        """Take the directional link ``src -> dst`` down (hard failure).

        The link leaves the nominal set entirely — :meth:`link`,
        :attr:`links`, :meth:`neighbors`, :meth:`egress_devices` and
        :meth:`digest` all see the surviving machine shape, so every
        planner/model consumer routes around it without special cases —
        and is stashed so :meth:`restore_link` can reinstate it
        *identically* (the digest-returns-to-pre-fault-value contract).
        Bumps the plan epoch: no cached plan or fast-path entry built on
        the failed link can ever be served again. Raises ``KeyError`` if
        the link is absent or already failed.
        """
        key = (src, dst)
        self._failed[key] = self._links.pop(key)
        self.bump_epoch()

    def restore_link(self, src: int, dst: int) -> None:
        """Bring a faulted link back to nominal health.

        Reinstates a failed link exactly as stashed by :meth:`fail_link`
        (so :meth:`digest` returns to its pre-fault value when no other
        mutation happened) and clears any degradation ratio and flaky
        mark — restore means full nominal re-admission at the hardware
        layer; quarantine re-admission stays the health monitor's probe
        decision. Bumps the plan epoch so degraded-mode plans are
        invalidated. Raises ``KeyError`` if the link carries no fault
        state at all.
        """
        key = (src, dst)
        if (key not in self._failed and key not in self._degraded
                and key not in self._flaky):
            raise KeyError(f"link {key} has no fault state to restore")
        if key in self._failed:
            self._register(self._failed.pop(key))
        self._degraded.pop(key, None)
        self._flaky.discard(key)
        self.bump_epoch()

    def degrade_link(self, src: int, dst: int, ratio: float) -> None:
        """Droop the link's effective bandwidth to ``ratio`` × nominal.

        A performance overlay in the :meth:`set_calibration` mold: the
        nominal link stays registered (structural :meth:`digest`
        unchanged, an attached calibration profile survives) but
        :meth:`link` serves a bandwidth-scaled shadow, so every model
        read — planner shares, §4.4 arbitration, collective tier
        bandwidths — prices the droop automatically. Bumps the plan
        epoch directly; ``ratio == 1.0`` clears the droop. Raises
        ``ValueError`` for ratios outside ``(0, 1]`` and ``KeyError``
        if the link is absent (or currently failed).
        """
        if not 0.0 < ratio <= 1.0:
            raise ValueError(f"degrade ratio must be in (0, 1], got {ratio}")
        key = (src, dst)
        if key not in self._links:
            raise KeyError(f"no link {key} to degrade")
        if ratio == 1.0:
            self._degraded.pop(key, None)
        else:
            self._degraded[key] = float(ratio)
        self._epoch += 1  # digest unchanged: droop is an overlay

    def mark_flaky(self, src: int, dst: int, flaky: bool = True) -> None:
        """Mark (or clear) a link as flaky — advisory fault metadata.

        A flaky link routes normally, but the health monitor demands a
        longer consecutive-healthy probe streak before re-admitting it
        from quarantine (hysteresis against flapping). Bumps the plan
        epoch conservatively so monitors keyed on fault state observe
        the change; the structural digest is preserved. Raises
        ``KeyError`` if the link is absent from the nominal set.
        """
        key = (src, dst)
        if key not in self._links and key not in self._failed:
            raise KeyError(f"no link {key} to mark flaky")
        if flaky:
            self._flaky.add(key)
        else:
            self._flaky.discard(key)
        self._epoch += 1  # digest unchanged: advisory overlay

    @property
    def failed_links(self) -> Mapping[tuple[int, int], Link]:
        """Links currently failed (``(src, dst) -> stashed nominal
        Link``) — invisible to every query until restored; the engine's
        degraded-mode dispatch validates entries against this set."""
        return self._failed

    @property
    def degraded_links(self) -> Mapping[tuple[int, int], float]:
        """Live droop overlay ``(src, dst) -> ratio``; :meth:`link`
        serves ``ratio × (calibrated or nominal)`` bandwidth while an
        entry is present (structural digest preserved)."""
        return self._degraded

    @property
    def flaky_links(self) -> frozenset:
        """Links marked flaky — the health monitor's re-admission
        hysteresis set (contract: advisory only, routing unchanged)."""
        return frozenset(self._flaky)

    def link_state(self, src: int, dst: int) -> str:
        """Fault-model state of the directional link: ``"failed"``,
        ``"degraded"``, ``"up"`` or ``"absent"`` — the single predicate
        health probes validate a link against."""
        key = (src, dst)
        if key in self._failed:
            return "failed"
        if key in self._degraded:
            return "degraded"
        return "up" if key in self._links else "absent"

    # -- hierarchy (islands / node boundaries, DESIGN §3.1) ----------------
    @property
    def num_islands(self) -> int:
        """Number of distinct islands (nodes); 1 for flat topologies."""
        return len(set(self._node_assignment))

    def node_of(self, dev: int) -> int:
        """Island (node) id of device ``dev``.

        Raises ``ValueError`` for out-of-range ids, including
        :data:`HOST` — the host is a staging point, not an island member
        (host hops never count as inter-island; see
        :meth:`is_inter_island`).
        """
        if not 0 <= dev < self.num_devices:
            raise ValueError(f"device {dev} has no island "
                             f"(num_devices={self.num_devices})")
        return self._node_assignment[dev]

    def islands(self) -> tuple[tuple[int, ...], ...]:
        """Device ids grouped per island, ordered by island id.

        The grouping is derived from the same node assignment that
        :meth:`digest` folds in, so models keyed on it share the plan
        epoch's validity.
        """
        groups: dict[int, list[int]] = {}
        for dev, island in enumerate(self._node_assignment):
            groups.setdefault(island, []).append(dev)
        return tuple(tuple(groups[i]) for i in sorted(groups))

    def is_inter_island(self, src: int, dst: int) -> bool:
        """True iff ``src -> dst`` crosses a node boundary.

        :data:`HOST` endpoints are never inter-island (the host belongs
        to no island); the §4.4 tier-aware costing and the planner's
        route invariants both key off this predicate.
        """
        if src == HOST or dst == HOST:
            return False
        return self.node_of(src) != self.node_of(dst)

    def egress_devices(self, island: int) -> tuple[int, ...]:
        """Devices of ``island`` owning at least one inter-island link —
        the fan-out targets of staged cross-island routes (§4.4)."""
        out = []
        for dev, isl in enumerate(self._node_assignment):
            if isl != island:
                continue
            for (s, d) in self._links:
                if s == dev and self.is_inter_island(s, d):
                    out.append(dev)
                    break
        return tuple(out)

    def set_node_assignment(self, node_assignment: Iterable[int] | None
                            ) -> None:
        """Reassign node boundaries (``None`` flattens to one island) and
        bump the plan epoch — the digest changes, so any attached
        calibration profile is dropped and every cached plan derived from
        the previous island layout is invalidated."""
        self._node_assignment = self._check_assignment(node_assignment)
        self.bump_epoch()

    # -- queries ----------------------------------------------------------
    @property
    def links(self) -> Mapping[tuple[int, int], Link]:
        """The nominal directional-link map ``(src, dst) -> Link``."""
        return self._links

    def link(self, src: int, dst: int) -> Link | None:
        """The directional link ``src -> dst`` (or ``None``). When a
        calibration profile is live, returns the fitted-bandwidth shadow
        of the nominal link — every model evaluation that reads
        bandwidths through here consumes measured terms automatically.
        A live droop overlay (:meth:`degrade_link`) scales the served
        bandwidth on top, and a failed link is ``None`` until restored —
        the fault model's invariant that no consumer can price or route
        over a link that is down."""
        key = (src, dst)
        base = None
        if self._calibrated_links:
            base = self._calibrated_links.get(key)
        if base is None:
            base = self._links.get(key)
        if base is not None and self._degraded:
            ratio = self._degraded.get(key)
            if ratio is not None:
                return Link(base.src, base.dst, base.kind,
                            base.bandwidth_gbps * ratio)
        return base

    def has_link(self, src: int, dst: int) -> bool:
        """True iff the nominal directional link ``src -> dst`` exists."""
        return (src, dst) in self._links

    def neighbors(self, dev: int) -> list[int]:
        """Devices (possibly :data:`HOST`) reachable from ``dev`` over
        one directional link, sorted."""
        return sorted({d for (s, d) in self._links if s == dev})

    def devices(self) -> list[int]:
        """All accelerator device ids, ``[0, num_devices)``."""
        return list(range(self.num_devices))

    # -- constructors ------------------------------------------------------
    @classmethod
    def full_mesh(cls, num_devices: int = 4, sublinks_per_pair: int = 2,
                  sublink_gbps: float = 25.0, host_gbps: float = 12.0,
                  with_host: bool = True, name: str = "beluga4") -> "Topology":
        """Beluga-like node: ``num_devices`` GPUs, NVLink full mesh + PCIe host.

        Beluga: 4×V100, 2 NVLink sublinks/pair (~25 GB/s each).
        Narval: 4×A100, pass ``sublinks_per_pair=4`` (name="narval4").
        """
        links = []
        for a, b in itertools.permutations(range(num_devices), 2):
            for _ in range(sublinks_per_pair):
                links.append(Link(a, b, "nvlink", sublink_gbps))
        if with_host:
            for d in range(num_devices):
                links.append(Link(d, HOST, "pcie", host_gbps))
                links.append(Link(HOST, d, "pcie", host_gbps))
        return cls(num_devices, links, name=name,
                   grid_shape=(num_devices,))

    @classmethod
    def torus2d(cls, nx: int, ny: int, link_gbps: float = ICI_LINK_GBPS,
                name: str | None = None) -> "Topology":
        """TPU-style 2-D torus: every chip has ±x, ±y ICI links (wraparound).

        For degenerate axes (size 2) the wraparound link is folded into the
        single neighbour link (doubled bandwidth), matching real ICI cabling.
        """
        links = _torus_links(nx, ny, link_gbps)
        return cls(nx * ny, links, name=name or f"torus{nx}x{ny}",
                   grid_shape=(nx, ny))

    @classmethod
    def hierarchical(cls, num_islands: int = 2, devices_per_island: int = 4,
                     *, intra: str = "mesh",
                     sublinks_per_pair: int = 2, sublink_gbps: float = 25.0,
                     torus_shape: tuple[int, int] | None = None,
                     intra_gbps: float = ICI_LINK_GBPS,
                     inter_gbps: float = 12.5, inter_kind: str = "ib",
                     egress_per_island: int = 1,
                     name: str | None = None) -> "Topology":
        """Multi-node topology: islands of fast intra-node links joined by
        a slower inter-node tier (De Sensi et al.; DESIGN §3.1).

        Each island is either an NVLink full mesh (``intra="mesh"``,
        ``sublinks_per_pair`` × ``sublink_gbps`` per pair) or an ICI
        2-D torus (``intra="torus"`` with ``torus_shape``,
        ``intra_gbps``/link). The first ``egress_per_island`` devices of
        every island are its egress points: egress ``e`` of island ``a``
        links to egress ``e`` of island ``b`` (both directions, all island
        pairs, ``inter_kind``/``inter_gbps``) — so every cross-island
        route has exactly one inter-node hop, the invariant the planner's
        staged routing preserves. No host links: a shared host would be a
        hidden cross-island wormhole; add PCIe links explicitly if an
        experiment wants host staging.
        """
        if num_islands < 1:
            raise ValueError(f"num_islands must be >= 1, got {num_islands}")
        if devices_per_island < 1:
            raise ValueError(f"devices_per_island must be >= 1, "
                             f"got {devices_per_island}")
        if not 1 <= egress_per_island <= devices_per_island:
            raise ValueError(
                f"egress_per_island must be in [1, {devices_per_island}], "
                f"got {egress_per_island}")
        links: list[Link] = []
        for island in range(num_islands):
            base = island * devices_per_island
            if intra == "mesh":
                for a, b in itertools.permutations(
                        range(devices_per_island), 2):
                    for _ in range(sublinks_per_pair):
                        links.append(Link(base + a, base + b, "nvlink",
                                          sublink_gbps))
            elif intra == "torus":
                if torus_shape is None or (
                        torus_shape[0] * torus_shape[1]
                        != devices_per_island):
                    raise ValueError(
                        f"intra='torus' needs torus_shape with product "
                        f"{devices_per_island}, got {torus_shape}")
                links.extend(_torus_links(*torus_shape, intra_gbps,
                                          base=base))
            else:
                raise ValueError(f"unknown intra island kind {intra!r}")
        for a, b in itertools.permutations(range(num_islands), 2):
            for e in range(egress_per_island):
                links.append(Link(a * devices_per_island + e,
                                  b * devices_per_island + e,
                                  inter_kind, inter_gbps))
        assignment = [island for island in range(num_islands)
                      for _ in range(devices_per_island)]
        return cls(num_islands * devices_per_island, links,
                   name=name or f"hier{num_islands}x{devices_per_island}",
                   node_assignment=assignment)

    def coords(self, dev: int) -> tuple[int, ...]:
        """Grid coordinates of ``dev`` (2-D tori), else ``(dev,)``."""
        if self.grid_shape is None or len(self.grid_shape) != 2:
            return (dev,)
        ny = self.grid_shape[1]
        return (dev // ny, dev % ny)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Topology(name={self.name!r}, devices={self.num_devices}, "
                f"islands={self.num_islands}, links={len(self._links)})")


def _torus_links(nx: int, ny: int, link_gbps: float,
                 base: int = 0) -> list[Link]:
    """ICI link list for a 2-D torus whose device ids start at ``base``."""
    links: list[Link] = []

    def dev(x: int, y: int) -> int:
        return base + (x % nx) * ny + (y % ny)

    for x in range(nx):
        for y in range(ny):
            s = dev(x, y)
            nbrs = []
            if nx > 1:
                nbrs += [dev(x + 1, y), dev(x - 1, y)]
            if ny > 1:
                nbrs += [dev(x, y + 1), dev(x, y - 1)]
            for n in nbrs:
                if n != s:
                    links.append(Link(s, n, "ici", link_gbps))
    return links
