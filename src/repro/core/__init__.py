"""Core multi-path transfer engine — the paper's primary contribution.

Layering (mirrors the paper's Fig. 3):

* :mod:`repro.core.topology`   — Base Module: link graph / hardware probe
* :mod:`repro.core.paths`      — Multi-Path Communication Handler + tuner
* :mod:`repro.core.pipelining` — 2-D Pipelining Engine + analytic time model
* :mod:`repro.core.plan_cache` — CUDA-Graph-cache analogue (LRU, lifecycle)
* :mod:`repro.core.multipath`  — executable transfer engine (shard_map)
* :mod:`repro.core.collectives`— beyond-paper multipath collectives
* :mod:`repro.core.halo`       — Jacobi halo exchange application layer
"""

from repro.core.topology import HOST, Link, Route, Topology  # noqa: F401
from repro.core.paths import PathAssignment, PathPlanner, TransferPlan  # noqa: F401
from repro.core.pipelining import (  # noqa: F401
    ChunkTask, build_schedule, effective_bandwidth_gbps,
    estimate_transfer_time_s, launch_overhead_ns, validate_plan,
    windowed_bandwidth_gbps)
from repro.core.plan_cache import (  # noqa: F401
    CompiledPlan, PlanLifecycle, TransferPlanCache, compile_plan)
from repro.core.multipath import (  # noqa: F401
    MultiPathTransfer, TransferKey, multipath_send_local, plan_signature)
