"""Core multi-path transfer engine — the paper's primary contribution.

As of the ``repro.comm`` API consolidation, only the hardware model
(:mod:`repro.core.topology`), the analytic pipeline model
(:mod:`repro.core.pipelining`), and the application layer
(:mod:`repro.core.halo`) live here; planning, caching, the executable
engine, and the collectives moved to :mod:`repro.comm` and are re-exported
lazily below for backwards compatibility (lazily both to keep the legacy
surface alive without import cycles and so that ``import repro.core`` stays
cheap). New code should construct a :class:`repro.comm.CommSession`
(see DESIGN.md §5/§6).

Layering (mirrors the paper's Fig. 3):

* :mod:`repro.core.topology`   — Base Module: link graph / hardware probe
* :mod:`repro.comm.planner`    — Multi-Path Communication Handler + tuner
* :mod:`repro.core.pipelining` — 2-D Pipelining Engine + analytic time model
* :mod:`repro.comm.cache`      — CUDA-Graph-cache analogue (LRU, lifecycle)
* :mod:`repro.comm.engine`     — executable transfer engine (shard_map)
* :mod:`repro.comm.collectives`— beyond-paper multipath collectives
* :mod:`repro.comm.session`    — the CommSession facade over all of it
* :mod:`repro.core.halo`       — Jacobi halo exchange application layer
"""

import dataclasses
import importlib
import warnings

from repro.core.topology import HOST, Link, Route, Topology  # noqa: F401
from repro.core.pipelining import (  # noqa: F401
    ChunkTask, DEFAULT_LAUNCH_MODEL, LaunchModel, build_schedule,
    effective_bandwidth_gbps, estimate_group_time_s,
    estimate_transfer_time_s, group_launch_overhead_ns, launch_model_for,
    launch_overhead_ns, scheduled_time_s, validate_group, validate_plan,
    windowed_bandwidth_gbps, wire_time_s)

# Legacy re-exports: these classes moved to repro.comm (PEP 562 lazy
# attributes — resolving them eagerly here would recreate the
# core.topology → core.__init__ → comm → core.topology import cycle).
_COMM_EXPORTS = {
    "PathAssignment": "repro.comm.plan",
    "TransferGroup": "repro.comm.plan",
    "TransferPlan": "repro.comm.plan",
    "TransferRequest": "repro.comm.plan",
    "PathPlanner": "repro.comm.planner",
    "CompiledPlan": "repro.comm.cache",
    "PlanLifecycle": "repro.comm.cache",
    "TransferPlanCache": "repro.comm.cache",
    "compile_plan": "repro.comm.cache",
    "MultiPathTransfer": "repro.comm.engine",
    "multipath_send_local": "repro.comm.engine",
    "plan_signature": "repro.comm.engine",
}


@dataclasses.dataclass(frozen=True)
class _LegacyTransferKey:
    """Pre-group single-message cache key. DEPRECATED and unused: compiled
    programs are keyed by :class:`repro.comm.engine.GroupKey`, whose
    identity is the lowered transfer graph's canonical digest."""

    src: int
    dst: int
    nelems: int
    dtype: str
    plan_sig: tuple
    window: int = 1
    bidirectional: bool = False

__all__ = [  # noqa: F822 - lazy names resolved via __getattr__
    "HOST", "Link", "Route", "Topology",
    "ChunkTask", "DEFAULT_LAUNCH_MODEL", "LaunchModel", "launch_model_for",
    "build_schedule", "effective_bandwidth_gbps",
    "estimate_group_time_s", "estimate_transfer_time_s",
    "group_launch_overhead_ns", "launch_overhead_ns", "scheduled_time_s",
    "validate_group", "validate_plan", "windowed_bandwidth_gbps",
    "wire_time_s",
    "TransferKey",
    *sorted(_COMM_EXPORTS),
]


def __getattr__(name):
    if name == "TransferKey":
        # Deprecation alias only — nothing in the repo constructs one since
        # the transfer-group rework; kept so legacy imports keep resolving.
        warnings.warn(
            "repro.core.TransferKey is deprecated and unused; compiled "
            "programs are keyed by repro.comm.engine.GroupKey (graph "
            "digest)", DeprecationWarning, stacklevel=2)
        return _LegacyTransferKey
    target = _COMM_EXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(target), name)
