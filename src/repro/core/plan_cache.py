"""DEPRECATED shim — the plan cache moved to :mod:`repro.comm.cache`.

A :class:`repro.comm.CommSession` owns one :class:`TransferPlanCache`
shared by P2P sends and collectives (DESIGN.md §6 migration guide).
"""

import warnings

from repro.comm.cache import (  # noqa: F401
    CompiledPlan, PlanLifecycle, TransferPlanCache, compile_plan)

warnings.warn(
    "repro.core.plan_cache is deprecated; use repro.comm "
    "(TransferPlanCache)", DeprecationWarning, stacklevel=2)
