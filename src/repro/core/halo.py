"""Multi-path halo exchange — the paper's Jacobi application (§5.4, Fig. 11).

A 1-D ring decomposition (the paper uses 4 ranks, each exchanging boundary
columns with its two neighbours). With single-path communication only the
±1 ring links carry traffic and the "diagonal" links sit idle (Fig. 11a).
The multipath mode splits each boundary in half and stages the second half
through the diagonal device (Fig. 11b), engaging the otherwise-idle links.

Contention note (paper §5.4): on Beluga each GPU pair has *two* NVLink
sublinks, which is what makes the staged hop-2 contention-free with the
opposite-direction direct sends; our aggregated-link topology models this as
shared doubled bandwidth rather than strict link exclusivity (DESIGN.md §7.3).
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.comm.session import CommSession


def _shift_perm(n: int, shift: int):
    return [(i, (i + shift) % n) for i in range(n)]


def halo_exchange_ring(left_bnd: jax.Array, right_bnd: jax.Array,
                       axis_name: str, *, multipath: bool = False,
                       ) -> tuple[jax.Array, jax.Array]:
    """Exchange boundaries with ring neighbours along ``axis_name``.

    ``left_bnd``/``right_bnd`` are this shard's own boundary slices. Returns
    ``(left_halo, right_halo)``: the right boundary of the left neighbour and
    the left boundary of the right neighbour.

    ``multipath=True`` splits each boundary into two stripes: the first goes
    over the direct ±1 link, the second stages through the device two hops
    around the ring (the idle diagonal on a 4-device node).
    """
    n = axis_size(axis_name)
    if n == 1:
        return right_bnd, left_bnd

    if not multipath or n < 3:
        left_halo = lax.ppermute(right_bnd, axis_name, _shift_perm(n, 1))
        right_halo = lax.ppermute(left_bnd, axis_name, _shift_perm(n, -1))
        return left_halo, right_halo

    def split(b):
        h = b.shape[-1] // 2
        if h == 0:
            return b, b[..., :0]
        return b[..., :h], b[..., h:]

    # to the RIGHT neighbour: my right boundary becomes their left halo.
    r0, r1 = split(right_bnd)
    right_direct = lax.ppermute(r0, axis_name, _shift_perm(n, 1))
    staged = lax.ppermute(r1, axis_name, _shift_perm(n, 2))      # hop-1: diag
    right_staged = lax.ppermute(staged, axis_name, _shift_perm(n, -1))  # hop-2
    left_halo = jnp.concatenate([right_direct, right_staged], axis=-1)

    # to the LEFT neighbour: my left boundary becomes their right halo.
    l0, l1 = split(left_bnd)
    left_direct = lax.ppermute(l0, axis_name, _shift_perm(n, -1))
    staged = lax.ppermute(l1, axis_name, _shift_perm(n, -2))     # hop-1: diag
    left_staged = lax.ppermute(staged, axis_name, _shift_perm(n, 1))   # hop-2
    right_halo = jnp.concatenate([left_direct, left_staged], axis=-1)
    return left_halo, right_halo


def halo_exchange_group(session: "CommSession", blocks: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """Driver-level ring halo exchange as ONE fused transfer group.

    ``blocks`` is the column-decomposed domain, shape ``(n, rows, cols)``
    (one block per rank). Every rank's two boundary columns ride a single
    ``2n``-message group — the paper's 4-rank pattern is a 4-transfer
    group per shift direction — planned jointly (the ring's directional
    links are disjoint, so the group is link-exclusive) and launched once,
    instead of ``2n`` independent sends. Returns ``(left_halos,
    right_halos)``, shape ``(n, rows, 1)`` each: rank *i*'s left halo is
    rank *i-1*'s right boundary and vice versa (periodic; apply Dirichlet
    masking downstream as :func:`jacobi_step` does).
    """
    n = blocks.shape[0]
    if n == 1:
        return blocks[:, :, -1:], blocks[:, :, :1]
    items = []
    for i in range(n):
        items.append((blocks[i, :, -1:], i, (i + 1) % n))  # → right nbr
        items.append((blocks[i, :, :1], i, (i - 1) % n))   # → left nbr
    received = session.exchange(items)
    left_halos = jnp.stack([received[2 * ((i - 1) % n)] for i in range(n)])
    right_halos = jnp.stack([received[2 * ((i + 1) % n) + 1]
                             for i in range(n)])
    return left_halos, right_halos


def make_captured_jacobi_step(session: "CommSession", rows: int, cols: int,
                              dtype=jnp.float32, *,
                              schedule: str | None = None,
                              max_paths: int | None = None,
                              num_chunks: int | None = None):
    """Capture one whole Jacobi iteration (halo exchange + sweep) as ONE
    heterogeneous graph — the reference ``session.capture`` idiom.

    The returned :class:`~repro.comm.capture.CapturedStep` takes the
    column-decomposed domain ``(n, rows, cols)`` and returns the swept
    domain, same shape, in ONE compiled launch: boundary extraction and
    the 5-point stencil are compute nodes, the ``2n``-message ring
    exchange is planned jointly (``max_paths``/``num_chunks`` as in
    :meth:`~repro.comm.session.CommSession.exchange`), and the scheduler
    pass interleaves the copies into the compute gaps. The sweep applies
    *exactly* the eager :func:`jacobi_step` operations (same Dirichlet
    masking, same stencil), and each halo is joined from the exchange's
    reception buffers by exact zero-sum — numerics are identical to the
    eager path, which ``tests/test_capture.py`` asserts bitwise.
    """
    ax = session.axis_name
    n = session.engine.num_devices
    if n < 2:
        raise ValueError("captured Jacobi needs >= 2 devices (the ring "
                         "exchange cannot self-send)")

    def build(cap):
        u = cap.input((rows, cols), dtype)
        right, left = cap.kernel(
            lambda u_: (u_[:, -1], u_[:, 0]), u, name="halo_slices",
            flops=0)
        sends = ([(right, i, (i + 1) % n) for i in range(n)]
                 + [(left, i, (i - 1) % n) for i in range(n)])
        recvs = cap.exchange(sends, max_paths=max_paths,
                             num_chunks=num_chunks)

        def sweep(u_, *halos):
            # device j's left halo is j-1's right boundary: of the n
            # right-going receptions exactly one is nonzero locally.
            left_halo = halos[0]
            for h in halos[1:n]:
                left_halo = left_halo + h
            right_halo = halos[n]
            for h in halos[n + 1:]:
                right_halo = right_halo + h
            left_halo = left_halo.reshape(rows, 1)
            right_halo = right_halo.reshape(rows, 1)
            i = lax.axis_index(ax)
            left_halo = jnp.where(i == 0, jnp.zeros_like(left_halo),
                                  left_halo)
            right_halo = jnp.where(i == n - 1, jnp.zeros_like(right_halo),
                                   right_halo)
            ext = jnp.concatenate([left_halo, u_, right_halo], axis=1)
            up = jnp.pad(ext[:-1, :], ((1, 0), (0, 0)))
            down = jnp.pad(ext[1:, :], ((0, 1), (0, 0)))
            return 0.25 * (ext[:, :-2] + ext[:, 2:] + up[:, 1:-1]
                           + down[:, 1:-1])

        from repro.comm.capture import BufferSpec
        out = cap.kernel(sweep, u, *recvs, name="jacobi_sweep",
                         out=BufferSpec((rows, cols), str(jnp.dtype(dtype))),
                         flops=5 * rows * cols)
        return out

    return session.capture(build, schedule=schedule)


def jacobi_step(u: jax.Array, axis_name: str, *, multipath: bool = False,
                use_kernel: bool = False) -> jax.Array:
    """One Jacobi sweep on a column-partitioned 2-D domain.

    ``u`` is the local block ``(rows, cols)`` of a domain decomposed along
    columns across the ring. Boundary columns are exchanged (optionally
    multi-path), then the 5-point stencil averages the four neighbours with
    zero (Dirichlet) conditions at the global domain edge — matching the
    NVIDIA multi-GPU Jacobi reference the paper benchmarks.
    """
    left_halo, right_halo = halo_exchange_ring(
        u[:, :1], u[:, -1:], axis_name, multipath=multipath)

    n = axis_size(axis_name)
    i = lax.axis_index(axis_name)
    # global edge → Dirichlet zeros
    left_halo = jnp.where(i == 0, jnp.zeros_like(left_halo), left_halo)
    right_halo = jnp.where(i == n - 1, jnp.zeros_like(right_halo), right_halo)

    ext = jnp.concatenate([left_halo, u, right_halo], axis=1)
    if use_kernel:
        from repro.kernels.jacobi import ops as jacobi_ops
        return jacobi_ops.jacobi_sweep(ext)
    up = jnp.pad(ext[:-1, :], ((1, 0), (0, 0)))
    down = jnp.pad(ext[1:, :], ((0, 1), (0, 0)))
    out = 0.25 * (ext[:, :-2] + ext[:, 2:] + up[:, 1:-1] + down[:, 1:-1])
    return out
