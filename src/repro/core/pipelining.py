"""2-D pipelining engine: chunk schedules + the analytic pipeline-time model.

The engine performs the paper's two splits (§4.3):

* **horizontal** — the message is partitioned across the selected paths
  (done by the :class:`~repro.comm.planner.PathPlanner` via its
  :class:`~repro.comm.policy.PathPolicy`, shares ∝ bandwidth),
* **vertical** — each path's share is split into chunks that flow through the
  path's hops in a pipelined fashion (hop-2 of chunk *i* overlaps hop-1 of
  chunk *i+1*).

Because this repo's execution substrate is XLA (no wall-clock TPU), the
module also provides the calibrated analytic time model used by the offline
tuner and the bandwidth benchmarks. The model captures exactly the effects
the paper measures:

* pipelined staged hops (fill + steady-state),
* per-directional-link exclusivity (§4.5) and host-node capacity contention
  (reproduces the paper's "host path hurts BIBW" finding),
* per-copy-node launch overhead vs amortized compiled-plan (CUDA Graph)
  launch overhead, including the first-iteration construction costs
  (paper Fig. 13/14).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import TYPE_CHECKING, Iterable, Sequence

from repro.core.topology import HOST, Topology

if TYPE_CHECKING:  # pragma: no cover - typing only (avoids an import cycle)
    from repro.comm.plan import TransferGroup, TransferPlan


@dataclasses.dataclass(frozen=True)
class ChunkTask:
    """One chunk flowing along one route — ``num_hops`` copy nodes."""

    path_idx: int
    chunk_idx: int
    offset: int
    nbytes: int
    hops: tuple[tuple[int, int], ...]  # directional links, in order


# -- launch-overhead calibration (model constants; the lifecycle benchmark
# measures their JAX analogues empirically) ---------------------------------
LAUNCH_NS_PER_NODE = 6_000          # one async-copy launch (no graphs)
GRAPH_LAUNCH_BASE_NS = 7_000        # cudaGraphLaunch fixed cost analogue
GRAPH_LAUNCH_PER_NODE_NS = 300      # marginal per-node launch cost in a graph
GRAPH_INSTANTIATE_BASE_NS = 90_000  # one-time instantiation (first iter)
GRAPH_INSTANTIATE_PER_NODE_NS = 85_000
SYNC_NS_PER_PATH = 2_000            # event record + stream-wait per path


def build_schedule(plan: TransferPlan) -> list[ChunkTask]:
    """Flatten a plan into chunk tasks, round-robin across paths.

    The paper distributes chunks across paths one-by-one (Alg. 1 note); the
    round-robin order is the dispatch order — data dependencies (hop order
    within a chunk, §4.5) are carried in each task's ``hops``.
    """
    per_path: list[list[ChunkTask]] = []
    for p_idx, pa in enumerate(plan.paths):
        tasks = [
            ChunkTask(p_idx, c_idx, off, size, pa.route.directional_links())
            for c_idx, (off, size) in enumerate(pa.chunk_bounds())
        ]
        per_path.append(tasks)
    schedule: list[ChunkTask] = []
    for wave in range(max((len(t) for t in per_path), default=0)):
        for tasks in per_path:
            if wave < len(tasks):
                schedule.append(tasks[wave])
    return schedule


def validate_plan(plan: TransferPlan) -> None:
    """Assert the §4.5 integrity invariants. Raises ``ValueError`` on breach.

    1. chunk byte ranges are disjoint and exactly cover ``[0, nbytes)``,
    2. no two paths share a directional link (contention avoidance),
    3. every staged route's hops are connected (src → via → dst).
    """
    intervals: list[tuple[int, int]] = []
    seen_links: set[tuple[int, int]] = set()
    for pa in plan.paths:
        links = pa.route.directional_links()
        for link in links:
            if link in seen_links:
                raise ValueError(f"directional link {link} shared by paths")
            seen_links.add(link)
        if links[0][0] != plan.src or links[-1][1] != plan.dst:
            raise ValueError(f"route endpoints wrong: {links}")
        for (a, b), (c, d) in zip(links, links[1:]):
            if b != c:
                raise ValueError(f"disconnected hops {links}")
        intervals.extend(pa.chunk_bounds())
    intervals.sort()
    pos = 0
    for off, size in intervals:
        if off != pos:
            raise ValueError(f"gap/overlap at byte {pos} (chunk at {off})")
        if size <= 0:
            raise ValueError("empty chunk")
        pos = off + size
    if pos != plan.nbytes:
        raise ValueError(f"coverage ends at {pos}, message is {plan.nbytes}")


def _launch_overhead_from_counts(num_nodes: int, num_paths: int, *,
                                 compiled_plan: bool,
                                 first_iteration: bool = False) -> float:
    if not compiled_plan:
        return (num_nodes * LAUNCH_NS_PER_NODE
                + num_paths * SYNC_NS_PER_PATH)
    cost = GRAPH_LAUNCH_BASE_NS + num_nodes * GRAPH_LAUNCH_PER_NODE_NS
    if first_iteration:
        cost += (GRAPH_INSTANTIATE_BASE_NS
                 + num_nodes * GRAPH_INSTANTIATE_PER_NODE_NS)
    return float(cost)


def launch_overhead_ns(plan: TransferPlan, *, compiled_plan: bool,
                       first_iteration: bool = False) -> float:
    """CPU-side overhead for dispatching the plan once (paper §5.5)."""
    return _launch_overhead_from_counts(
        plan.num_nodes, len(plan.paths), compiled_plan=compiled_plan,
        first_iteration=first_iteration)


def group_launch_overhead_ns(plans: Sequence[TransferPlan], *,
                             compiled_plan: bool,
                             first_iteration: bool = False,
                             fused: bool = True) -> float:
    """CPU-side overhead for a transfer group.

    ``fused=True`` models the group as ONE graph launch (the fused SPMD
    program the engine compiles): a single base launch cost amortized over
    the total node count, and one instantiation on the first iteration.
    ``fused=False`` models the legacy dispatch loop — one launch (and one
    first-iteration instantiation) per message.
    """
    if fused:
        return _launch_overhead_from_counts(
            sum(p.num_nodes for p in plans),
            sum(len(p.paths) for p in plans),
            compiled_plan=compiled_plan, first_iteration=first_iteration)
    return sum(launch_overhead_ns(p, compiled_plan=compiled_plan,
                                  first_iteration=first_iteration)
               for p in plans)


def _link_times_s(plan: TransferPlan, topo: Topology,
                  contention: dict[tuple[int, int], int],
                  host_flows: int) -> list[list[float]]:
    """Per-path list of per-hop chunk-times (seconds, steady-state chunk)."""
    out = []
    for pa in plan.paths:
        nchunks = max(1, pa.num_chunks)
        chunk_bytes = pa.nbytes / nchunks
        hop_times = []
        for link in pa.route.hops:
            bw = link.bandwidth_gbps * 1e9
            share = max(1, contention.get((link.src, link.dst), 1))
            # Host-node capacity: concurrent flows staging through the host
            # split its aggregate copy bandwidth (paper §5.3 obs. 6).
            if HOST in (link.src, link.dst) and host_flows > 1:
                share = max(share, host_flows)
            hop_times.append(chunk_bytes / (bw / share))
        out.append(hop_times)
    return out


def wire_time_s(plan: TransferPlan, topo: Topology, *,
                concurrent_plans: Sequence[TransferPlan] = ()) -> float:
    """Pure wire time (no launch overhead) for one message.

    ``concurrent_plans`` are other transfers in flight at the same time
    (e.g. the reverse direction of a bidirectional test, or the other
    messages of a transfer group): any directional link they share with
    ``plan`` is time-shared, and host-staged flows contend on host
    capacity.
    """
    contention: dict[tuple[int, int], int] = defaultdict(lambda: 0)
    host_flows = 0
    for p in (plan, *concurrent_plans):
        for pa in p.paths:
            for link in pa.route.directional_links():
                contention[link] += 1
            if pa.route.via == HOST:
                host_flows += 1

    per_path = _link_times_s(plan, topo, dict(contention), host_flows)
    path_times = []
    for pa, hop_times in zip(plan.paths, per_path):
        n = max(1, pa.num_chunks)
        fill = sum(hop_times)                 # first chunk traverses all hops
        steady = (n - 1) * max(hop_times)     # pipeline bottleneck stage
        path_times.append(fill + steady)
    return max(path_times) if path_times else 0.0


def estimate_transfer_time_s(
        plan: TransferPlan, topo: Topology, *,
        compiled_plan: bool = True,
        first_iteration: bool = False,
        concurrent_plans: Sequence[TransferPlan] = ()) -> float:
    """Analytic end-to-end time for one message under the pipeline model.

    See :func:`wire_time_s` for the ``concurrent_plans`` contention
    semantics; launch overhead is added per §5.5.
    """
    return wire_time_s(plan, topo, concurrent_plans=concurrent_plans) + (
        launch_overhead_ns(plan, compiled_plan=compiled_plan,
                           first_iteration=first_iteration) / 1e9)


def _group_plans(group) -> tuple:
    plans = getattr(group, "plans", group)
    return tuple(plans)


def validate_group(group: "TransferGroup | Sequence[TransferPlan]") -> None:
    """Assert the group-level §4.5 invariants. Raises ``ValueError``.

    1. every plan individually satisfies :func:`validate_plan` (disjoint
       cover of its own message, within-plan link exclusivity, ...),
    2. **cross-flow link exclusivity** — no directional link is used by
       plans of two *distinct* flows (src, dst). Plans of the same flow
       (e.g. the leaves of one pytree migration) legitimately share that
       flow's routes and are exempt.
    """
    owner: dict[tuple[int, int], tuple[int, int]] = {}
    for plan in _group_plans(group):
        validate_plan(plan)
        flow = (plan.src, plan.dst)
        for link in plan.directional_links():
            prev = owner.setdefault(link, flow)
            if prev != flow:
                raise ValueError(
                    f"directional link {link} shared across flows {prev} "
                    f"and {flow} (group-level §4.5 exclusivity breach)")


def estimate_group_time_s(
        group: "TransferGroup | Sequence[TransferPlan]", topo: Topology, *,
        compiled_plan: bool = True,
        first_iteration: bool = False,
        fused: bool = True) -> float:
    """Analytic makespan of a set of concurrent transfers.

    ``fused=True`` is the transfer-group execution model: one compiled
    launch covering every message, so the makespan is a single (fused)
    launch overhead plus the slowest message's wire time — each message
    priced with every other group member as concurrent traffic.

    ``fused=False`` is the legacy dispatch loop (one compiled program per
    message, launched back-to-back without blocking): the CPU serializes
    the launches, so message *i* cannot start before launches ``1..i``
    have issued, while the wires still contend. This is the baseline
    `exchange()` is measured against.
    """
    plans = _group_plans(group)
    if not plans:
        return 0.0
    others = [
        [q for j, q in enumerate(plans) if j != i]
        for i in range(len(plans))
    ]
    wires = [wire_time_s(p, topo, concurrent_plans=o)
             for p, o in zip(plans, others)]
    if fused:
        return max(wires) + group_launch_overhead_ns(
            plans, compiled_plan=compiled_plan,
            first_iteration=first_iteration, fused=True) / 1e9
    makespan, dispatched = 0.0, 0.0
    for plan, wire in zip(plans, wires):
        dispatched += launch_overhead_ns(
            plan, compiled_plan=compiled_plan,
            first_iteration=first_iteration) / 1e9
        makespan = max(makespan, dispatched + wire)
    return makespan


def effective_bandwidth_gbps(plan: TransferPlan, topo: Topology, *,
                             compiled_plan: bool = True,
                             concurrent_plans: Sequence[TransferPlan] = (),
                             ) -> float:
    t = estimate_transfer_time_s(plan, topo, compiled_plan=compiled_plan,
                                 concurrent_plans=concurrent_plans)
    return plan.nbytes / t / 1e9


def windowed_bandwidth_gbps(plan: TransferPlan, topo: Topology, *,
                            window: int, compiled_plan: bool = True) -> float:
    """OMB-style windowed bandwidth: ``window`` back-to-back messages.

    Launch overheads of messages 2..W overlap the wire time of earlier
    messages (the paper's window-size effect, §5.3 obs. 3): with compiled
    plans the CPU can run ahead, so per-message cost approaches pure wire
    time; without, per-node launches serialize on the CPU.
    """
    wire = estimate_transfer_time_s(plan, topo, compiled_plan=True)
    wire -= launch_overhead_ns(plan, compiled_plan=True) / 1e9  # pure wire
    launch = launch_overhead_ns(plan, compiled_plan=compiled_plan) / 1e9
    # CPU dispatch pipeline: total = first launch + max(wire, launch)*(W-1)
    # + wire of the last message's tail.
    total = launch + window * wire if launch <= wire else (
        window * launch + wire)
    return plan.nbytes * window / total / 1e9
