"""2-D pipelining engine: chunk schedules + the analytic pipeline-time model.

The engine performs the paper's two splits (§4.3):

* **horizontal** — the message is partitioned across the selected paths
  (done by the :class:`~repro.comm.planner.PathPlanner` via its
  :class:`~repro.comm.policy.PathPolicy`, shares ∝ bandwidth),
* **vertical** — each path's share is split into chunks that flow through the
  path's hops in a pipelined fashion (hop-2 of chunk *i* overlaps hop-1 of
  chunk *i+1*).

As of the transfer-graph IR (DESIGN.md §2.1), everything in this module is
a *view over* or an *evaluation of* the :class:`~repro.comm.graph.\
TransferGraph` produced by the single lowering pass
:func:`repro.comm.graph.lower` — the same copy-node DAG the executable
engine walks:

* :func:`build_schedule` flattens graph nodes into dispatch-ordered
  :class:`ChunkTask` views,
* :func:`validate_plan` / :func:`validate_group` are the §4.5 invariants
  checked on graph nodes/edges (:meth:`TransferGraph.validate`),
* :func:`wire_time_s` / :func:`estimate_transfer_time_s` /
  :func:`estimate_group_time_s` evaluate the **critical path** of the DAG
  (hop edges + per-link serialization edges), and the launch-overhead
  model prices per-node launch cost × graph node count,
* :func:`scheduled_time_s` is the schedule-*aware* variant: an exact
  weighted longest path over a (possibly pass-reordered) graph, the
  arbiter the ``auto`` scheduler in :mod:`repro.comm.passes` uses to
  pick a dispatch order before compiling (DESIGN.md §2.2).

Because this repo's execution substrate is XLA (no wall-clock TPU), the
time model is calibrated-analytic; it captures exactly the effects the
paper measures: pipelined staged hops (fill + steady-state),
per-directional-link exclusivity (§4.5) and host-node capacity contention
(the paper's "host path hurts BIBW" finding), and per-copy-node launch
overhead vs amortized compiled-plan (CUDA Graph) launch overhead including
first-iteration construction costs (paper Fig. 13/14).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import TYPE_CHECKING, Sequence

from repro.core.topology import HOST, Topology

if TYPE_CHECKING:  # pragma: no cover - typing only (avoids an import cycle)
    from repro.comm.graph import TransferGraph
    from repro.comm.plan import TransferGroup, TransferPlan


@dataclasses.dataclass(frozen=True)
class ChunkTask:
    """One chunk flowing along one route — ``num_hops`` copy nodes.

    A thin dispatch-ordered *view* over the transfer graph: ``hops`` is
    the chunk's copy-node chain collapsed into its link sequence.
    """

    path_idx: int
    chunk_idx: int
    offset: int
    nbytes: int
    hops: tuple[tuple[int, int], ...]  # directional links, in order


# -- launch-overhead calibration (model constants; the lifecycle benchmark
# measures their JAX analogues empirically) ---------------------------------
LAUNCH_NS_PER_NODE = 6_000          # one async-copy launch (no graphs)
GRAPH_LAUNCH_BASE_NS = 7_000        # cudaGraphLaunch fixed cost analogue
GRAPH_LAUNCH_PER_NODE_NS = 300      # marginal per-node launch cost in a graph
GRAPH_INSTANTIATE_BASE_NS = 90_000  # one-time instantiation (first iter)
GRAPH_INSTANTIATE_PER_NODE_NS = 85_000
SYNC_NS_PER_PATH = 2_000            # event record + stream-wait per path
COMPUTE_GFLOPS = 50.0               # declared-FLOP pricing rate for
                                    # ComputeNodes without a measured cost
INTER_NODE_LATENCY_NS = 1_500       # per-chunk hop latency on inter-node
                                    # links (RDMA/DCN tier, DESIGN §3.1)


def compute_time_s(node, topo: "Topology | None" = None) -> float:
    """Modeled seconds for one :class:`~repro.comm.graph.ComputeNode`.

    Pricing precedence (DESIGN §4.4d): a *fitted* per-kernel term from
    the topology's live calibration profile wins (keyed by the node's
    ``kernel`` name — measured execute aggregation, see
    ``TimelineRecorder.record_kernel``), then a stamped ``cost_ns``,
    then declared ``flops`` at the nominal :data:`COMPUTE_GFLOPS` rate.
    Shared by the critical-path weights, the lane simulation, and the
    scheduled-DAG arbiter so ``auto`` stays honest about compute.
    """
    prof = getattr(topo, "calibration", None)
    fitted = getattr(prof, "kernel_cost_ns", None)
    if fitted:
        ns = fitted.get(node.kernel)
        if ns:
            return ns / 1e9
    if node.cost_ns:
        return node.cost_ns / 1e9
    return node.flops / (COMPUTE_GFLOPS * 1e9)


@dataclasses.dataclass(frozen=True)
class LaunchModel:
    """The §4.4 launch-overhead terms as one swappable value.

    Defaults are the module's nominal constants; a fitted instance comes
    from :class:`repro.comm.calibration.CalibrationProfile` and reaches
    every estimator through :func:`launch_model_for` (DESIGN §4.4c) —
    the model never reads the bare constants once a profile is live.
    """

    launch_ns_per_node: float = LAUNCH_NS_PER_NODE
    graph_launch_base_ns: float = GRAPH_LAUNCH_BASE_NS
    graph_launch_per_node_ns: float = GRAPH_LAUNCH_PER_NODE_NS
    graph_instantiate_base_ns: float = GRAPH_INSTANTIATE_BASE_NS
    graph_instantiate_per_node_ns: float = GRAPH_INSTANTIATE_PER_NODE_NS
    sync_ns_per_path: float = SYNC_NS_PER_PATH


#: The nominal (uncalibrated) launch model — exactly the constants above.
DEFAULT_LAUNCH_MODEL = LaunchModel()


def launch_model_for(topo: Topology | None) -> LaunchModel:
    """Resolve the launch model in force for ``topo``.

    Returns the fitted :class:`LaunchModel` of the topology's live
    calibration profile when one is attached (and carries launch terms),
    else :data:`DEFAULT_LAUNCH_MODEL`. Accepts ``None`` so legacy
    call sites that never knew about calibration keep their exact
    constant-based behaviour.
    """
    prof = getattr(topo, "calibration", None)
    fitted = getattr(prof, "launch", None)
    return fitted if fitted is not None else DEFAULT_LAUNCH_MODEL


def _calibrated_bw(bw: dict[tuple[int, int], float],
                   topo: Topology | None) -> dict[tuple[int, int], float]:
    """Overlay fitted per-link bandwidths onto a plan-embedded map.

    Plans embed the nominal ``Link`` objects that existed when they were
    planned; when ``topo`` carries a live calibration profile the model
    must price measured bandwidths instead, so each entry is re-read
    through :meth:`Topology.link` (which serves the calibrated shadow).
    No-op without a profile.
    """
    if getattr(topo, "calibration", None) is None:
        return bw
    out = dict(bw)
    for key in out:
        link = topo.link(*key)
        if link is not None:
            out[key] = link.bandwidth_gbps
    return out


def _lower(obj, window: int = 1) -> "TransferGraph":
    # Local import: repro.core must stay importable without repro.comm
    # (the comm package itself imports core.topology).
    from repro.comm.graph import lower
    return lower(obj, window)


def _as_group(group: "TransferGroup | Sequence[TransferPlan]"
              ) -> "TransferGroup":
    from repro.comm.plan import TransferGroup
    if isinstance(group, TransferGroup):
        return group
    plans = tuple(group)
    name = plans[0].topology_name if plans else ""
    return TransferGroup(plans, name)


def build_schedule(plan: TransferPlan) -> list[ChunkTask]:
    """Flatten the plan's transfer graph into chunk tasks, round-robin
    across paths.

    The paper distributes chunks across paths one-by-one (Alg. 1 note); the
    round-robin order is the dispatch order — data dependencies (hop order
    within a chunk, §4.5) are carried in each task's ``hops``, which is the
    chunk's copy-node chain from the graph.
    """
    graph = _lower(plan)
    chains: dict[tuple[int, int], list] = {}
    for node in graph.nodes:
        chains.setdefault((node.path_idx, node.chunk_idx), []).append(node)
    per_path: dict[int, list[ChunkTask]] = defaultdict(list)
    for (p_idx, c_idx) in sorted(chains):
        nodes = sorted(chains[(p_idx, c_idx)], key=lambda n: n.hop_idx)
        per_path[p_idx].append(ChunkTask(
            p_idx, c_idx, nodes[0].offset, nodes[0].nbytes,
            tuple(n.link for n in nodes)))
    schedule: list[ChunkTask] = []
    paths = [per_path[p] for p in sorted(per_path)]
    for wave in range(max((len(t) for t in paths), default=0)):
        for tasks in paths:
            if wave < len(tasks):
                schedule.append(tasks[wave])
    return schedule


def validate_plan(plan: TransferPlan) -> None:
    """Assert the §4.5 integrity invariants. Raises ``ValueError`` on breach.

    Checked on the plan's transfer graph (:meth:`TransferGraph.validate`):

    1. chunk byte ranges are disjoint and exactly cover ``[0, nbytes)``,
    2. no two paths share a directional link (contention avoidance),
    3. every staged route's hops are connected (src → via → dst).
    """
    _lower(plan).validate({0: plan.nbytes})


def validate_group(group: "TransferGroup | Sequence[TransferPlan]") -> None:
    """Assert the group-level §4.5 invariants. Raises ``ValueError``.

    Checked on the fused group's transfer graph:

    1. every message individually satisfies :func:`validate_plan`
       (disjoint cover of its own message, within-plan link exclusivity),
    2. **cross-flow link exclusivity** — no directional link is used by
       plans of two *distinct* flows (src, dst). Plans of the same flow
       (e.g. the leaves of one pytree migration) legitimately share that
       flow's routes and are exempt.
    """
    g = _as_group(group)
    _lower(g).validate({i: p.nbytes for i, p in enumerate(g.plans)})


def _launch_overhead_from_counts(num_nodes: int, num_paths: int, *,
                                 compiled_plan: bool,
                                 first_iteration: bool = False,
                                 launch: LaunchModel = DEFAULT_LAUNCH_MODEL
                                 ) -> float:
    if not compiled_plan:
        return (num_nodes * launch.launch_ns_per_node
                + num_paths * launch.sync_ns_per_path)
    cost = (launch.graph_launch_base_ns
            + num_nodes * launch.graph_launch_per_node_ns)
    if first_iteration:
        cost += (launch.graph_instantiate_base_ns
                 + num_nodes * launch.graph_instantiate_per_node_ns)
    return float(cost)


def launch_overhead_ns(plan: TransferPlan, *, compiled_plan: bool,
                       first_iteration: bool = False,
                       topo: Topology | None = None) -> float:
    """CPU-side overhead for dispatching the plan once (paper §5.5):
    per-node launch cost × graph node count. Pass ``topo`` to price the
    fitted :class:`LaunchModel` of its live calibration profile."""
    return _launch_overhead_from_counts(
        _lower(plan).num_nodes, len(plan.paths),
        compiled_plan=compiled_plan, first_iteration=first_iteration,
        launch=launch_model_for(topo))


def group_launch_overhead_ns(plans: Sequence[TransferPlan], *,
                             compiled_plan: bool,
                             first_iteration: bool = False,
                             fused: bool = True,
                             topo: Topology | None = None) -> float:
    """CPU-side overhead for a transfer group.

    ``fused=True`` models the group as ONE graph launch (the fused SPMD
    program the engine compiles): a single base launch cost amortized over
    the fused graph's node count, and one instantiation on the first
    iteration. ``fused=False`` models the legacy dispatch loop — one
    launch (and one first-iteration instantiation) per message. ``topo``
    selects the fitted launch model as in :func:`launch_overhead_ns`.
    """
    if fused:
        return _launch_overhead_from_counts(
            _lower(_as_group(plans)).num_nodes,
            sum(len(p.paths) for p in plans),
            compiled_plan=compiled_plan, first_iteration=first_iteration,
            launch=launch_model_for(topo))
    return sum(launch_overhead_ns(p, compiled_plan=compiled_plan,
                                  first_iteration=first_iteration, topo=topo)
               for p in plans)


# -- critical-path evaluation over the transfer graph ------------------------

def _contention(plans: Sequence[TransferPlan]
                ) -> tuple[dict[tuple[int, int], int], int]:
    """Directional-link use counts + host-staged flow count across plans."""
    counts: dict[tuple[int, int], int] = defaultdict(int)
    host_flows = 0
    for p in plans:
        for pa in p.paths:
            for link in pa.route.directional_links():
                counts[link] += 1
            if pa.route.via == HOST:
                host_flows += 1
    return counts, host_flows


def _bandwidth_map(plans: Sequence[TransferPlan]
                   ) -> dict[tuple[int, int], float]:
    """Directional link → GB/s, from the links embedded in the plans."""
    bw: dict[tuple[int, int], float] = {}
    for p in plans:
        for pa in p.paths:
            for link in pa.route.hops:
                bw[(link.src, link.dst)] = link.bandwidth_gbps
    return bw


def _inter_latency_s(topo: Topology | None
                     ) -> "dict[tuple[int, int], float]":
    """Per-link latency surcharge for the inter-node tier (DESIGN §3.1).

    Flat topologies (one island) get an empty map — the §4.4 model is
    then bitwise-identical to the pre-hierarchy model. On hierarchical
    topologies every inter-island directional link costs an extra
    :data:`INTER_NODE_LATENCY_NS` per chunk hop, so the tuner/arbiter
    naturally prefer fewer, larger chunks across node boundaries.
    """
    if topo is None or getattr(topo, "num_islands", 1) <= 1:
        return {}
    lat = INTER_NODE_LATENCY_NS / 1e9
    return {key: lat for key in topo.links
            if topo.is_inter_island(*key)}


def _graph_message_times_s(graph: "TransferGraph",
                           bw_gbps: dict[tuple[int, int], float],
                           contention: dict[tuple[int, int], int],
                           host_flows: int,
                           latency_s: "dict[tuple[int, int], float] | None"
                           = None) -> dict[int, float]:
    """Per-message critical-path wire time over the copy-node DAG.

    The relevant DAG per (message, path) is the chunks × hops grid: hop
    edges within each chunk plus the per-link serialization edges between
    consecutive chunks (:meth:`TransferGraph.serialization_edges`). Its
    longest weighted path runs along the bottleneck link, which for the
    uniform steady-state chunk weight the model prices reduces to the
    closed form ``fill + (n_chunks − 1) · max(hop_times)`` — evaluated
    here per path directly from the graph's nodes/edges structure.

    Node weights: steady-state chunk bytes over the link's contended
    bandwidth. A directional link shared by several concurrent paths is
    time-shared; flows staging through the host additionally split the
    host's aggregate copy bandwidth (paper §5.3 obs. 6). ``latency_s``
    (from :func:`_inter_latency_s`) adds a per-chunk-hop surcharge on
    inter-node links — the tier-aware term of the hierarchical model.
    """
    # per (msg, path): hop link sequence + chunk count + total bytes,
    # read off window-0 nodes (windows replay the identical round).
    hops: dict[tuple[int, int], dict[int, tuple[int, int]]] = {}
    totals: dict[tuple[int, int], int] = defaultdict(int)
    chunks: dict[tuple[int, int], int] = defaultdict(int)
    for node in graph.nodes:
        if hasattr(node, "kernel"):   # ComputeNode: no wire time
            continue
        if node.window:
            continue
        key = (node.msg_idx, node.path_idx)
        hops.setdefault(key, {})[node.hop_idx] = node.link
        if node.hop_idx == 0:
            totals[key] += node.nbytes
            chunks[key] += 1
    times: dict[int, float] = {m: 0.0 for m in range(graph.num_messages)}
    latency_s = latency_s or {}
    for key, link_by_hop in hops.items():
        n = max(1, chunks[key])
        chunk_bytes = totals[key] / n
        hop_times = []
        for h in sorted(link_by_hop):
            link = link_by_hop[h]
            bw = bw_gbps[link] * 1e9
            share = max(1, contention.get(link, 1))
            if HOST in link and host_flows > 1:
                share = max(share, host_flows)
            hop_times.append(chunk_bytes / (bw / share)
                             + latency_s.get(link, 0.0))
        fill = sum(hop_times)                 # first chunk: all hop edges
        steady = (n - 1) * max(hop_times)     # serialization on bottleneck
        times[key[0]] = max(times[key[0]], fill + steady)
    return times


def wire_time_s(plan: TransferPlan, topo: Topology, *,
                concurrent_plans: Sequence[TransferPlan] = ()) -> float:
    """Pure wire time (no launch overhead) for one message: the critical
    path of its transfer graph.

    ``concurrent_plans`` are other transfers in flight at the same time
    (e.g. the reverse direction of a bidirectional test, or the other
    messages of a transfer group): any directional link they share with
    ``plan`` is time-shared, and host-staged flows contend on host
    capacity.
    """
    all_plans = (plan, *concurrent_plans)
    contention, host_flows = _contention(all_plans)
    times = _graph_message_times_s(
        _lower(plan), _calibrated_bw(_bandwidth_map(all_plans), topo),
        contention, host_flows, _inter_latency_s(topo))
    return times[0]


def estimate_transfer_time_s(
        plan: TransferPlan, topo: Topology, *,
        compiled_plan: bool = True,
        first_iteration: bool = False,
        concurrent_plans: Sequence[TransferPlan] = ()) -> float:
    """Analytic end-to-end time for one message under the pipeline model.

    See :func:`wire_time_s` for the ``concurrent_plans`` contention
    semantics; launch overhead is added per §5.5.
    """
    return wire_time_s(plan, topo, concurrent_plans=concurrent_plans) + (
        launch_overhead_ns(plan, compiled_plan=compiled_plan,
                           first_iteration=first_iteration, topo=topo) / 1e9)


def estimate_group_time_s(
        group: "TransferGroup | Sequence[TransferPlan]", topo: Topology, *,
        compiled_plan: bool = True,
        first_iteration: bool = False,
        fused: bool = True) -> float:
    """Analytic makespan of a set of concurrent transfers: critical-path
    evaluation over the fused group's transfer graph.

    ``fused=True`` is the transfer-group execution model: one compiled
    launch covering every message, so the makespan is a single (fused)
    launch overhead plus the DAG's critical path — the slowest message's
    wire time, each message priced with every other group member as
    concurrent traffic.

    ``fused=False`` is the legacy dispatch loop (one compiled program per
    message, launched back-to-back without blocking): the CPU serializes
    the launches, so message *i* cannot start before launches ``1..i``
    have issued, while the wires still contend. This is the baseline
    `exchange()` is measured against.
    """
    g = _as_group(group)
    plans = g.plans
    if not plans:
        return 0.0
    contention, host_flows = _contention(plans)
    times = _graph_message_times_s(
        _lower(g), _calibrated_bw(_bandwidth_map(plans), topo),
        contention, host_flows, _inter_latency_s(topo))
    wires = [times[i] for i in range(len(plans))]
    if fused:
        return max(wires) + group_launch_overhead_ns(
            plans, compiled_plan=compiled_plan,
            first_iteration=first_iteration, fused=True, topo=topo) / 1e9
    makespan, dispatched = 0.0, 0.0
    for plan, wire in zip(plans, wires):
        dispatched += launch_overhead_ns(
            plan, compiled_plan=compiled_plan,
            first_iteration=first_iteration, topo=topo) / 1e9
        makespan = max(makespan, dispatched + wire)
    return makespan


def graph_node_weights_s(graph: "TransferGraph", topo: Topology
                         ) -> list[float]:
    """Per-node copy time in seconds: actual chunk bytes over the link's
    contended bandwidth — THE §4.4 node-weight model.

    Contention is derived from the graph itself: one share per (message,
    path) using a directional link, host capacity split across
    host-staged paths — the same counting :func:`_contention` derives
    from plans. Shared by :func:`scheduled_time_s` (the arbiter) and the
    ``critical_path`` scheduler in :mod:`repro.comm.passes`, so the
    greedy pass optimizes exactly the objective the ``auto`` scorer
    rates it on. Raises ``ValueError`` when a graph link is absent from
    ``topo`` (the graph and topology must agree). Heterogeneous graphs:
    compute nodes are priced by :func:`compute_time_s` (measured
    ``cost_ns`` or declared FLOPs) and use no link.
    """
    paths_on: dict[tuple[int, int], set] = defaultdict(set)
    host_paths: set = set()
    for node in graph.nodes:
        if hasattr(node, "kernel"):   # ComputeNode: uses no link
            continue
        paths_on[node.link].add((node.msg_idx, node.path_idx))
        if HOST in node.link:
            host_paths.add((node.msg_idx, node.path_idx))
    latency_s = _inter_latency_s(topo)
    weight = []
    for node in graph.nodes:
        if hasattr(node, "kernel"):
            weight.append(compute_time_s(node, topo))
            continue
        link = topo.link(*node.link)
        if link is None:
            raise ValueError(f"graph link {node.link} not in topology "
                             f"{topo.name}")
        share = max(1, len(paths_on[node.link]))
        if HOST in node.link and len(host_paths) > 1:
            share = max(share, len(host_paths))
        weight.append(node.nbytes / (link.bandwidth_gbps * 1e9 / share)
                      + latency_s.get(node.link, 0.0))
    return weight


def _graph_base_s(graph: "TransferGraph", launch: LaunchModel, *,
                  compiled_plan: bool, first_iteration: bool) -> float:
    """Fixed per-dispatch cost shared by both scheduling models."""
    n = graph.num_nodes
    if compiled_plan:
        base = launch.graph_launch_base_ns
        if first_iteration:
            base += (launch.graph_instantiate_base_ns
                     + n * launch.graph_instantiate_per_node_ns)
    else:
        num_paths = len({(nd.msg_idx, nd.path_idx) for nd in graph.nodes
                         if not hasattr(nd, "kernel")})
        base = num_paths * launch.sync_ns_per_path
    return base / 1e9


def _lane_of(node) -> tuple:
    """Resource lane a node occupies: its directional link for a copy
    (link-exclusive transfer engine), the shared SPMD compute lane for a
    kernel (every device's compute lane advances in lockstep)."""
    if hasattr(node, "kernel"):
        return ("compute",)
    return ("link",) + tuple(node.link)


def lane_intervals_s(graph: "TransferGraph", topo: Topology, *,
                     compiled_plan: bool = True
                     ) -> list[tuple[float, float]]:
    """Per-node ``(start, finish)`` seconds under the resource-lane
    simulation (no fixed base cost included).

    The lane model: each (src, dst) directional link is an exclusive
    transfer lane, all compute shares one SPMD compute lane, a node
    occupies its lane for its §4.4-priced duration plus the per-node
    launch cost, lanes drain in dispatch (node-index) order — CUDA-
    stream-style head-of-line FIFO, which is what makes *order* matter
    to a reorder-only pass — and stored hop/window/buffer edges gate
    start times. Makespan replaces the serialized issue chain.
    """
    n = graph.num_nodes
    weight = graph_node_weights_s(graph, topo)
    launch = launch_model_for(topo)
    per_node_s = (launch.graph_launch_per_node_ns if compiled_plan
                  else launch.launch_ns_per_node) / 1e9
    preds: dict[int, list[int]] = defaultdict(list)
    for e in graph.edges:
        preds[e.dst].append(e.src)
    lane_free: dict[tuple, float] = defaultdict(float)
    out: list[tuple[float, float]] = [(0.0, 0.0)] * n
    for idx in range(n):          # dispatch order IS lane-enqueue order
        lane = _lane_of(graph.nodes[idx])
        start = lane_free[lane]
        for p in preds[idx]:
            start = max(start, out[p][1])
        finish = start + weight[idx] + per_node_s
        lane_free[lane] = finish
        out[idx] = (start, finish)
    return out


def scheduled_time_s(graph: "TransferGraph", topo: Topology, *,
                     compiled_plan: bool = True,
                     first_iteration: bool = False,
                     mode: str | None = None) -> float:
    """Modeled end-to-end time of a *scheduled* transfer graph (§2.2).

    Unlike the closed-form :func:`wire_time_s` (which is schedule-blind —
    it reduces the DAG to per-path chunk counts), this is an exact
    evaluation over the scheduled DAG, which is how a chunk-interleaving
    pass becomes visible to the model. Two objectives share the entry
    point, selected by ``mode``:

    * ``"serialized"`` — the degenerate single-lane model (the historic
      objective): stored hop + window edges, the derived per-slot
      serialization edges, and a global issue chain (node *i* cannot
      start before ``i × per-node launch cost``). Pure-comm digests and
      arbitration are scored exactly as before.
    * ``"lanes"`` — the resource-lane makespan (:func:`lane_intervals_s`):
      link-exclusive transfer lanes plus one SPMD compute lane, per-node
      launch cost charged to the executing lane instead of a global
      chain, so copies on independent links make concurrent progress and
      can *hide* behind compute.
    * ``None`` (default) — dispatch on graph content: heterogeneous
      graphs (any ComputeNode) are priced by lanes, pure-comm graphs by
      the serialized chain. The default therefore *reduces* to the
      serialized chain on every pure-comm graph — numerically identical
      scores, digest-stable arbitration — which is the invariant the
      PR 5/6 acceptance gates rely on. (Explicit ``mode="lanes"`` on a
      single-path pure-comm chain differs from serialized by exactly
      ``num_nodes × per-node launch``: the lane model charges issue
      cost into lane occupancy rather than a global chain.)

    Used by the ``auto`` scheduler and ``session.describe`` to score
    candidate dispatch orders of the SAME lowering against each other;
    absolute values are comparable to :func:`estimate_transfer_time_s`
    but not identical (that closed form prices uniform chunk sizes).
    """
    n = graph.num_nodes
    if n == 0:
        return 0.0
    if mode is None:
        mode = "lanes" if graph.num_compute_nodes else "serialized"
    if mode not in ("serialized", "lanes"):
        raise ValueError(f"unknown scheduling model {mode!r}; expected "
                         "'serialized', 'lanes', or None")
    launch = launch_model_for(topo)
    base = _graph_base_s(graph, launch, compiled_plan=compiled_plan,
                         first_iteration=first_iteration)
    if mode == "lanes":
        intervals = lane_intervals_s(graph, topo,
                                     compiled_plan=compiled_plan)
        return max(f for _, f in intervals) + base
    weight = graph_node_weights_s(graph, topo)
    preds: dict[int, list[int]] = defaultdict(list)
    for e in graph.edges:
        preds[e.dst].append(e.src)
    for a, b in graph.serialization_edges():
        preds[b].append(a)
    per_node_ns = (launch.graph_launch_per_node_ns if compiled_plan
                   else launch.launch_ns_per_node)
    finish = [0.0] * n
    for idx in graph.topological_order():
        start = idx * per_node_ns / 1e9          # serialized issue chain
        for p in preds[idx]:
            start = max(start, finish[p])
        finish[idx] = start + weight[idx]
    return max(finish) + base


def hidden_copy_time_s(graph: "TransferGraph", topo: Topology, *,
                       compiled_plan: bool = True) -> float:
    """Modeled copy seconds that run *behind* compute on the lane
    timeline: Σ over copy nodes of the overlap between the copy's
    ``(start, finish)`` interval and the union of compute-lane busy
    intervals (:func:`lane_intervals_s`). Zero on pure-comm graphs.

    This is the quantity the ``overlap`` scheduler exists to maximize
    and what ``session.describe()["overlap"]`` reports.
    """
    if not graph.num_compute_nodes or not graph.num_copy_nodes:
        return 0.0
    intervals = lane_intervals_s(graph, topo, compiled_plan=compiled_plan)
    busy = sorted(iv for iv, nd in zip(intervals, graph.nodes)
                  if hasattr(nd, "kernel"))
    merged: list[list[float]] = []
    for s, f in busy:
        if merged and s <= merged[-1][1]:
            merged[-1][1] = max(merged[-1][1], f)
        else:
            merged.append([s, f])
    hidden = 0.0
    for (s, f), nd in zip(intervals, graph.nodes):
        if hasattr(nd, "kernel"):
            continue
        for bs, bf in merged:
            hidden += max(0.0, min(f, bf) - max(s, bs))
    return hidden


def effective_bandwidth_gbps(plan: TransferPlan, topo: Topology, *,
                             compiled_plan: bool = True,
                             concurrent_plans: Sequence[TransferPlan] = (),
                             ) -> float:
    t = estimate_transfer_time_s(plan, topo, compiled_plan=compiled_plan,
                                 concurrent_plans=concurrent_plans)
    return plan.nbytes / t / 1e9


def windowed_bandwidth_gbps(plan: TransferPlan, topo: Topology, *,
                            window: int, compiled_plan: bool = True) -> float:
    """OMB-style windowed bandwidth: ``window`` back-to-back messages.

    Launch overheads of messages 2..W overlap the wire time of earlier
    messages (the paper's window-size effect, §5.3 obs. 3): with compiled
    plans the CPU can run ahead, so per-message cost approaches pure wire
    time; without, per-node launches serialize on the CPU.
    """
    wire = wire_time_s(plan, topo)
    launch = launch_overhead_ns(plan, compiled_plan=compiled_plan,
                                topo=topo) / 1e9
    # CPU dispatch pipeline: total = first launch + max(wire, launch)*(W-1)
    # + wire of the last message's tail.
    total = launch + window * wire if launch <= wire else (
        window * launch + wire)
    return plan.nbytes * window / total / 1e9
