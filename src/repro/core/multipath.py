"""DEPRECATED shim — the executable engine moved to :mod:`repro.comm.engine`.

Construct a :class:`repro.comm.CommSession` and use ``session.send`` /
``session.bidirectional`` / ``session.compiled_for`` instead of building a
``MultiPathTransfer`` directly (DESIGN.md §6 migration guide).
"""

import warnings

from repro.comm.engine import (  # noqa: F401
    AXIS, MultiPathTransfer, _check_executable,
    multipath_send_local, plan_signature)


def __getattr__(name):  # legacy TransferKey lives on repro.core only now
    if name == "TransferKey":
        import repro.core
        return repro.core.TransferKey
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

warnings.warn(
    "repro.core.multipath is deprecated; use repro.comm (CommSession, "
    "MultiPathTransfer)", DeprecationWarning, stacklevel=2)
