"""DEPRECATED shim — collectives moved to :mod:`repro.comm.collectives`.

Use ``session.all_gather/reduce_scatter/all_reduce/all_to_all/psum`` for
driver-level launches that share the session's plan cache, or
``session.collectives.*`` inside ``shard_map`` programs (DESIGN.md §6).
"""

import warnings

from repro.comm.collectives import (  # noqa: F401
    bidir_ring_all_gather, bidir_ring_reduce_scatter, multipath_all_reduce,
    multipath_all_to_all, psum_via_multipath)

warnings.warn(
    "repro.core.collectives is deprecated; use repro.comm.collectives or "
    "CommSession collectives", DeprecationWarning, stacklevel=2)
