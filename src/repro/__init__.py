"""repro — multi-path accelerator transfer framework (JAX/TPU).

Reproduction + TPU adaptation of "Accelerating Intra-Node GPU-to-GPU
Communication Through Multi-Path Transfers with CUDA Graphs" (CS.DC 2026).
See DESIGN.md for the system map.
"""

__version__ = "1.0.0"
