"""Batched serving engine: continuous prefill → decode with sharded caches.

``make_serve_step`` builds the jit-able single-token step the dry-run lowers
for ``decode_32k`` / ``long_500k``; ``ServeEngine`` is the runnable engine
used by the examples — batched requests, prefill-into-cache, greedy/top-k
sampling, per-request completion tracking.

Communication goes through an optional :class:`repro.comm.CommSession`:
``ServeEngine.migrate_kv`` moves a populated KV cache between devices over
the session's compiled multi-path plans (the prefill→decode disaggregation
primitive). All leaves are fused into ONE transfer group — one compiled
program and one launch per migration, regardless of leaf count.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from repro.training import sharding as shd

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.comm.session import CommSession


def pick_kv_chunks(cfg: ArchConfig, mesh: Mesh, batch: int,
                   max_len: int) -> int:
    """Chunk count for the split-KV decode cache: the model axis when the
    batch carries the DP axes, every mesh axis when batch is unshardable
    (long-context batch=1)."""
    model = mesh.shape.get("model", 1)
    dp = shd.axis_size(mesh, shd.dp_axes(mesh))
    chunks = model if (batch % dp == 0 and batch > 1) else model * dp
    while chunks > 1 and max_len % chunks:
        chunks //= 2
    return max(1, chunks)


def make_serve_step(cfg: ArchConfig, spec: tfm.CacheSpec) -> Callable:
    """serve_step(params, cache, tokens (B,1), cur_len) → (logits, cache)."""
    def serve_step(params, cache, tokens, cur_len):
        return tfm.decode_step(params, cfg, cache, tokens, cur_len, spec)
    return serve_step


def make_captured_decode_step(comm: "CommSession", *, batch: int,
                              heads: int, kv_len: int, head_dim: int,
                              kv_chunk: int, src: int, dst: int,
                              dtype=jnp.float32,
                              schedule: str | None = None,
                              max_paths: int | None = None,
                              num_chunks: int | None = None) -> Callable:
    """Capture one decode step that migrates a KV chunk *behind* the
    attention kernel — the flagship overlap adopter (mirrors
    :func:`repro.training.train_step.make_captured_dp_train_step`).

    ONE heterogeneous graph per call: a flash-attention compute node on
    the local ``(batch, heads, kv_len, head_dim)`` q/k/v shards, and —
    on an *independent* dataflow path — a ``kv_chunk``-element KV
    migration ``src → dst`` (stage kernel → multipath exchange →
    install kernel), so the lane model can run the migration copies
    concurrently with attention and the ``overlap`` scheduler has real
    copy time to hide. The attention node's ``cost_ns`` is stamped from
    the session's telemetry recorder when it holds measurements for
    ``"flash_attention"`` (see
    :meth:`~repro.comm.telemetry.TimelineRecorder.record_kernel`).

    Returns ``step(q, k, v, kv) -> (attn, new_kv)`` over
    ``(num_devices, *local)`` arrays; every call is ONE engine dispatch.
    ``new_kv`` equals ``kv`` everywhere except device ``dst``, which
    receives device ``src``'s chunk.
    """
    from jax import lax

    from repro.comm.capture import BufferSpec
    from repro.kernels.flash_attention.ops import captured_flash_attention

    ax = comm.axis_name
    n = comm.engine.num_devices
    if not 0 <= src < n or not 0 <= dst < n or src == dst:
        raise ValueError(f"need distinct src/dst in [0, {n}), got "
                         f"{src}/{dst}")

    def build(cap):
        q = cap.input((batch, heads, kv_len, head_dim), dtype)
        k = cap.input((batch, heads, kv_len, head_dim), dtype)
        v = cap.input((batch, heads, kv_len, head_dim), dtype)
        kv = cap.input((kv_chunk,), dtype)
        attn = captured_flash_attention(cap, q, k, v,
                                        telemetry=comm.telemetry)
        staged = cap.kernel(lambda c: c * jnp.ones((), c.dtype), kv,
                            name="kv_stage", flops=kv_chunk)
        (moved,) = cap.exchange([(staged, src, dst)], max_paths=max_paths,
                                num_chunks=num_chunks)

        def install(cur, mig):
            i = lax.axis_index(ax)
            return jnp.where(i == dst, mig, cur)

        new_kv = cap.kernel(install, kv, moved, name="kv_install",
                            out=BufferSpec((kv_chunk,),
                                           str(jnp.dtype(dtype))),
                            flops=kv_chunk)
        return attn, new_kv

    return comm.capture(build, schedule=schedule)


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Minimal batched engine: pads a request batch to a common prompt
    length, prefills once, decodes greedily until every request finishes."""

    def __init__(self, cfg: ArchConfig, params, *, max_len: int = 256,
                 kv_chunks: int = 4, temperature: float = 0.0,
                 comm: "CommSession | None" = None):
        self.cfg = cfg
        self.params = params
        self.spec = tfm.cache_spec(cfg, max_len=max_len,
                                   kv_chunks=kv_chunks)
        self.temperature = temperature
        self.comm = comm
        #: Comm-health events (DESIGN §4.6) drained from the session
        #: after each migration / generation — link faults, retries,
        #: quarantines, re-admissions that happened under serving
        #: traffic. Decode keeps serving through a mid-traffic link
        #: failure (the session re-plans on surviving routes); this log
        #: is how the serving layer surfaces that it happened.
        self.health_events: list[dict] = []
        self._decode = jax.jit(make_serve_step(cfg, self.spec))
        self._prefill = jax.jit(
            lambda p, b: tfm.prefill_forward(p, cfg, b, self.spec))

    def _drain_health(self) -> None:
        """Fold the comm session's pending health events into
        :attr:`health_events`. Draining clears the session-side log but
        preserves its windowed counters (``stats()['health']``)."""
        if self.comm is not None:
            self.health_events.extend(self.comm.drain_health_events())

    def prefill(self, tokens: jax.Array):
        """Run the prefill forward pass: ``(B, S) int32`` prompt tokens →
        ``(logits, cache)``. The cache is what :meth:`migrate_kv` moves."""
        return self._prefill(self.params, {"tokens": jnp.asarray(tokens,
                                                                 jnp.int32)})

    def migrate_kv(self, cache, src: int, dst: int):
        """Move a KV cache from device ``src`` to ``dst`` through the comm
        session's multi-path engine (prefill→decode disaggregation).

        All leaves ride ONE fused transfer group: a single compiled
        program (one plan-cache entry keyed on every leaf's plan) and a
        single dispatch per migration — steady-state migration of a
        same-shaped cache is one cache hit and one launch; check
        ``self.comm.stats()``. Empty caches and ``src == dst`` no-op.
        """
        if self.comm is None:
            raise ValueError("ServeEngine was built without a CommSession; "
                             "pass comm= to enable KV migration")
        out = self.comm.send_pytree(cache, src, dst)
        self._drain_health()
        return out

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature).astype(jnp.int32)

    def generate(self, requests: Sequence[Request],
                 seed: int = 0) -> list[Request]:
        reqs = list(requests)
        plen = max(len(r.prompt) for r in reqs)
        toks = jnp.asarray(
            [([0] * (plen - len(r.prompt))) + r.prompt for r in reqs],
            jnp.int32)
        logits, cache = self.prefill(toks)
        key = jax.random.key(seed)
        cur = jnp.asarray(plen - 1, jnp.int32)
        next_tok = self._sample(logits[:, -1], key)
        max_new = max(r.max_new_tokens for r in reqs)
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if not r.done and step < r.max_new_tokens:
                    r.out.append(int(next_tok[i]))
                    if step + 1 >= r.max_new_tokens:
                        r.done = True
            if all(r.done for r in reqs):
                break
            cur = cur + 1
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache,
                                         next_tok[:, None], cur)
            next_tok = self._sample(logits, sub)
        self._drain_health()
        return reqs
