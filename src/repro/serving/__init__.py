from repro.serving.engine import (  # noqa: F401
    Request, ServeEngine, make_serve_step, pick_kv_chunks)
