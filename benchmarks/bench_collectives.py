"""Beyond-paper (paper §6 future work): multipath-striped collectives.

Compares the bidirectional-ring all-gather/reduce-scatter against the
single-direction baseline: wall-clock on the host mesh plus the structural
metric that matters on the torus — bytes crossing the busiest directional
link per step (halved by striping)."""

from benchmarks.common import MiB, Row, timeit_us

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.collectives import (bidir_ring_all_gather,
                                    bidir_ring_reduce_scatter)


def _uni_ring_all_gather(x, axis_name):
    n = jax.lax.axis_size(axis_name)
    i = jax.lax.axis_index(axis_name)
    cw = [(j, (j + 1) % n) for j in range(n)]
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, x[None], i, axis=0)
    cur = x
    for step in range(1, n):
        cur = jax.lax.ppermute(cur, axis_name, cw)
        out = jax.lax.dynamic_update_slice(
            out, cur[None], (jnp.mod(i - step, n),) + (0,) * x.ndim)
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def run() -> list[Row]:
    mesh = jax.sharding.Mesh(jax.devices(), ("dev",))
    n = 8
    rows = []
    for mb in (1, 8):
        nelems = mb * MiB // 4 // n
        x = jnp.asarray(np.random.RandomState(0).randn(n * 8, nelems // 8),
                        jnp.float32)

        def run_ag(fn):
            return jax.jit(jax.shard_map(
                lambda v: fn(v, "dev"), mesh=mesh, in_specs=P("dev"),
                out_specs=P(None), check_vma=False))

        uni = run_ag(_uni_ring_all_gather)
        bi = run_ag(bidir_ring_all_gather)
        us_uni = timeit_us(uni, x)
        us_bi = timeit_us(bi, x)
        rows.append(Row(f"allgather/{mb}MiB/uni_ring", us_uni,
                        "1link/step"))
        rows.append(Row(f"allgather/{mb}MiB/bidir_ring", us_bi,
                        "2links/step"))
        # structural: per-step busiest-link bytes halve with striping
        shard_bytes = x.nbytes // n
        rows.append(Row(
            f"allgather/{mb}MiB/busiest_link_bytes_per_step", 0.0,
            f"uni={shard_bytes}B,bidir={shard_bytes // 2}B"))

        rs = jax.jit(jax.shard_map(
            lambda v: bidir_ring_reduce_scatter(v, "dev"), mesh=mesh,
            in_specs=P(None), out_specs=P("dev"), check_vma=False))
        xr = jnp.asarray(np.random.RandomState(1).randn(n * 8, nelems // 8),
                         jnp.float32)
        rows.append(Row(f"reducescatter/{mb}MiB/bidir_ring",
                        timeit_us(rs, xr), "2links/step"))
    return rows
