"""Beyond-paper (paper §6 future work): multipath-striped collectives.

Compares the bidirectional-ring all-gather/reduce-scatter against the
single-direction baseline: wall-clock on the host mesh plus the structural
metric that matters on the torus — bytes crossing the busiest directional
link per step (halved by striping)."""

from benchmarks.common import MiB, Row, timeit_us

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import CommSession
from repro.compat import axis_size, shard_map


def _uni_ring_all_gather(x, axis_name):
    n = axis_size(axis_name)
    i = jax.lax.axis_index(axis_name)
    cw = [(j, (j + 1) % n) for j in range(n)]
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, x[None], i, axis=0)
    cur = x
    for step in range(1, n):
        cur = jax.lax.ppermute(cur, axis_name, cw)
        out = jax.lax.dynamic_update_slice(
            out, cur[None], (jnp.mod(i - step, n),) + (0,) * x.ndim)
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def run() -> list[Row]:
    mesh = jax.sharding.Mesh(jax.devices(), ("dev",))
    sess = CommSession(mesh=mesh)
    n = 8
    rows = []
    for mb in (1, 8):
        nelems = mb * MiB // 4 // n
        x = jnp.asarray(np.random.RandomState(0).randn(n * 8, nelems // 8),
                        jnp.float32)

        # both sides identically jit-wrapped so the comparison is pure
        # collective time (the session driver path adds per-call key/cache
        # bookkeeping that would skew the uni-vs-bidir rows)
        uni = jax.jit(shard_map(
            lambda v: _uni_ring_all_gather(v, "dev"), mesh=mesh,
            in_specs=P("dev"), out_specs=P(None), check_vma=False))
        bi = jax.jit(shard_map(
            sess.collectives.all_gather, mesh=mesh,
            in_specs=P("dev"), out_specs=P(None), check_vma=False))
        us_uni = timeit_us(uni, x)
        us_bi = timeit_us(bi, x)
        sess.all_gather(x)   # driver path: compiled once into the plan cache
        rows.append(Row(f"allgather/{mb}MiB/uni_ring", us_uni,
                        "1link/step"))
        rows.append(Row(f"allgather/{mb}MiB/bidir_ring", us_bi,
                        "2links/step"))
        # structural: per-step busiest-link bytes halve with striping
        shard_bytes = x.nbytes // n
        rows.append(Row(
            f"allgather/{mb}MiB/busiest_link_bytes_per_step", 0.0,
            f"uni={shard_bytes}B,bidir={shard_bytes // 2}B"))

        rs = jax.jit(shard_map(
            sess.collectives.reduce_scatter, mesh=mesh,
            in_specs=P(None), out_specs=P("dev"), check_vma=False))
        xr = jnp.asarray(np.random.RandomState(1).randn(n * 8, nelems // 8),
                         jnp.float32)
        rows.append(Row(f"reducescatter/{mb}MiB/bidir_ring",
                        timeit_us(rs, xr), "2links/step"))
        sess.reduce_scatter(xr)
    rows.append(Row("collectives/plan_cache", 0.0,
                    "hits={hits},misses={misses}".format(
                        **sess.stats()["cache"])))
    return rows
