"""Beyond-paper (paper §6 future work): multipath-striped collectives.

Compares the bidirectional-ring all-gather/reduce-scatter against the
single-direction baseline: wall-clock on the host mesh plus the structural
metric that matters on the torus — bytes crossing the busiest directional
link per step (halved by striping).

``--hierarchical`` switches to the island-aware sweep (DESIGN §3.1): the
§4.4 tier model's flat-ring vs two-level all-reduce times on a 2-island
topology, plus the executable ``two_level_all_reduce`` on a (2, 4) host
mesh validated against ``lax.psum`` over both axes. CI's bench-smoke
gates ``modeled_two_level_s <= modeled_flat_s`` on these rows.
"""

from functools import partial

from benchmarks.common import MiB, Row, timeit_us

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import CommSession
from repro.compat import axis_size, make_mesh, shard_map

#: Payload sizes (MiB) for the hierarchical model rows; --smoke keeps one.
HIER_SIZES = [8, 64]


def _uni_ring_all_gather(x, axis_name):
    n = axis_size(axis_name)
    i = jax.lax.axis_index(axis_name)
    cw = [(j, (j + 1) % n) for j in range(n)]
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, x[None], i, axis=0)
    cur = x
    for step in range(1, n):
        cur = jax.lax.ppermute(cur, axis_name, cw)
        out = jax.lax.dynamic_update_slice(
            out, cur[None], (jnp.mod(i - step, n),) + (0,) * x.ndim)
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def run() -> list[Row]:
    mesh = jax.sharding.Mesh(jax.devices(), ("dev",))
    sess = CommSession(mesh=mesh)
    n = 8
    rows = []
    for mb in (1, 8):
        nelems = mb * MiB // 4 // n
        x = jnp.asarray(np.random.RandomState(0).randn(n * 8, nelems // 8),
                        jnp.float32)

        # both sides identically jit-wrapped so the comparison is pure
        # collective time (the session driver path adds per-call key/cache
        # bookkeeping that would skew the uni-vs-bidir rows)
        uni = jax.jit(shard_map(
            lambda v: _uni_ring_all_gather(v, "dev"), mesh=mesh,
            in_specs=P("dev"), out_specs=P(None), check_vma=False))
        bi = jax.jit(shard_map(
            sess.collectives.all_gather, mesh=mesh,
            in_specs=P("dev"), out_specs=P(None), check_vma=False))
        us_uni = timeit_us(uni, x)
        us_bi = timeit_us(bi, x)
        sess.all_gather(x)   # driver path: compiled once into the plan cache
        rows.append(Row(f"allgather/{mb}MiB/uni_ring", us_uni,
                        "1link/step"))
        rows.append(Row(f"allgather/{mb}MiB/bidir_ring", us_bi,
                        "2links/step"))
        # structural: per-step busiest-link bytes halve with striping
        shard_bytes = x.nbytes // n
        rows.append(Row(
            f"allgather/{mb}MiB/busiest_link_bytes_per_step", 0.0,
            f"uni={shard_bytes}B,bidir={shard_bytes // 2}B"))

        rs = jax.jit(shard_map(
            sess.collectives.reduce_scatter, mesh=mesh,
            in_specs=P(None), out_specs=P("dev"), check_vma=False))
        xr = jnp.asarray(np.random.RandomState(1).randn(n * 8, nelems // 8),
                         jnp.float32)
        rows.append(Row(f"reducescatter/{mb}MiB/bidir_ring",
                        timeit_us(rs, xr), "2links/step"))
        sess.reduce_scatter(xr)
    rows.append(Row("collectives/plan_cache", 0.0,
                    "hits={hits},misses={misses}".format(
                        **sess.stats()["cache"])))
    return rows


def run_hierarchical() -> list[Row]:
    """Island-aware sweep: modeled flat vs two-level all-reduce on a
    2-island × 4-device topology + the executable decomposition."""
    from repro.comm.collectives import (select_all_reduce_strategy,
                                        two_level_all_reduce)
    from repro.core.topology import Topology

    topo = Topology.hierarchical(2, 4, name="hier2x4")
    rows = []
    for mb in HIER_SIZES:
        nbytes = mb * MiB
        chosen, times = select_all_reduce_strategy(topo, nbytes)
        speedup = times["flat"] / max(times["two_level"], 1e-12)
        rows.append(Row(
            f"hier/allreduce/{mb}MiB/modeled", times["two_level"] * 1e6,
            f"chosen={chosen},flat={times['flat'] * 1e6:.1f}us,"
            f"speedup={speedup:.2f}x",
            {"islands": topo.num_islands,
             "modeled_flat_s": times["flat"],
             "modeled_two_level_s": times["two_level"],
             "chosen": chosen}))

    # Executable two-level decomposition on the (pod, dev) host mesh,
    # validated against the joint psum before timing.
    mesh = make_mesh((2, 4), ("pod", "dev"))
    x = jnp.asarray(np.random.RandomState(2).randn(16, 256), jnp.float32)
    two = jax.jit(shard_map(
        partial(two_level_all_reduce, inter_axis="pod", intra_axis="dev"),
        mesh=mesh, in_specs=P("dev"), out_specs=P("dev"), check_vma=False))
    ref = jax.jit(shard_map(
        lambda v: jax.lax.psum(v, ("pod", "dev")),
        mesh=mesh, in_specs=P("dev"), out_specs=P("dev"), check_vma=False))
    np.testing.assert_allclose(np.asarray(two(x)), np.asarray(ref(x)),
                               rtol=1e-5)
    rows.append(Row("hier/allreduce/exec/two_level", timeit_us(two, x),
                    "2x4_mesh", {"matches_psum": True}))
    rows.append(Row("hier/allreduce/exec/flat_psum", timeit_us(ref, x),
                    "2x4_mesh"))
    return rows


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hierarchical", action="store_true",
                    help="island-aware sweep (flat vs two-level rows)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes only (CI smoke step)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        HIER_SIZES[:] = HIER_SIZES[:1]
    rows = run_hierarchical() if args.hierarchical else run()
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv(), flush=True)
    if args.json:
        payload = [{"name": r.name, "us_per_call": round(r.us, 2),
                    "derived": r.derived, **r.extra} for r in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()
