"""Paper Fig. 7/8 — OMB unidirectional MPI bandwidth across window sizes
(1/4/16) on the Beluga (2 NVLink/pair) and Narval (4 NVLink/pair) models."""

from benchmarks.common import MiB, Row, SIZES_OMB

from repro.comm import CommSession
from repro.core import Topology, windowed_bandwidth_gbps

CLUSTERS = {
    "beluga": Topology.full_mesh(4, sublinks_per_pair=2, name="beluga4"),
    "narval": Topology.full_mesh(4, sublinks_per_pair=4, name="narval4"),
}


def run() -> list[Row]:
    rows = []
    for cluster, topo in CLUSTERS.items():
        sess = CommSession(topology=topo)
        for mb in SIZES_OMB:
            plan3 = sess.plan(0, 1, mb * MiB, max_paths=3)
            plan1 = sess.plan(0, 1, mb * MiB, max_paths=1)
            for w in (1, 4, 16):
                for tag, plan in (("1path", plan1), ("3path", plan3)):
                    for graphs in (False, True):
                        bw = windowed_bandwidth_gbps(
                            plan, topo, window=w, compiled_plan=graphs)
                        g = "graph" if graphs else "nograph"
                        rows.append(Row(
                            f"omb_bw/{cluster}/{mb}MiB/w{w}/{tag}/{g}",
                            0.0, f"{bw:.1f}GB/s"))
    return rows
