"""Calibration accuracy — cold §4.4 constants vs fitted profile (§4.4c).

The analytical model (DESIGN.md §4.4) ships with constants measured on
the paper's DGX A100; on any other machine — including this CPU test
backend — its absolute predictions are off, even if the *ordering* of
candidate plans is usually right. The measured-feedback loop (§4.4c)
closes that gap: run real traffic with ``REPRO_MP_TELEMETRY`` on, fit a
:class:`CalibrationProfile`, and re-score.

Rows, per (route signature, chunks-per-path, schedule):

* ``calibration/.../model_err_cold``   — mean relative error of the
  constant-driven model against measured dispatch time,
* ``calibration/.../model_err_fitted`` — same samples re-scored through
  the fitted profile; the derived column reports the improvement ratio
  (acceptance: fitted is strictly closer than the constants).

Plus two overhead rows gating the "near-zero cost when off" claim:

* ``calibration/telemetry_off/setup_fastpath`` — steady-state resolution
  cost with telemetry disabled; directly comparable with
  ``dispatch/nodesN/setup_fastpath`` from :mod:`bench_dispatch` (CI
  asserts they agree within noise),
* ``calibration/telemetry_on/setup_fastpath`` — the same with the
  recorder enabled (the price of a sample per dispatch).

``--profile-out PATH`` writes the fitted profile JSON (the CI bench-smoke
step uploads it alongside the ``BENCH_*.json`` artifact).
"""

import time

from benchmarks import common
from benchmarks.common import Row

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, CommSession, modeled_vs_measured
from repro.core import Topology

NELEMS = 1 << 15     # 128 KiB f32 — multipath engages, compiles stay quick
SENDS_PER_CONFIG = 8
#: Schedules exercised by the calibration sweep — one identity pass and
#: one model-driven pass, so fitted terms are scored on both kinds.
CALIBRATION_SCHEDULES = ["round_robin", "critical_path"]


def _session(telemetry: bool):
    topo = Topology.full_mesh(4, with_host=False)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("dev",))
    return CommSession(
        CommConfig(multipath_threshold=64, fastpath=True,
                   telemetry=telemetry),
        mesh=mesh, topology=topo)


def _setup_us(sess, chunks: int, iters: int = 10) -> float:
    """Mean resolution-stage cost (mirrors bench_dispatch._setup_us)."""
    eng = sess.engine
    specs = [(0, 1, NELEMS, jnp.float32)]
    t0 = time.perf_counter_ns()
    for _ in range(iters):
        eng._resolve(specs, window=1, max_paths=3, num_chunks=chunks,
                     exclusive=False, schedule=None, single=True)
    return (time.perf_counter_ns() - t0) / iters / 1e3


def _drive(sess) -> None:
    """Dispatch every (chunks, schedule) config enough times to fit."""
    msg = jnp.arange(NELEMS, dtype=jnp.float32)
    for chunks in common.DISPATCH_CHUNKS:
        for sched in CALIBRATION_SCHEDULES:
            for _ in range(SENDS_PER_CONFIG):
                jax.block_until_ready(
                    sess.send(msg, 0, 1, max_paths=3, num_chunks=chunks,
                              schedule=sched))


def _error_rows(sess, profile) -> list[Row]:
    """Per-signature modeled-vs-measured rows, cold and fitted."""
    rows = []
    by_sig: dict[tuple, list] = {}
    for s in sess.telemetry.samples():
        by_sig.setdefault((s.schedule, s.num_paths, s.routes), []).append(s)
    for (sched, npaths, _routes), group in sorted(
            by_sig.items(), key=lambda kv: (kv[0][0] or "", kv[0][1])):
        chunks = group[0].routes[0][0][2] if group[0].routes else 0
        res = modeled_vs_measured(group, sess.topology, profile=profile)
        cold = res["constant"]["mean_rel_err"]
        fit = res["fitted"]["mean_rel_err"]
        name = f"calibration/{sched}/paths{npaths}/chunks{chunks}"
        improve = cold / max(fit, 1e-9)
        extra = {"schedule": sched, "num_paths": npaths,
                 "chunks_per_path": chunks, "samples": res["num_samples"],
                 "mean_rel_err_cold": round(cold, 4),
                 "mean_rel_err_fitted": round(fit, 4),
                 "improvement_x": round(improve, 2)}
        rows.append(Row(f"{name}/model_err_cold", cold * 1e2,
                        "pct_rel_err", extra))
        rows.append(Row(f"{name}/model_err_fitted", fit * 1e2,
                        f"{improve:.1f}x_closer", extra))
    return rows


def run(profile_out: str | None = None) -> list[Row]:
    rows = []

    # -- fit a profile from real traffic, score cold vs fitted
    sess = _session(telemetry=True)
    _drive(sess)
    profile = sess.calibrate(min_samples=2, warmup=1)
    rows += _error_rows(sess, profile)
    agg = modeled_vs_measured(sess.telemetry.samples(), sess.topology,
                              profile=profile)
    rows.append(Row(
        "calibration/all/model_err_fitted",
        agg["fitted"]["mean_rel_err"] * 1e2,
        f"vs_cold_{agg['constant']['mean_rel_err'] * 1e2:.0f}pct",
        {"samples": agg["num_samples"],
         "mean_rel_err_cold": round(agg["constant"]["mean_rel_err"], 4),
         "mean_rel_err_fitted": round(agg["fitted"]["mean_rel_err"], 4),
         "fitted_links": len(profile.link_bandwidth_gbps),
         "topology_digest": profile.topology_digest}))
    if profile_out:
        import json
        with open(profile_out, "w") as f:
            json.dump(profile.to_payload(), f, indent=2, sort_keys=True)
        print(f"# wrote calibration profile to {profile_out}", flush=True)

    # -- telemetry overhead: off must match bench_dispatch's fast path
    msg = jnp.arange(NELEMS, dtype=jnp.float32)
    chunks = common.DISPATCH_CHUNKS[0]
    for label, telemetry in (("telemetry_off", False), ("telemetry_on",
                                                        True)):
        osess = _session(telemetry=telemetry)
        jax.block_until_ready(osess.send(msg, 0, 1, max_paths=3,
                                         num_chunks=chunks))
        setup = _setup_us(osess, chunks)
        rows.append(Row(f"calibration/{label}/setup_fastpath", setup,
                        "steady_state",
                        {"chunks_per_path": chunks,
                         "telemetry": telemetry}))
    return rows


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one chunk count only (CI smoke step)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON artifact")
    ap.add_argument("--profile-out", metavar="PATH", default=None,
                    help="write the fitted CalibrationProfile JSON here")
    args = ap.parse_args()
    if args.smoke:
        common.DISPATCH_CHUNKS[:] = common.DISPATCH_CHUNKS[:1]
    rows = run(profile_out=args.profile_out)
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv(), flush=True)
    if args.json:
        payload = [{"name": r.name, "us_per_call": round(r.us, 2),
                    "derived": r.derived, **r.extra} for r in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()
