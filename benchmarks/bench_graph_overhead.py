"""Paper Fig. 13/14 — transfer-plan (CUDA Graph analogue) lifecycle costs.

Measures the REAL trace / lower / compile(=instantiate) / launch times of
compiled multipath plans as a function of copy-node count, first iteration
vs steady state — the JAX counterpart of the paper's overhead analysis.
"""

from benchmarks.common import Row, timeit_us

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, CommSession
from repro.core import Topology


def run() -> list[Row]:
    topo = Topology.full_mesh(4, with_host=False)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("dev",))
    rows = []
    # node count grows with chunk count (paper: with message size)
    for chunks in (1, 2, 4, 8, 16):
        sess = CommSession(
            CommConfig(multipath_threshold=64, cache_capacity=8),
            mesh=mesh, topology=topo)
        nelems = 1 << 16
        compiled, plan = sess.compiled_for(0, 1, nelems, max_paths=3,
                                           num_chunks=chunks)
        life = compiled.lifecycle
        rows.append(Row(
            f"plan_lifecycle/nodes{plan.num_nodes}/trace",
            life.trace_ns / 1e3, "first_iter"))
        rows.append(Row(
            f"plan_lifecycle/nodes{plan.num_nodes}/lower",
            life.lower_ns / 1e3, "first_iter"))
        rows.append(Row(
            f"plan_lifecycle/nodes{plan.num_nodes}/instantiate",
            life.compile_ns / 1e3, "first_iter"))
        x = jnp.zeros((1, 4, nelems), jnp.float32)
        launch_us = timeit_us(compiled.compiled, x, iters=10, warmup=3)
        rows.append(Row(
            f"plan_lifecycle/nodes{plan.num_nodes}/launch",
            launch_us, "steady_state"))
        total_first = life.build_ns / 1e3 + launch_us
        rows.append(Row(
            f"plan_lifecycle/nodes{plan.num_nodes}/amortize_breakeven",
            0.0, f"{total_first / max(launch_us, 1e-9):.0f}launches"))
    return rows
