"""Paper Fig. 13/14 — transfer-graph (CUDA Graph analogue) lifecycle costs.

Measures the REAL trace / lower / compile(=instantiate) / launch times of
compiled multipath plans as a function of copy-node count, first iteration
vs steady state — the JAX counterpart of the paper's overhead analysis —
and, alongside them, the ANALYTIC launch cost the pipeline model derives
from the same :class:`~repro.comm.graph.TransferGraph` node count (graph
launch constants vs per-node launch constants). Every row carries the
graph's node/edge counts in the ``--json`` artifact so the perf trajectory
can be plotted against graph size directly.

The ``--schedule`` axis (``benchmarks.common.SCHEDULES``, narrowed by
``run.py --schedule``) additionally emits one modeled-time row per
chunk-interleaving scheduler (DESIGN.md §2.2) per graph size, with the
scheduled digest and the delta vs the round-robin baseline in the
``--json`` extras — the BENCH_*.json trajectory tracks schedule deltas.
"""

from benchmarks import common
from benchmarks.common import Row, timeit_us

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, CommSession
from repro.comm.graph import lower
from repro.comm.passes import apply_schedule
from repro.core import Topology, launch_overhead_ns, scheduled_time_s


def run() -> list[Row]:
    topo = Topology.full_mesh(4, with_host=False)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("dev",))
    rows = []
    # node count grows with chunk count (paper: with message size)
    for chunks in (1, 2, 4, 8, 16):
        sess = CommSession(
            CommConfig(multipath_threshold=64, cache_capacity=8),
            mesh=mesh, topology=topo)
        nelems = 1 << 16
        compiled, plan = sess.compiled_for(0, 1, nelems, max_paths=3,
                                           num_chunks=chunks)
        graph = lower(plan)
        counts = {"nodes": graph.num_nodes, "edges": graph.num_edges,
                  "critical_path_nodes": graph.critical_path_nodes()}
        assert graph.num_nodes == compiled.lifecycle.num_nodes
        life = compiled.lifecycle
        rows.append(Row(
            f"plan_lifecycle/nodes{graph.num_nodes}/trace",
            life.trace_ns / 1e3, "first_iter", counts))
        rows.append(Row(
            f"plan_lifecycle/nodes{graph.num_nodes}/lower",
            life.lower_ns / 1e3, "first_iter", counts))
        rows.append(Row(
            f"plan_lifecycle/nodes{graph.num_nodes}/instantiate",
            life.compile_ns / 1e3, "first_iter", counts))
        x = jnp.zeros((1, 4, nelems), jnp.float32)
        launch_us = timeit_us(compiled.compiled, x, iters=10, warmup=3)
        rows.append(Row(
            f"plan_lifecycle/nodes{graph.num_nodes}/launch",
            launch_us, "steady_state", counts))
        # modeled launch costs from the SAME graph node count: one fused
        # graph launch vs per-node async-copy launches (paper §5.5)
        modeled_graph_us = launch_overhead_ns(
            plan, compiled_plan=True) / 1e3
        modeled_pernode_us = launch_overhead_ns(
            plan, compiled_plan=False) / 1e3
        rows.append(Row(
            f"plan_lifecycle/nodes{graph.num_nodes}/modeled_graph_launch",
            modeled_graph_us, "model", counts))
        rows.append(Row(
            f"plan_lifecycle/nodes{graph.num_nodes}/modeled_pernode_launch",
            modeled_pernode_us, "model",
            {**counts,
             "graph_vs_pernode":
                 round(modeled_pernode_us / max(modeled_graph_us, 1e-9),
                       2)}))
        total_first = life.build_ns / 1e3 + launch_us
        rows.append(Row(
            f"plan_lifecycle/nodes{graph.num_nodes}/amortize_breakeven",
            0.0, f"{total_first / max(launch_us, 1e-9):.0f}launches",
            counts))
        # --schedule axis: modeled time per chunk-interleaving scheduler
        # over the SAME lowering (DESIGN.md §2.2); the round-robin row is
        # the baseline every delta is against.
        baseline_us = scheduled_time_s(graph, topo) * 1e6
        for sched in common.SCHEDULES:
            sg, chosen = apply_schedule(graph, sched, topo)
            t_us = scheduled_time_s(sg, topo) * 1e6
            rows.append(Row(
                f"plan_lifecycle/nodes{graph.num_nodes}/schedule_{sched}",
                t_us, chosen,
                {**counts, "schedule": sched, "chosen": chosen,
                 "digest": sg.digest(),
                 "delta_vs_round_robin_us":
                     round(t_us - baseline_us, 4)}))
    return rows
