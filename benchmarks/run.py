"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:

* bench_put_bw         → paper Fig. 6   (UCX Put bandwidth)
* bench_omb_bw         → paper Fig. 7/8 (OMB BW, windows 1/4/16)
* bench_omb_bibw       → paper Fig. 9/10 (OMB bidirectional BW + groups)
* bench_jacobi         → paper Fig. 12  (Jacobi solver speedup + halo group)
* bench_graph_overhead → paper Fig. 13/14 (plan lifecycle costs)
* bench_calibration    → DESIGN.md §4.4c (model error, cold vs fitted)
* bench_step_capture   → DESIGN.md §2.4 (captured vs uncaptured step)
* bench_collectives    → paper §6 future work (multipath collectives)
* bench_faults         → DESIGN.md §4.6 (degraded-mode ladder + recovery)

``--smoke`` shrinks every size sweep to its smallest point (CI's tier-1
benchmark smoke step); ``--json PATH`` additionally writes the rows as a
JSON artifact (the ``BENCH_*.json`` perf trajectory).
"""

import argparse
import json

from benchmarks import common  # noqa: F401 — pins device count first


def _apply_smoke() -> None:
    # In-place so modules that did ``from benchmarks.common import
    # SIZES_*`` see the shrunken sweeps.
    common.SIZES_PUT[:] = [1, 4]
    common.SIZES_OMB[:] = [1, 4]
    common.EXEC_SIZES[:] = [1]
    common.DISPATCH_CHUNKS[:] = common.DISPATCH_CHUNKS[:1]


def collect() -> list:
    from benchmarks import (bench_calibration, bench_collectives,
                            bench_dispatch, bench_faults,
                            bench_graph_overhead, bench_jacobi,
                            bench_omb_bibw, bench_omb_bw, bench_put_bw,
                            bench_step_capture)

    rows = []
    for mod in (bench_put_bw, bench_omb_bw, bench_omb_bibw, bench_jacobi,
                bench_graph_overhead, bench_dispatch, bench_calibration,
                bench_step_capture, bench_collectives, bench_faults):
        rows.extend(mod.run())
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes only (CI smoke step)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON artifact")
    ap.add_argument("--schedule", metavar="NAME", default=None,
                    choices=common.SCHEDULES,
                    help="restrict the bench_graph_overhead scheduler "
                         "sweep to one chunk-interleaving pass "
                         "(default: sweep all of "
                         f"{', '.join(common.SCHEDULES)})")
    args = ap.parse_args()
    if args.smoke:
        _apply_smoke()
    if args.schedule:
        common.SCHEDULES[:] = [args.schedule]

    rows = collect()
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv(), flush=True)
    if args.json:
        payload = [{"name": r.name, "us_per_call": round(r.us, 2),
                    "derived": r.derived,
                    **getattr(r, "extra", {})} for r in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()
