"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:

* bench_put_bw         → paper Fig. 6   (UCX Put bandwidth)
* bench_omb_bw         → paper Fig. 7/8 (OMB BW, windows 1/4/16)
* bench_omb_bibw       → paper Fig. 9/10 (OMB bidirectional BW)
* bench_jacobi         → paper Fig. 12  (Jacobi solver speedup)
* bench_graph_overhead → paper Fig. 13/14 (plan lifecycle costs)
* bench_collectives    → paper §6 future work (multipath collectives)
"""

from benchmarks import common  # noqa: F401 — pins device count first


def main() -> None:
    from benchmarks import (bench_collectives, bench_graph_overhead,
                            bench_jacobi, bench_omb_bibw, bench_omb_bw,
                            bench_put_bw)

    print("name,us_per_call,derived")
    for mod in (bench_put_bw, bench_omb_bw, bench_omb_bibw, bench_jacobi,
                bench_graph_overhead, bench_collectives):
        for row in mod.run():
            print(row.csv(), flush=True)


if __name__ == "__main__":
    main()
