"""Whole-iteration capture — captured vs uncaptured Jacobi step (§2.4).

The paper's CUDA Graphs capture kernels and memcpys together; §2.4's
``session.capture`` does the analogue here. This benchmark measures one
Jacobi iteration (boundary extraction + ring halo exchange + 5-point
sweep) two ways, per chunk-interleaving schedule:

* **captured** — the whole iteration is ONE heterogeneous
  ``TransferGraph`` (copy + compute nodes) and ONE engine dispatch
  (``make_captured_jacobi_step``),
* **uncaptured** — the pre-§2.4 idiom: one ``session.exchange`` group
  dispatch for the halos plus a separately-jitted sweep (two launches
  per iteration).

Each captured row carries ``captured_dispatches`` (the acceptance
invariant: exactly ONE per iteration) and the modeled times of both
variants — ``modeled_captured_s`` is ``scheduled_time_s`` over the
heterogeneous graph, ``modeled_uncaptured_s`` adds the second launch's
fixed cost and the compute nodes' ``compute_time_s`` to the comm-only
graph — so CI can assert the model agrees capture never loses.

Overlap instrumentation (DESIGN §2.2 lane model): captured rows
additionally carry ``modeled_lane_s`` / ``modeled_serialized_s`` (the
resource-lane makespan vs the historic serialized chain of the SAME
scheduled graph) and ``hidden_copy_s`` / ``hidden_frac`` (modeled copy
seconds running behind compute, as a fraction of total copy time).
``step_capture/{sched}/dp_model`` rows price a mini captured DP-train
step graph (grad → ring all-reduce → update; lowered + scheduled, never
compiled) the same way — together they feed the CI overlap gate, which
asserts ``overlap``'s lane makespan never exceeds ``critical_path``'s
serialized makespan on either graph and ``auto`` never regresses
``round_robin``.
"""

import time

from benchmarks import common
from benchmarks.common import Row, timeit_us

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, CommSession, PathPlanner, TransferRequest
from repro.core import Topology
from repro.core.halo import halo_exchange_group, make_captured_jacobi_step
from repro.core.pipelining import (compute_time_s, graph_node_weights_s,
                                   hidden_copy_time_s, launch_model_for,
                                   scheduled_time_s)

NDEV = 4
ROWS, COLS = 64, 64
ITERS = 10


def _session(schedule: str):
    topo = Topology.full_mesh(NDEV, with_host=False)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:NDEV]), ("dev",))
    return CommSession(
        CommConfig(multipath_threshold=64, schedule=schedule),
        mesh=mesh, topology=topo)


def _global_sweep():
    """Jitted whole-domain sweep — the uncaptured step's compute half."""

    @jax.jit
    def sweep(blocks, left_halos, right_halos):
        n = blocks.shape[0]
        idx = jnp.arange(n)
        left = jnp.where((idx == 0)[:, None, None], 0.0, left_halos)
        right = jnp.where((idx == n - 1)[:, None, None], 0.0, right_halos)
        ext = jnp.concatenate([left, blocks, right], axis=2)
        up = jnp.pad(ext[:, :-1, :], ((0, 0), (1, 0), (0, 0)))
        down = jnp.pad(ext[:, 1:, :], ((0, 0), (0, 1), (0, 0)))
        return 0.25 * (ext[:, :, :-2] + ext[:, :, 2:] + up[:, :, 1:-1]
                       + down[:, :, 1:-1])

    return sweep


def _modeled(step_entry, comm_graph, topo) -> tuple[float, float]:
    """(captured, uncaptured) modeled seconds for one iteration."""
    captured_s = scheduled_time_s(step_entry.graph, topo)
    launch = launch_model_for(topo)
    compute_s = sum(compute_time_s(n, topo) for n in step_entry.graph.nodes
                    if hasattr(n, "kernel"))
    uncaptured_s = (scheduled_time_s(comm_graph, topo) + compute_s
                    + launch.graph_launch_base_ns / 1e9)
    return captured_s, uncaptured_s


def _overlap_extras(graph, topo) -> dict:
    """Lane-model view of one scheduled mixed graph: both objectives'
    makespans plus modeled hidden-copy seconds and fraction."""
    lane_s = scheduled_time_s(graph, topo, mode="lanes")
    serialized_s = scheduled_time_s(graph, topo, mode="serialized")
    hidden_s = hidden_copy_time_s(graph, topo)
    weights = graph_node_weights_s(graph, topo)
    copy_s = sum(w for nd, w in zip(graph.nodes, weights)
                 if not hasattr(nd, "kernel"))
    return {"modeled_lane_s": lane_s,
            "modeled_serialized_s": serialized_s,
            "hidden_copy_s": hidden_s,
            "hidden_frac": round(hidden_s / copy_s, 4) if copy_s else 0.0}


def _dp_model_rows() -> list[Row]:
    """Modeled-only rows for a mini captured DP-train step graph (grad →
    multipath ring all-reduce → update), lowered and scheduled per
    schedule but never compiled — the second mixed graph the CI overlap
    gate prices."""
    from repro.comm.capture import StepCapture, captured_psum, lower_step
    from repro.comm.passes import apply_schedule

    topo = Topology.full_mesh(NDEV, with_host=False)
    planner = PathPlanner(topo, multipath_threshold=256)

    def plan_group_fn(specs, *, max_paths=None, num_chunks=None):
        reqs = [TransferRequest(s, d, ne * 4, granularity=4)
                for (s, d, ne, _) in specs]
        return planner.plan_group(reqs, max_paths=max_paths,
                                  include_host=False,
                                  num_chunks=num_chunks)

    # Launch-bound payload (the regime graph capture targets): the
    # serialized issue chain dominates, so concurrent link lanes give
    # the lane model a strict win the CI gate can assert.
    nelems = 1 << 10
    cap = StepCapture()
    x = cap.input((nelems,), jnp.float32)
    gvec = cap.kernel(lambda v: v * 2.0, x, name="grad",
                      flops=6 * nelems)
    tot = captured_psum(cap, gvec, NDEV, num_chunks=2, name="gradsum")
    cap.kernel(lambda t, v: t / NDEV + v, tot, x, name="update",
               flops=10 * nelems)
    graph, _ = lower_step(cap, plan_group_fn, topo.name)

    rows = []
    for sched in common.SCHEDULES:
        scheduled, chosen = apply_schedule(graph, sched, topo)
        extras = _overlap_extras(scheduled, topo)
        rows.append(Row(
            f"step_capture/{sched}/dp_model",
            extras["modeled_lane_s"] * 1e6, f"chosen={chosen}",
            {"nodes": scheduled.num_nodes,
             "copy_nodes": scheduled.num_copy_nodes,
             "compute_nodes": scheduled.num_compute_nodes,
             "schedule": sched, "chosen": chosen, **extras}))
    return rows


def run() -> list[Row]:
    rows = []
    domain = jnp.arange(NDEV * ROWS * COLS, dtype=jnp.float32).reshape(
        NDEV, ROWS, COLS) / (NDEV * ROWS * COLS)
    sweep = _global_sweep()
    for sched in common.SCHEDULES:
        # -- captured: the whole iteration is one dispatch
        cap_sess = _session(sched)
        t0 = time.perf_counter_ns()
        captured = make_captured_jacobi_step(cap_sess, ROWS, COLS)
        entry = captured.resolve()
        setup_us = (time.perf_counter_ns() - t0) / 1e3
        cap_sess.stats(reset=True)
        out = captured(domain)[0]
        jax.block_until_ready(out)
        captured_dispatches = cap_sess.stats()["dispatches"]
        cap_us = timeit_us(lambda: captured(domain)[0], iters=ITERS,
                           warmup=1)

        # -- uncaptured: one exchange-group dispatch + a jitted sweep
        unc_sess = _session(sched)

        def uncaptured_step(blocks):
            left, right = halo_exchange_group(unc_sess, blocks)
            return sweep(blocks, left, right)

        unc_sess.stats(reset=True)
        jax.block_until_ready(uncaptured_step(domain))
        unc_dispatches = unc_sess.stats()["dispatches"]
        unc_us = timeit_us(uncaptured_step, domain, iters=ITERS, warmup=1)
        comm_entry = next(iter(
            unc_sess.engine._fastpath._store.values()))[1]

        g = entry.graph
        modeled_cap_s, modeled_unc_s = _modeled(
            entry, comm_entry.graph, cap_sess.topology)
        counts = {"nodes": g.num_nodes,
                  "copy_nodes": g.num_copy_nodes,
                  "compute_nodes": g.num_compute_nodes,
                  "schedule": sched}
        rows += [
            Row(f"step_capture/{sched}/captured", cap_us,
                f"{captured_dispatches}dispatch",
                {**counts,
                 "captured_dispatches": captured_dispatches,
                 "setup_us": round(setup_us, 2),
                 "modeled_captured_s": modeled_cap_s,
                 "modeled_uncaptured_s": modeled_unc_s,
                 "modeled_speedup": round(
                     modeled_unc_s / max(modeled_cap_s, 1e-12), 3),
                 **_overlap_extras(g, cap_sess.topology)}),
            Row(f"step_capture/{sched}/uncaptured", unc_us,
                "exchange+jit_sweep",
                {**counts,
                 "engine_dispatches": unc_dispatches,
                 "launches_per_iter": unc_dispatches + 1}),
        ]
    rows += _dp_model_rows()
    return rows


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="overlap-gate schedules only (CI smoke step)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        # Keep the schedules the CI overlap gate compares (overlap vs
        # critical_path, auto vs round_robin) in the smoke artifact.
        common.SCHEDULES[:] = [s for s in common.SCHEDULES
                               if s != "depth_first"]
    rows = run()
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv(), flush=True)
    if args.json:
        payload = [{"name": r.name, "us_per_call": round(r.us, 2),
                    "derived": r.derived, **r.extra} for r in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()
