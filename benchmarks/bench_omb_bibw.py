"""Paper Fig. 9/10 — OMB bidirectional bandwidth. Key reproduced effects:
the host path consistently DEGRADES bidirectional traffic (both directions
contend on host staging capacity), while GPU-path striping does not; and
fusing the two directions into ONE transfer group (one compiled launch,
jointly planned) beats two independently-planned dispatches."""

from benchmarks.common import MiB, Row, SIZES_OMB

from repro.comm import CommSession
from repro.core import (Topology, estimate_group_time_s,
                        estimate_transfer_time_s)


def run() -> list[Row]:
    rows = []
    for cluster, sub in (("beluga", 2), ("narval", 4)):
        topo = Topology.full_mesh(4, sublinks_per_pair=sub, name=cluster)
        sess = CommSession(topology=topo)
        for mb in SIZES_OMB:
            nbytes = mb * MiB
            for cname, kw in (("1path", dict(max_paths=1)),
                              ("3path", dict(max_paths=3)),
                              ("3path+host", dict(max_paths=4,
                                                  include_host=True))):
                fwd = sess.plan(0, 1, nbytes, **kw)
                rev = sess.plan(1, 0, nbytes, **kw)
                t = estimate_transfer_time_s(fwd, topo,
                                             concurrent_plans=[rev])
                bibw = 2 * nbytes / t / 1e9
                rows.append(Row(f"omb_bibw/{cluster}/{mb}MiB/{cname}",
                                0.0, f"{bibw:.1f}GB/s"))
            # transfer-group mode: both directions planned jointly and
            # fused into one launch vs two independent dispatches.
            group = sess.plan_group([(0, 1, nbytes), (1, 0, nbytes)])
            t_grp = estimate_group_time_s(group, topo, fused=True)
            indep = [sess.plan(0, 1, nbytes), sess.plan(1, 0, nbytes)]
            t_ind = estimate_group_time_s(indep, topo, fused=False)
            bibw = 2 * nbytes / t_grp / 1e9
            rows.append(Row(f"omb_bibw/{cluster}/{mb}MiB/group",
                            0.0, f"{bibw:.1f}GB/s({t_ind / t_grp:.2f}x)"))
    return rows
