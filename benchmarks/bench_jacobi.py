"""Paper Fig. 12 — Jacobi solver runtime speedup: single-path vs multipath
halo exchange. Executes for real on the 8-device host mesh (wall-clock) and
reports the Beluga link-model speedup for the paper's problem sizes."""

from benchmarks.common import MiB, Row, timeit_us

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import CommSession
from repro.compat import shard_map
from repro.core import (Topology, estimate_group_time_s,
                        estimate_transfer_time_s)
from repro.core.halo import jacobi_step


def _solver(mesh, multipath, iters=10):
    def body(u):
        def sweep(u, _):
            return jacobi_step(u, "dev", multipath=multipath), None
        u, _ = jax.lax.scan(sweep, u, None, length=iters)
        return u

    def local(u):
        return body(u[0])[None]

    return jax.jit(shard_map(local, mesh=mesh, in_specs=P("dev"),
                             out_specs=P("dev"), check_vma=False))


def run() -> list[Row]:
    rows = []
    mesh = jax.sharding.Mesh(jax.devices(), ("dev",))
    u = jnp.asarray(np.random.RandomState(0).randn(8, 8, 4096), jnp.float32)
    for multipath in (False, True):
        f = _solver(mesh, multipath)
        us = timeit_us(f, u, iters=3, warmup=1)
        tag = "multipath" if multipath else "singlepath"
        rows.append(Row(f"jacobi_exec/8x32768/{tag}", us, "10iters"))

    # paper-scale analytic model: 4 ranks, vertical dim 8, horizontal 2^23..2^30
    topo = Topology.full_mesh(4)
    sess = CommSession(topology=topo)
    for log2w in (23, 26, 28, 30):
        total = 8 * (1 << log2w) * 4          # fp32 domain bytes
        boundary = total // 4 // (1 << 5)     # 256MB at 8GB (paper §5.4)
        boundary = max(4096, 8 * (1 << log2w) // 4 // 8 * 4 // 1)
        # per-iteration comm: each rank exchanges one boundary column block
        # with each neighbour; compute time modeled at 819 GB/s local sweep
        nbytes = 8 * 4 * (1 << log2w) // 4 // 8  # col-block bytes per rank
        nbytes = max(nbytes, 4096)
        t1 = 2 * estimate_transfer_time_s(
            sess.plan(0, 1, nbytes, max_paths=1), topo,
            compiled_plan=False)
        t2 = 2 * estimate_transfer_time_s(
            sess.plan(0, 1, nbytes, max_paths=2, num_chunks=4), topo,
            compiled_plan=True)
        compute = (total / 4) * 5 / (819e9)   # 5-point sweep reads
        sp = (compute + t1) / (compute + t2)
        rows.append(Row(f"jacobi_model/2^{log2w}cols/2path_speedup", 0.0,
                        f"{sp:.2f}x(paper<=1.28x)"))

        # transfer-group halo: all 8 boundary messages of the 4-rank ring
        # (±1 neighbours) planned jointly and fused into ONE launch, vs 8
        # independently-planned back-to-back dispatches per iteration.
        reqs = []
        for i in range(4):
            reqs += [(i, (i + 1) % 4, nbytes), (i, (i - 1) % 4, nbytes)]
        group = sess.plan_group(reqs, num_chunks=4)
        t_grp = estimate_group_time_s(group, topo, fused=True)
        indep = [sess.plan(s, d, n, num_chunks=4) for s, d, n in reqs]
        t_ind = estimate_group_time_s(indep, topo, fused=False)
        rows.append(Row(f"jacobi_halo_group/2^{log2w}cols/fused_speedup",
                        0.0, f"{t_ind / t_grp:.2f}x"))
    return rows
