"""Steady-state dispatch latency — cold vs warm-key vs fast path (§2.3).

The paper's Fig. 13/14 story is "setup once, launch many": once a
``cudaGraphExec_t`` exists, a launch is one ``cudaGraphLaunch``. This
benchmark measures what OUR dispatch actually pays per call, as a
function of transfer-graph node count:

* **cold** — fresh session, first send: planner + lower + pass + digest
  + trace/lower/compile + staging + launch (the one-time cost),
* **warm-key** — ``fastpath=False``: the compiled program is served from
  the plan cache but every dispatch still re-runs the
  plan→lower→schedule→digest pipeline (the pre-§2.3 steady state),
* **fast-path** — ``fastpath=True``: one epoch-checked dict lookup +
  pooled staging + launch.

``setup_*`` rows isolate the resolution stage (everything before
staging/launch) so the acceptance ratio — fast-path setup ≥ 5x cheaper
than the cold/warm setup — is measured directly, not inferred. A final
row reports the group-dedup hit-rate delta from canonical message
identity (permuted operand order collides on one entry; ROADMAP
"graph-level cache dedup").
"""

import time

from benchmarks import common
from benchmarks.common import Row, timeit_us

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, CommSession
from repro.core import Topology

NELEMS = 1 << 15     # 128 KiB f32 — multipath engages, compiles stay quick
ITERS = 10


def _session(fastpath: bool):
    topo = Topology.full_mesh(4, with_host=False)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("dev",))
    return CommSession(
        CommConfig(multipath_threshold=64, fastpath=fastpath),
        mesh=mesh, topology=topo)


def _setup_us(sess, chunks: int, iters: int = ITERS) -> float:
    """Mean time of the resolution stage only (no staging, no launch)."""
    eng = sess.engine
    specs = [(0, 1, NELEMS, jnp.float32)]
    t0 = time.perf_counter_ns()
    for _ in range(iters):
        eng._resolve(specs, window=1, max_paths=3, num_chunks=chunks,
                     exclusive=False, schedule=None, single=True)
    return (time.perf_counter_ns() - t0) / iters / 1e3


def _send_us(sess, msg, chunks: int) -> float:
    return timeit_us(lambda: sess.send(msg, 0, 1, max_paths=3,
                                       num_chunks=chunks),
                     iters=ITERS, warmup=2)


def _group_dedup_row() -> Row:
    """Hit-rate delta from canonical message identity inside a group."""
    sess = _session(fastpath=True)
    msgs = [jnp.arange(4096, dtype=jnp.float32),
            jnp.arange(2048, dtype=jnp.float32) * -1.0,
            jnp.arange(1024, dtype=jnp.int32)]
    pairs = [(0, 1), (1, 2), (2, 3)]
    perms = [(0, 1, 2), (2, 0, 1), (1, 2, 0), (2, 1, 0)]
    t0 = time.perf_counter_ns()
    for perm in perms:
        sess.exchange([(msgs[i], *pairs[i]) for i in perm])
    us = (time.perf_counter_ns() - t0) / len(perms) / 1e3
    fp = sess.stats()["fastpath"]
    # Without canonicalization every permutation is its own miss/compile.
    naive_misses = len(perms)
    hit_rate = fp["hits"] / len(perms)
    naive_rate = (len(perms) - naive_misses) / len(perms)
    return Row("dispatch/group_dedup/hit_rate",
               us, f"{fp['hits']}/{len(perms)}hits",
               {"canonical_misses": fp["misses"],
                "naive_misses": naive_misses,
                "hit_rate": round(hit_rate, 3),
                "hit_rate_delta_vs_order_keyed": round(
                    hit_rate - naive_rate, 3),
                "compiled_programs": sess.stats()["cache"]["size"]})


def run() -> list[Row]:
    rows = []
    msg = jnp.arange(NELEMS, dtype=jnp.float32)
    for chunks in common.DISPATCH_CHUNKS:
        # -- cold: fresh session, first send end-to-end (incl. compile)
        cold_sess = _session(fastpath=True)
        t0 = time.perf_counter_ns()
        setup_cold_us = _setup_us(cold_sess, chunks, iters=1)
        jax.block_until_ready(cold_sess.send(msg, 0, 1, max_paths=3,
                                             num_chunks=chunks))
        cold_us = (time.perf_counter_ns() - t0) / 1e3
        entry = next(iter(cold_sess.engine._fastpath._store.values()))[1]
        nodes = entry.graph.num_nodes
        counts = {"nodes": nodes, "edges": entry.graph.num_edges,
                  "chunks_per_path": chunks}

        # -- warm-key: plan-cache hits, full pipeline re-run per dispatch
        warm_sess = _session(fastpath=False)
        warm_us = _send_us(warm_sess, msg, chunks)
        setup_warm_us = _setup_us(warm_sess, chunks)

        # -- fast path: epoch-checked lookup + pooled staging + launch
        fast_sess = _session(fastpath=True)
        fast_us = _send_us(fast_sess, msg, chunks)
        setup_fast_us = _setup_us(fast_sess, chunks)
        fp = fast_sess.stats()["fastpath"]
        staging_us = fp["staging_ns"] / 1e3 / max(
            fast_sess.stats()["dispatches"], 1)

        ratio_warm = setup_warm_us / max(setup_fast_us, 1e-9)
        ratio_cold = setup_cold_us / max(setup_fast_us, 1e-9)
        rows += [
            Row(f"dispatch/nodes{nodes}/cold_first_send", cold_us,
                "first_iter", counts),
            Row(f"dispatch/nodes{nodes}/warm_key", warm_us,
                "steady_state", counts),
            Row(f"dispatch/nodes{nodes}/fastpath", fast_us,
                "steady_state",
                {**counts, "fastpath_hits": fp["hits"],
                 "staging_dispatch_us_per_launch": round(staging_us, 2)}),
            Row(f"dispatch/nodes{nodes}/setup_cold", setup_cold_us,
                "plan+lower+pass+digest+instantiate", counts),
            Row(f"dispatch/nodes{nodes}/setup_warm_key", setup_warm_us,
                "plan+memo+digest", counts),
            Row(f"dispatch/nodes{nodes}/setup_fastpath", setup_fast_us,
                f"{ratio_warm:.0f}x_vs_warm",
                {**counts,
                 "setup_speedup_vs_warm_key": round(ratio_warm, 1),
                 "setup_speedup_vs_cold": round(ratio_cold, 1)}),
        ]
    rows.append(_group_dedup_row())
    return rows


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one chunk count only (CI smoke step)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    if args.smoke:
        common.DISPATCH_CHUNKS[:] = common.DISPATCH_CHUNKS[:1]
    rows = run()
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv(), flush=True)
    if args.json:
        payload = [{"name": r.name, "us_per_call": round(r.us, 2),
                    "derived": r.derived, **r.extra} for r in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()
