"""Degraded-mode bandwidth per ladder rung + recovery digest (§4.6).

The resilience story has three measurable claims, and this module is the
CI row for each:

* **Ladder value** — degraded multipath (the surviving-routes re-plan
  after one NVLink fails) must still model MORE bandwidth than the
  single-path baseline: the whole point of re-planning instead of
  collapsing straight to one path. Rows ``faults/ladder/*`` report
  measured dispatch time plus the modeled effective bandwidth and the
  ladder level each rung runs at.
* **Exact recovery** — after ``restore_link`` + healthy probes the plan
  digest must return to its pre-fault value (``faults/recovery/digest``:
  the pre/post digests and their match ride the JSON extras).
* **Health-off costs nothing** — with ``health=False`` and no injector
  the fast-path setup stage must stay within the same bound the §2.3
  dispatch benchmark enforces (``faults/health_off/setup_fastpath``).

CI gates assert all three on the ``--smoke`` artifact.
"""

import time

from benchmarks.common import Row, timeit_us

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, CommSession
from repro.core import Topology
from repro.core.pipelining import effective_bandwidth_gbps

NELEMS = 1 << 15     # 128 KiB f32 — multipath engages, compiles stay quick
ITERS = 10


def _session(**cfg):
    cfg.setdefault("multipath_threshold", 64)
    topo = Topology.full_mesh(4)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("dev",))
    return CommSession(CommConfig(**cfg), mesh=mesh, topology=topo)


def _modeled_gbps(sess, max_paths=3) -> float:
    plan = sess.plan(0, 1, NELEMS * 4, max_paths=max_paths)
    return effective_bandwidth_gbps(plan, sess.topology)


def _send_us(sess, msg, **kw) -> float:
    return timeit_us(lambda: sess.send(msg, 0, 1, **kw),
                     iters=ITERS, warmup=2)


def _ladder_rows() -> list:
    """One dispatch-time + modeled-bandwidth row per ladder rung."""
    msg = jnp.arange(NELEMS, dtype=jnp.float32)
    rows = []

    sess = _session()
    us = _send_us(sess, msg, max_paths=3)
    rows.append(Row("faults/ladder/multipath", us,
                    f"{_modeled_gbps(sess):.1f}GB/s",
                    extra={"modeled_gbps": _modeled_gbps(sess),
                           "level": sess.stats()["health"]["ladder_level"]}))

    sess.topology.fail_link(0, 1)          # the direct NVLink dies
    us = _send_us(sess, msg, max_paths=3)
    rows.append(Row("faults/ladder/surviving", us,
                    f"{_modeled_gbps(sess):.1f}GB/s",
                    extra={"modeled_gbps": _modeled_gbps(sess),
                           "level": sess.stats()["health"]["ladder_level"]}))

    us = _send_us(sess, msg, max_paths=1)  # forced single surviving path
    rows.append(Row("faults/ladder/single", us,
                    f"{_modeled_gbps(sess, max_paths=1):.1f}GB/s",
                    extra={"modeled_gbps": _modeled_gbps(sess, max_paths=1),
                           "level": 2}))
    return rows


def _recovery_row() -> Row:
    """Fail → re-plan → restore → probe: digest must round-trip."""
    sess = _session()
    msg = jnp.arange(NELEMS, dtype=jnp.float32)
    sess.send(msg, 0, 1)
    pre = sess.describe(0, 1, NELEMS * 4)["graph"]["digest"]
    sess.topology.fail_link(0, 1)
    sess.send(msg, 0, 1)                   # degraded traffic
    sess.topology.restore_link(0, 1)
    t0 = time.perf_counter_ns()
    for _ in range(3):
        sess.probe_links()                 # healthy probes re-admit
    us = (time.perf_counter_ns() - t0) / 3 / 1e3
    post = sess.describe(0, 1, NELEMS * 4)["graph"]["digest"]
    match = (pre == post
             and sess.planner.quarantined == frozenset())
    return Row("faults/recovery/digest", us, f"match={match}",
               extra={"pre": pre, "post": post, "match": bool(match)})


def _health_off_row() -> Row:
    """Resolution-stage cost with health off — the zero-overhead gate."""
    sess = _session(health=False)
    msg = jnp.arange(NELEMS, dtype=jnp.float32)
    sess.send(msg, 0, 1)                   # populate the fast path
    eng = sess.engine
    specs = [(0, 1, NELEMS, jnp.float32)]
    t0 = time.perf_counter_ns()
    for _ in range(ITERS):
        eng._resolve(specs, window=1, max_paths=None, num_chunks=None,
                     exclusive=False, schedule=None, single=True)
    us = (time.perf_counter_ns() - t0) / ITERS / 1e3
    return Row("faults/health_off/setup_fastpath", us, "health=off")


def run() -> list:
    rows = _ladder_rows()
    rows.append(_recovery_row())
    rows.append(_health_off_row())
    return rows


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for CI-step uniformity (the chaos rows "
                         "are already smoke-sized)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    rows = run()
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv(), flush=True)
    if args.json:
        payload = [{"name": r.name, "us_per_call": round(r.us, 2),
                    "derived": r.derived, **r.extra} for r in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()
