"""Paper Fig. 6 — UCX Put Bandwidth: single-path vs multi-path vs
multi-path + compiled transfer plans (CUDA-Graph analogue).

Derived column: modeled GB/s on the Beluga-calibrated link model (the
container is CPU-only). Small sizes additionally execute for real on the
8-device host mesh (wall-clock µs) to validate the engine end-to-end.
"""

from benchmarks.common import EXEC_SIZES, MiB, Row, SIZES_PUT, timeit_us

import jax.numpy as jnp

from repro.comm import CommSession
from repro.core import Topology, effective_bandwidth_gbps


def run() -> list[Row]:
    topo = Topology.full_mesh(4)             # Beluga: 4xV100, 2 NVLink/pair
    sess = CommSession(topology=topo)
    rows = []
    for mb in SIZES_PUT:
        nbytes = mb * MiB
        configs = {
            "1path": dict(max_paths=1),
            "3path": dict(max_paths=3),
            "3path+host": dict(max_paths=4, include_host=True),
        }
        for cname, kw in configs.items():
            plan = sess.plan(0, 1, nbytes, **kw)
            for graphs in (False, True):
                bw = effective_bandwidth_gbps(plan, topo,
                                              compiled_plan=graphs)
                tag = "graph" if graphs else "nograph"
                rows.append(Row(f"put_bw/{mb}MiB/{cname}/{tag}", 0.0,
                                f"{bw:.1f}GB/s"))
    # speedup summary at the paper's headline point (>=32MB, 3 paths+host)
    base = effective_bandwidth_gbps(
        sess.plan(0, 1, 512 * MiB, max_paths=1), topo,
        compiled_plan=False)
    best = effective_bandwidth_gbps(
        sess.plan(0, 1, 512 * MiB, max_paths=4, include_host=True),
        topo, compiled_plan=True)
    rows.append(Row("put_bw/512MiB/speedup_vs_single", 0.0,
                    f"{best / base:.2f}x(paper:2.95x)"))

    # real execution on the host mesh (engine correctness + dispatch cost)
    exec_sess = CommSession(topology=Topology.full_mesh(8, with_host=False))
    for mb in EXEC_SIZES:
        nelems = mb * MiB // 4
        compiled, plan = exec_sess.compiled_for(0, 1, nelems)
        x = jnp.zeros((1, 8, nelems), jnp.float32)
        us = timeit_us(compiled.compiled, x)
        rows.append(Row(f"put_bw_exec/{mb}MiB/3path", us,
                        f"nodes={plan.num_nodes}"))
    return rows
