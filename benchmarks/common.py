"""Shared benchmark utilities. Import this FIRST in every bench module —
it pins the CPU device count before jax initializes."""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402

MiB = 1 << 20

SIZES_PUT = [1, 4, 16, 64, 128, 256, 512]          # MiB (paper Fig. 6)
SIZES_OMB = [1, 4, 8, 16, 32, 64]                  # MiB (paper Fig. 7-10)
EXEC_SIZES = [1, 4, 16]                            # MiB actually executed
#: Chunk-interleaving schedulers swept by bench_graph_overhead (the
#: ``--schedule`` axis; ``run.py --schedule NAME`` narrows it in place).
SCHEDULES = ["round_robin", "depth_first", "critical_path", "overlap",
             "auto"]
#: Per-path chunk counts swept by bench_dispatch (the node-count axis of
#: the steady-state dispatch rows; --smoke shrinks it in place).
DISPATCH_CHUNKS = [1, 4, 16]


def timeit_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter_ns()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter_ns() - t0) / iters / 1e3


class Row:
    def __init__(self, name: str, us_per_call: float, derived: str,
                 extra: dict | None = None):
        self.name = name
        self.us = us_per_call
        self.derived = derived
        #: Structured extras (e.g. graph node/edge counts) — emitted into
        #: the ``--json`` artifact rows, not the CSV stream.
        self.extra = extra or {}

    def csv(self) -> str:
        return f"{self.name},{self.us:.2f},{self.derived}"
