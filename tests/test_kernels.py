"""Per-kernel shape/dtype sweeps against the pure-jnp oracles.

Every Pallas kernel runs in TPU-interpret mode on CPU; tolerances follow
dtype (f32 tight, bf16 loose per long-reduction error)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import has_pallas_tpu_interpret_mode
from repro.core import PathPlanner, Topology

requires_remote_dma_interpret = pytest.mark.skipif(
    not has_pallas_tpu_interpret_mode(),
    reason="remote-DMA kernels need jax's typed TPU interpret mode "
           "(pltpu.InterpretParams); this jax only has plain interpret=True")

# ------------------------------ multipath DMA ------------------------------
from repro.kernels.multipath_dma import ops as dma_ops
from repro.kernels.multipath_dma import ref as dma_ref


@pytest.fixture(scope="module")
def mesh4():
    devs = jax.devices()[:4]
    return jax.sharding.Mesh(np.array(devs), ("dev",))


@pytest.mark.parametrize("nelems,paths,chunks", [
    (512, 1, 1), (512, 2, 2), (1024, 3, 4), (768, 3, 3), (2048, 2, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@requires_remote_dma_interpret
def test_dma_kernel_sweep(mesh4, nelems, paths, chunks, dtype):
    topo = Topology.full_mesh(4)
    planner = PathPlanner(topo, multipath_threshold=4)
    itemsize = jnp.dtype(dtype).itemsize
    plan = planner.plan(0, 1, nelems * itemsize, granularity=itemsize,
                        max_paths=paths, num_chunks=chunks)
    x = np.random.RandomState(0).randn(4, nelems).astype(dtype)
    got = np.asarray(dma_ops.multipath_dma_transfer(jnp.asarray(x), plan,
                                                    mesh4))
    ref = dma_ref.multipath_transfer_ref(np.asarray(x, np.float64), plan)
    np.testing.assert_array_equal(got.astype(np.float64), ref)


def test_dma_kernel_rejects_3hop(mesh4):
    topo = Topology.torus2d(2, 2)
    planner = PathPlanner(topo, multipath_threshold=4)
    plan = planner.plan(0, 1, 1024, granularity=4, max_paths=3)
    if any(p.route.num_hops > 2 for p in plan.paths):
        from repro.kernels.multipath_dma.kernel import build_multipath_dma
        with pytest.raises(NotImplementedError):
            build_multipath_dma(plan, 256, jnp.float32, 4)


# ------------------------------ flash attention ----------------------------
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 2, 256, 64), (2, 4, 4, 128, 32), (1, 8, 2, 200, 64),
    (1, 2, 1, 384, 128),
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (True, 64), (False, None),
])
def test_flash_attention_sweep(b, hq, hkv, s, d, causal, window):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, hq, s, d).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.randn(b, hkv, s, d).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.randn(b, hkv, s, d).astype(np.float32))
    got = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    ref = fa_ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-5, rtol=1e-4)


def test_flash_attention_bf16():
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 4, 128, 64), jnp.bfloat16) * 0.3
    k = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16) * 0.3
    v = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    got = fa_ops.flash_attention(q, k, v, causal=True)
    ref = fa_ref.attention_ref(q, k, v, causal=True)
    err = np.max(np.abs(np.asarray(got, np.float32)
                        - np.asarray(ref, np.float32)))
    assert err < 2e-2


# -------------------------------- jacobi -----------------------------------
from repro.kernels.jacobi import ops as j_ops
from repro.kernels.jacobi import ref as j_ref


@pytest.mark.parametrize("rows,w,tile", [
    (8, 1024, 512), (8, 700, 512), (16, 256, 128), (8, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_jacobi_sweep(rows, w, tile, dtype):
    ext = jnp.asarray(
        np.random.RandomState(2).randn(rows, w + 2), dtype)
    got = j_ops.jacobi_sweep(ext, tile=tile)
    ref = j_ref.jacobi_sweep_ref(ext)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


# ------------------------------- rwkv6 scan --------------------------------
from repro.kernels.rwkv6_scan import ops as r_ops
from repro.kernels.rwkv6_scan import ref as r_ref


@pytest.mark.parametrize("bh,s,dk,dv,chunk", [
    (2, 128, 32, 32, 32), (1, 200, 64, 64, 64), (4, 64, 16, 32, 16),
    (1, 96, 8, 8, 32),
])
def test_rwkv6_sweep(bh, s, dk, dv, chunk):
    rng = np.random.RandomState(3)
    r = jnp.asarray(rng.randn(bh, s, dk).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.randn(bh, s, dk).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.randn(bh, s, dv).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.85, 0.999, (bh, s, dk)).astype(np.float32))
    u = jnp.asarray(rng.randn(bh, dk).astype(np.float32)) * 0.3
    got = r_ops.rwkv6_scan(r, k, v, w, u, chunk=chunk)
    ref = r_ref.rwkv6_scan_ref(r, k, v, w, u)
    scale = np.max(np.abs(np.asarray(ref))) + 1e-9
    err = np.max(np.abs(np.asarray(got) - np.asarray(ref))) / scale
    assert err < 1e-4


# --------------------------- ring all-gather -------------------------------
from repro.kernels.ring_allgather import ops as ag_ops


@pytest.mark.parametrize("n", [4, 8])
@pytest.mark.parametrize("rows,f", [(8, 128), (4, 64), (8, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@requires_remote_dma_interpret
def test_ring_allgather_sweep(n, rows, f, dtype):
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n]), ("dev",))
    x = jnp.asarray(np.random.RandomState(0).randn(n * rows, f), dtype)
    got = np.asarray(ag_ops.ring_allgather(x, mesh))
    np.testing.assert_array_equal(got, np.asarray(x))
