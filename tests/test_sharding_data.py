"""Sharding-rule validity across all archs × production meshes + data
pipeline determinism + pipeline parallelism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh, set_mesh

from repro.configs import REGISTRY, load_all
from repro.data import DataConfig, SyntheticDataset
from repro.models import transformer as tfm
from repro.optim import OptimConfig
from repro.training import sharding as shd

load_all()
ALL = sorted(REGISTRY)

SINGLE = abstract_mesh((16, 16), ("data", "model"))
MULTI = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_specs(specs, shapes, mesh):
    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_a, _ = jax.tree_util.tree_flatten(shapes)
    assert len(flat_s) == len(flat_a)
    for spec, leaf in zip(flat_s, flat_a):
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert leaf.shape[i] % n == 0, (spec, leaf.shape)


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("name", ALL)
def test_param_specs_divisible(name, mesh):
    cfg = REGISTRY[name]
    abstract = tfm.param_shapes(cfg)
    specs = shd.param_specs(cfg, mesh, abstract)
    _check_specs(specs, abstract, mesh)


@pytest.mark.parametrize("name", ["llama3_8b", "kimi_k2_1t_a32b",
                                  "rwkv6_1_6b", "hymba_1_5b"])
def test_cache_specs_divisible(name):
    cfg = REGISTRY[name]
    spec = tfm.cache_spec(cfg, max_len=32768, kv_chunks=16)
    shapes = tfm.cache_shapes(cfg, 128, spec)
    specs = shd.cache_specs(cfg, SINGLE, shapes, 128)
    _check_specs(specs, shapes, SINGLE)


def test_tp_sharding_present_for_llama():
    cfg = REGISTRY["llama3_8b"]
    specs = shd.param_specs(cfg, SINGLE, tfm.param_shapes(cfg))
    wq = specs["layers"]["attn"]["wq"]
    assert "model" in jax.tree_util.tree_leaves(
        [wq], is_leaf=lambda x: isinstance(x, P))[0]
    assert specs["embed"][0] == "model"      # vocab sharded


def test_moe_ep_vs_tp_rule():
    kimi = REGISTRY["kimi_k2_1t_a32b"]       # 384 experts: EP
    mixtral = REGISTRY["mixtral_8x22b"]      # 8 experts: expert-TP
    sk = shd.param_specs(kimi, SINGLE, tfm.param_shapes(kimi))
    sm = shd.param_specs(mixtral, SINGLE, tfm.param_shapes(mixtral))
    assert sk["layers"]["moe"]["w1"][1] == "model"         # E sharded
    assert sm["layers"]["moe"]["w1"][1] is None            # E replicated
    assert sm["layers"]["moe"]["w1"][3] == "model"         # ff sharded


# ------------------------------- data --------------------------------------
def test_data_deterministic():
    cfg = REGISTRY["smollm_360m"].reduced()
    ds = SyntheticDataset(cfg, DataConfig(seq_len=16, global_batch=4,
                                          seed=3))
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    c = ds.batch_at(6)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_audio_batch_shape():
    cfg = REGISTRY["hubert_xlarge"].reduced()
    ds = SyntheticDataset(cfg, DataConfig(seq_len=8, global_batch=2))
    b = ds.batch_at(0)
    assert b["features"].shape == (2, 8, cfg.frontend_dim)


def test_prefetch_loader_order():
    from repro.data import PrefetchLoader
    cfg = REGISTRY["smollm_360m"].reduced()
    ds = SyntheticDataset(cfg, DataConfig(seq_len=8, global_batch=2))
    loader = PrefetchLoader(ds, start_step=3, prefetch=2)
    steps = [next(loader)[0] for _ in range(4)]
    loader.close()
    assert steps == [3, 4, 5, 6]


# ------------------------- pipeline parallelism ----------------------------
def test_pipeline_parallel_matches_sequential():
    from repro.training.pipeline import pipeline_apply
    n_stages, m, mb, d = 4, 6, 3, 8
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:n_stages]), ("pipe",))
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(n_stages, d, d).astype(np.float32)) * 0.3
    x = jnp.asarray(rng.randn(m, mb, d).astype(np.float32))

    def stage_fn(wl, h):
        return jnp.tanh(h @ wl)

    ref = x
    for i in range(n_stages):
        ref = jnp.tanh(ref @ w[i])

    for multipath in (False, True):
        got = pipeline_apply(stage_fn, w, x, mesh, microbatches=m,
                             multipath=multipath)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)


# ----------------------- sharded serve integration -------------------------
@pytest.mark.parametrize("name", ["llama3_8b", "rwkv6_1_6b"])
def test_decode_step_sharded_matches_unsharded(name, dp_tp_mesh):
    """decode_step under a (data=2, model=4) mesh with launcher cache
    shardings must be numerically identical to the single-device path."""
    import dataclasses
    from jax.sharding import NamedSharding
    cfg = dataclasses.replace(REGISTRY[name].reduced(), capacity_factor=8.0)
    params = tfm.init_params(jax.random.key(0), cfg)
    b, s = 4, 8
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    spec = tfm.cache_spec(cfg, max_len=s, kv_chunks=4)
    # unsharded reference
    cache_ref = tfm.init_cache(cfg, b, spec)
    logits_ref = []
    for t in range(s):
        lg, cache_ref = tfm.decode_step(params, cfg, cache_ref,
                                        toks[:, t:t + 1], jnp.int32(t),
                                        spec)
        logits_ref.append(lg)
    # sharded run
    cache = tfm.init_cache(cfg, b, spec)
    c_specs = shd.cache_specs(cfg, dp_tp_mesh,
                              jax.eval_shape(lambda: cache), b)
    cache = jax.device_put(cache, jax.tree.map(
        lambda sp: NamedSharding(dp_tp_mesh, sp), c_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    with set_mesh(dp_tp_mesh):
        step = jax.jit(lambda c, t, i: tfm.decode_step(
            params, cfg, c, t, i, spec))
        for t in range(s):
            lg, cache = step(cache, toks[:, t:t + 1], jnp.int32(t))
            np.testing.assert_allclose(
                np.asarray(lg, np.float32),
                np.asarray(logits_ref[t], np.float32), atol=2e-3)


@pytest.mark.parametrize("name", ["llama3_8b", "mixtral_8x22b"])
def test_train_step_sharded_matches_unsharded(name, dp_tp_mesh):
    """One sharded train step (full launcher shardings) equals the
    single-device step to numerical tolerance."""
    import dataclasses
    from repro.optim import OptimConfig
    from repro.training import (TrainStepConfig, init_state,
                                make_train_step, state_shardings)
    cfg = dataclasses.replace(REGISTRY[name].reduced(), capacity_factor=8.0)
    opt = OptimConfig(learning_rate=1e-3, warmup_steps=1, total_steps=5)
    ds_batch = {
        "tokens": jax.random.randint(jax.random.key(2), (4, 16), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(3), (4, 16), 0,
                                     cfg.vocab_size),
        "mask": jnp.ones((4, 16), jnp.float32),
    }
    step = make_train_step(cfg, TrainStepConfig(), opt)
    s_ref, m_ref = jax.jit(step)(init_state(cfg, opt, seed=7), ds_batch)
    with set_mesh(dp_tp_mesh):
        state = init_state(cfg, opt, mesh=dp_tp_mesh, seed=7)
        s_got, m_got = jax.jit(step)(state, ds_batch)
    assert abs(float(m_got["loss"]) - float(m_ref["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(s_ref["params"]),
                    jax.tree.leaves(s_got["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
