"""Unit + property tests for topology, route enumeration, and planning."""

import pytest

from repro.core import (HOST, PathPlanner, estimate_transfer_time_s,
                        validate_plan)

MiB = 1 << 20

# beluga4 / torus4x4 topologies come from the shared fixture library
# in conftest.py.


def test_full_mesh_links_aggregate(beluga4):
    # two 25 GB/s sublinks aggregate to one 50 GB/s logical link
    assert beluga4.link(0, 1).bandwidth_gbps == pytest.approx(50.0)
    assert beluga4.link(0, HOST).kind == "pcie"


def test_route_enumeration_direct_first(beluga4):
    planner = PathPlanner(beluga4)
    routes = planner.enumerate_routes(0, 1)
    assert routes[0].kind == "direct"
    assert {r.via for r in routes[1:]} == {2, 3}


def test_route_enumeration_host(beluga4):
    planner = PathPlanner(beluga4)
    routes = planner.enumerate_routes(0, 1, include_host=True)
    assert routes[-1].kind == "staged_host"   # host sorts last (lowest bw)


def test_torus_routes(torus4x4):
    planner = PathPlanner(torus4x4)
    # neighbours (0, 1): direct + 2-hop staged routes exist
    routes = planner.enumerate_routes(0, 1)
    assert routes[0].kind == "direct"
    assert len(routes) >= 2


def test_small_message_single_path(beluga4):
    planner = PathPlanner(beluga4)   # threshold 2 MiB (paper §5.3)
    plan = planner.plan(0, 1, 1 * MiB)
    assert plan.num_paths == 1
    assert plan.paths[0].route.kind == "direct"


def test_large_message_multipath(beluga4):
    planner = PathPlanner(beluga4)
    plan = planner.plan(0, 1, 64 * MiB, max_paths=3)
    assert plan.num_paths == 3
    validate_plan(plan)


def test_shares_proportional_to_bandwidth(beluga4):
    planner = PathPlanner(beluga4)
    plan = planner.plan(0, 1, 64 * MiB, max_paths=4, include_host=True)
    # host share must be the smallest (12 vs 50 GB/s routes)
    host = [p for p in plan.paths if p.route.via == HOST]
    others = [p for p in plan.paths if p.route.via != HOST]
    assert host and all(host[0].nbytes < o.nbytes for o in others)


def test_plan_rejects_bad_granularity(beluga4):
    planner = PathPlanner(beluga4)
    with pytest.raises(ValueError):
        planner.plan(0, 1, 10 * MiB + 1, granularity=4)


def test_tuner_prefers_multipath_for_large(beluga4):
    planner = PathPlanner(beluga4)
    best = planner.tune(0, 1, 128 * MiB)
    assert best.num_paths >= 2
    t_single = estimate_transfer_time_s(
        planner.plan(0, 1, 128 * MiB, max_paths=1), beluga4)
    t_best = estimate_transfer_time_s(best, beluga4)
    assert t_best < t_single


def test_tuner_prefers_single_path_for_tiny(beluga4):
    planner = PathPlanner(beluga4, multipath_threshold=0)
    best = planner.tune(0, 1, 64 * 1024,
                        chunk_counts=(1, 2, 4),
                        path_counts=(1, 2, 3))
    assert best.num_paths == 1   # launch overhead dominates


def test_env_overrides(monkeypatch, beluga4):
    monkeypatch.setenv("REPRO_MP_MAX_PATHS", "2")
    monkeypatch.setenv("REPRO_MP_CHUNK_BYTES", str(2 * MiB))
    planner = PathPlanner(beluga4)
    assert planner.max_paths == 2
    assert planner.chunk_bytes == 2 * MiB
    plan = planner.plan(0, 1, 64 * MiB)
    assert plan.num_paths == 2
