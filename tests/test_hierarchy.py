"""Hierarchical (multi-island) topology, planning, and collectives tests.

Covers the DESIGN §3.1 surface end-to-end: island queries and validation
on :meth:`Topology.hierarchical`, the planner's staged cross-island
routing (§4.5 link-disjointness across tiers), the node-boundary
digest/epoch regression (identical links, different islands must never
cross-serve cached plans), the two-level collective decomposition and
its §4.4 tier model, and the launch-spec resolution for the multi-pod
arch configs.
"""

import pytest

import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P

import jax

from repro.comm import (CommConfig, CommSession, FastPathCache,
                        PathPlanner, modeled_all_reduce_s,
                        select_all_reduce_strategy, tier_bandwidths_gbps,
                        two_level_all_reduce)
from repro.comm.cache import FastPathEntry
from repro.comm.config import COLLECTIVE_STRATEGIES
from repro.compat import make_mesh, shard_map
from repro.core import HOST, Link, Topology, validate_plan

MiB = 1 << 20


# -- topology: island queries and validation --------------------------------

def test_hierarchical_construction(two_island):
    assert two_island.num_devices == 8
    assert two_island.num_islands == 2
    assert two_island.islands() == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert [two_island.node_of(d) for d in range(8)] == [0] * 4 + [1] * 4
    assert two_island.egress_devices(0) == (0,)
    assert two_island.egress_devices(1) == (4,)
    assert two_island.is_inter_island(0, 4)
    assert not two_island.is_inter_island(0, 3)
    # HOST belongs to no island: host hops are never inter-island
    assert not two_island.is_inter_island(0, HOST)


def test_flat_topology_is_one_island(mesh4):
    assert mesh4.num_islands == 1
    assert mesh4.islands() == ((0, 1, 2, 3),)
    assert not mesh4.is_inter_island(0, 1)


def test_node_of_rejects_host_and_out_of_range(two_island):
    with pytest.raises(ValueError):
        two_island.node_of(HOST)
    with pytest.raises(ValueError):
        two_island.node_of(8)


def test_hierarchical_validation_errors():
    with pytest.raises(ValueError, match="num_islands"):
        Topology.hierarchical(0, 4)
    with pytest.raises(ValueError, match="egress_per_island"):
        Topology.hierarchical(2, 4, egress_per_island=5)
    with pytest.raises(ValueError, match="torus_shape"):
        Topology.hierarchical(2, 4, intra="torus", torus_shape=(4, 4))
    with pytest.raises(ValueError, match="intra"):
        Topology.hierarchical(2, 4, intra="ring")
    with pytest.raises(ValueError, match="node_assignment"):
        Topology(4, [Link(0, 1, "nvlink", 25.0)], node_assignment=(0, 1))


def test_node_assignment_in_digest_and_epoch():
    """SATELLITE regression: identical links, different node boundaries
    must yield distinct digests and distinct planner epochs — plans and
    fast-path entries for one island layout never serve the other."""
    links = [Link(a, b, "nvlink", 25.0)
             for a in range(4) for b in range(4) if a != b]
    flat = Topology(4, links, name="same")
    split = Topology(4, links, name="same", node_assignment=(0, 0, 1, 1))
    assert flat.digest() != split.digest()
    assert PathPlanner(flat).epoch != PathPlanner(split).epoch
    # and reassigning boundaries in place bumps the epoch + digest
    epoch0, digest0 = flat.epoch, flat.digest()
    flat.set_node_assignment((0, 1, 1, 1))
    assert flat.epoch != epoch0
    assert flat.digest() != digest0
    flat.set_node_assignment(None)          # flatten back to one island
    assert flat.num_islands == 1
    assert flat.digest() == digest0


def test_fastpath_entry_not_served_across_node_reassignment(mesh4):
    """A fast-path entry stamped under one island layout is invalidated
    (not served) after ``set_node_assignment`` bumps the epoch."""
    planner = PathPlanner(mesh4)
    cache = FastPathCache(capacity=4)
    entry = FastPathEntry(plans=(), graph=None, digest="d", key="k",
                          compiled=None, schedule="round_robin")
    cache.put("sig", planner.epoch, entry)
    assert cache.get("sig", planner.epoch) is entry
    mesh4.set_node_assignment((0, 0, 1, 1))
    assert cache.get("sig", planner.epoch) is None
    assert cache.invalidations == 1


# -- planner: staged cross-island routing ------------------------------------

def test_intra_island_routes_avoid_inter_links(two_island):
    planner = PathPlanner(two_island)
    for src, dst in ((0, 3), (1, 2), (5, 7)):
        for route in planner.enumerate_routes(src, dst):
            for a, b in route.directional_links():
                assert not two_island.is_inter_island(a, b), (route, a, b)


def test_cross_island_routes_have_one_inter_hop(two_island):
    planner = PathPlanner(two_island)
    routes = planner.cross_island_routes(1, 7)
    assert routes
    for route in routes:
        inter = [(a, b) for a, b in route.directional_links()
                 if two_island.is_inter_island(a, b)]
        assert len(inter) == 1
        assert inter[0] == (0, 4)          # the single egress pair


def test_cross_island_plan_link_disjoint(two_island):
    planner = PathPlanner(two_island, multipath_threshold=256)
    plan = planner.plan(1, 7, 8 * MiB, max_paths=4)
    validate_plan(plan)                    # §4.5 link exclusivity
    for pa in plan.paths:
        inter = [lk for lk in pa.route.directional_links()
                 if two_island.is_inter_island(*lk)]
        assert len(inter) == 1


def test_cross_island_multipath_uses_multiple_egress():
    topo = Topology.hierarchical(2, 4, egress_per_island=2, name="egress2")
    planner = PathPlanner(topo, multipath_threshold=256)
    plan = planner.plan(2, 6, 8 * MiB, max_paths=4)
    inter_links = {lk for pa in plan.paths
                   for lk in pa.route.directional_links()
                   if topo.is_inter_island(*lk)}
    assert inter_links == {(0, 4), (1, 5)}


def test_plan_group_across_tiers(two_island):
    """``plan_group`` keeps link-exclusive claiming across tiers: one
    cross-island and one intra-island message share no directional link."""
    planner = PathPlanner(two_island, multipath_threshold=256)
    group = planner.plan_group([(1, 7, 4 * MiB), (2, 3, 4 * MiB)],
                               exclusive=True)
    assert group.exclusive
    claimed: set = set()
    for plan in group.plans:
        for pa in plan.paths:
            for lk in pa.route.directional_links():
                assert lk not in claimed
                claimed.add(lk)


# -- collectives: tier model + two-level decomposition -----------------------

def test_tier_bandwidths(two_island, mesh4):
    intra, inter = tier_bandwidths_gbps(two_island)
    assert intra == pytest.approx(50.0)    # 2 × 25 NVLink sublinks
    assert inter == pytest.approx(12.5)
    intra, inter = tier_bandwidths_gbps(mesh4)
    assert inter is None


def test_two_level_models_strictly_faster_on_two_islands(two_island):
    """ISSUE acceptance: on the 2-island × 4-GPU topology the two-level
    all-reduce must model *strictly* faster than the flat ring."""
    for mb in (1, 8, 64):
        flat = modeled_all_reduce_s(two_island, mb * MiB, strategy="flat")
        two = modeled_all_reduce_s(two_island, mb * MiB,
                                   strategy="two_level")
        assert two < flat, (mb, two, flat)


def test_select_strategy_auto_and_forced(two_island, mesh4):
    chosen, times = select_all_reduce_strategy(two_island, 8 * MiB)
    assert chosen == "two_level"
    assert times["two_level"] < times["flat"]
    chosen, _ = select_all_reduce_strategy(two_island, 8 * MiB,
                                           strategy="flat")
    assert chosen == "flat"
    # single island: nothing to decompose — auto resolves flat
    chosen, times = select_all_reduce_strategy(mesh4, 8 * MiB)
    assert chosen == "flat"
    assert times["two_level"] == times["flat"]


def test_two_level_all_reduce_matches_joint_psum():
    mesh = make_mesh((2, 4), ("pod", "dev"))
    x = jnp.asarray(np.random.RandomState(0).randn(16, 64), jnp.float32)
    two = jax.jit(shard_map(
        partial(two_level_all_reduce, inter_axis="pod", intra_axis="dev"),
        mesh=mesh, in_specs=P("dev"), out_specs=P("dev"), check_vma=False))
    ref = jax.jit(shard_map(
        lambda v: jax.lax.psum(v, ("pod", "dev")),
        mesh=mesh, in_specs=P("dev"), out_specs=P("dev"), check_vma=False))
    np.testing.assert_allclose(np.asarray(two(x)), np.asarray(ref(x)),
                               rtol=1e-5)


# -- session + config + launch ----------------------------------------------

def test_describe_reports_hierarchy(two_island):
    sess = CommSession(CommConfig(multipath_threshold=256),
                       topology=two_island)
    d = sess.describe(1, 7, 8 * MiB)
    h = d["hierarchy"]
    assert h["islands"] == 2
    assert (h["src_island"], h["dst_island"]) == (0, 1)
    assert h["cross_island"]
    ar = h["all_reduce"]
    assert ar["chosen"] == "two_level"
    assert ar["delta_two_level_vs_flat_s"] == pytest.approx(
        ar["two_level_time_s"] - ar["flat_time_s"])
    assert ar["delta_two_level_vs_flat_s"] < 0     # modeled improvement
    d = sess.describe(1, 3, 8 * MiB)
    assert not d["hierarchy"]["cross_island"]


def test_describe_flat_topology_has_no_all_reduce_section(mesh4):
    sess = CommSession(CommConfig(multipath_threshold=256), topology=mesh4)
    h = sess.describe(0, 1, 8 * MiB)["hierarchy"]
    assert h["islands"] == 1
    assert "all_reduce" not in h


def test_collective_strategy_config(monkeypatch):
    assert CommConfig().collective_strategy == "auto"
    for s in COLLECTIVE_STRATEGIES:
        assert CommConfig(collective_strategy=s).collective_strategy == s
    with pytest.raises(ValueError, match="collective strategy"):
        CommConfig(collective_strategy="tree")
    monkeypatch.setenv("REPRO_MP_COLLECTIVES", "two_level")
    assert CommConfig.from_env().collective_strategy == "two_level"


def test_multi_pod_launch_specs_resolve_island_aware_meshes():
    """ISSUE acceptance: the kimi/nemotron specs resolve 2-pod meshes and
    hierarchical topologies; smaller archs stay on the flat pod."""
    from repro.configs import get_config, load_all
    from repro.launch.mesh import production_launch_spec

    load_all()
    for arch_name in ("kimi_k2_1t_a32b", "nemotron_4_340b"):
        spec = production_launch_spec(get_config(arch_name))
        assert spec["multi_pod"], arch_name
        assert spec["mesh_shape"] == (2, 16, 16)
        assert spec["mesh_axes"] == ("pod", "data", "model")
        assert spec["topology"].num_islands == 2
        assert spec["topology"].num_devices == 512
    spec = production_launch_spec(get_config("llama3_8b"))
    assert not spec["multi_pod"]
    assert spec["mesh_shape"] == (16, 16)
    assert spec["topology"].num_islands == 1
