"""Checkpointing, elastic restore, and fault-tolerance runtime."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_checkpoint,
                              restore_checkpoint, save_checkpoint)
from repro.runtime import (HeartbeatMonitor, ResilientLoopConfig,
                           ResilientTrainLoop, StragglerDetector)


def _state(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "params": {"w": jnp.asarray(rng.randn(8, 16), jnp.float32),
                   "b": jnp.asarray(rng.randn(16), jnp.float32)},
        "opt": {"m": {"w": {"q": jnp.ones((8, 16), jnp.int8),
                            "scale": jnp.float32(0.5)}},
                "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    state = _state()
    path = save_checkpoint(str(tmp_path), 7, state)
    restored, step, _ = restore_checkpoint(
        path, jax.eval_shape(lambda: state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corruption_detection(tmp_path):
    state = _state()
    path = save_checkpoint(str(tmp_path), 1, state)
    victim = next(f for f in os.listdir(path) if f.endswith(".npy"))
    fn = os.path.join(path, victim)
    data = bytearray(open(fn, "rb").read())
    data[-1] ^= 0xFF
    open(fn, "wb").write(bytes(data))
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(path, jax.eval_shape(lambda: state))


def test_keep_last_k_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    state = _state()
    for s in (10, 20, 30):
        mgr.save(s, state)
    mgr.wait()
    mgr._gc()
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000020", "step_00000030"]


def test_elastic_restore_new_sharding(tmp_path, dp_tp_mesh):
    """Save replicated, restore sharded onto a different layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = {"w": jnp.asarray(np.random.RandomState(0).randn(8, 16),
                              jnp.float32)}
    path = save_checkpoint(str(tmp_path), 5, state)
    shard = {"w": NamedSharding(dp_tp_mesh, P("data", "model"))}
    restored, step, _ = restore_checkpoint(
        path, jax.eval_shape(lambda: state), shardings=shard)
    assert step == 5
    assert restored["w"].sharding.spec == P("data", "model")
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


# ----------------------------- runtime ------------------------------------
def test_heartbeat_monitor():
    t = [0.0]
    mon = HeartbeatMonitor(["w0", "w1"], timeout_s=5.0,
                           clock=lambda: t[0])
    t[0] = 3.0
    mon.beat("w0")
    t[0] = 6.0
    failed = mon.check()
    assert failed == ["w1"]
    assert mon.alive() == ["w0"]
    assert mon.check() == []          # only reported once


def test_straggler_detector():
    det = StragglerDetector(window=16, factor=3.0)
    for i in range(12):
        assert not det.observe(i, 1.0)
    assert det.observe(12, 10.0)      # 10x median flagged
    assert det.flagged[0][0] == 12


def test_resilient_loop_elastic_restart(tmp_path):
    """Train, kill at step 6 (8→4 devices), resume from checkpoint, and
    verify the loss stream continues deterministically."""
    from repro.configs import REGISTRY, load_all
    from repro.data import DataConfig, SyntheticDataset
    from repro.optim import OptimConfig
    from repro.training import (TrainStepConfig, init_state,
                                make_train_step, state_shardings)
    load_all()
    cfg = REGISTRY["smollm_360m"].reduced()
    opt = OptimConfig(learning_rate=1e-3, warmup_steps=2, total_steps=20)
    ds = SyntheticDataset(cfg, DataConfig(seq_len=16, global_batch=4))

    def build(num_devices, ckpt):
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:num_devices]).reshape(1, -1),
            ("data", "model"))
        step_fn = jax.jit(make_train_step(cfg, TrainStepConfig(), opt))
        state = init_state(cfg, opt)
        restored = ckpt.restore_latest(jax.eval_shape(lambda: state))
        if restored is not None:
            state = restored[0]
        return (step_fn, state,
                lambda s: {k: jnp.asarray(v)
                           for k, v in ds.batch_at(s).items()})

    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    loop = ResilientTrainLoop(ckpt, ResilientLoopConfig(checkpoint_every=5))
    state, losses, events = loop.run(build, total_steps=12,
                                     fail_at={6: 4})
    kinds = [e["kind"] for e in events]
    assert "failure" in kinds and "checkpoint" in kinds
    assert len(losses) >= 12          # step 5 replayed after restart
    assert int(jax.device_get(state["opt"]["step"])) == 12
    assert all(np.isfinite(losses))
