"""Tests for the 2-D pipelining engine and the analytic time model —
these encode the paper's measured effects as executable assertions."""

import pytest

from repro.core import (HOST, PathPlanner, Topology, build_schedule,
                        effective_bandwidth_gbps, estimate_transfer_time_s,
                        launch_overhead_ns, windowed_bandwidth_gbps)

MiB = 1 << 20


@pytest.fixture
def topo():
    return Topology.full_mesh(4)


@pytest.fixture
def planner(topo):
    return PathPlanner(topo)


def test_schedule_round_robin(planner):
    plan = planner.plan(0, 1, 32 * MiB, max_paths=2, num_chunks=4)
    sched = build_schedule(plan)
    # first wave hits every path once before any second chunk
    first_wave = [t.path_idx for t in sched[:plan.num_paths]]
    assert sorted(first_wave) == list(range(plan.num_paths))
    # per-path chunk order is increasing
    per_path = {}
    for t in sched:
        assert t.chunk_idx == per_path.get(t.path_idx, 0)
        per_path[t.path_idx] = t.chunk_idx + 1


def test_multipath_speedup_matches_paper_band(planner, topo):
    """Paper Fig. 6: 3 paths reach 2.5–3× over single path at ≥32 MB."""
    big = 64 * MiB
    t1 = estimate_transfer_time_s(planner.plan(0, 1, big, max_paths=1),
                                  topo)
    t3 = estimate_transfer_time_s(planner.plan(0, 1, big, max_paths=3),
                                  topo)
    assert 2.0 < t1 / t3 < 3.2


def test_host_path_marginal_unidirectional(planner, topo):
    """Paper §5.2 obs. 3: host path adds ≤15% on top of 3 GPU paths."""
    big = 64 * MiB
    t3 = estimate_transfer_time_s(planner.plan(0, 1, big, max_paths=3),
                                  topo)
    t4 = estimate_transfer_time_s(
        planner.plan(0, 1, big, max_paths=4, include_host=True), topo)
    assert t4 <= t3 * 1.001
    assert t3 / t4 < 1.15


def test_host_path_hurts_bidirectional(planner, topo):
    """Paper §5.3 obs. 6: both directions share host capacity — the host
    path degrades BIBW while GPU-only multipath does not."""
    big = 64 * MiB
    fwd_gpu = planner.plan(0, 1, big, max_paths=3)
    rev_gpu = planner.plan(1, 0, big, max_paths=3)
    t_gpu = estimate_transfer_time_s(fwd_gpu, topo,
                                     concurrent_plans=[rev_gpu])
    fwd_h = planner.plan(0, 1, big, max_paths=4, include_host=True)
    rev_h = planner.plan(1, 0, big, max_paths=4, include_host=True)
    t_host = estimate_transfer_time_s(fwd_h, topo,
                                      concurrent_plans=[rev_h])
    # per-message time with host staging under bidirectional load is worse
    assert t_host > t_gpu * 0.999


def test_compiled_plan_launch_cheaper(planner):
    plan = planner.plan(0, 1, 128 * MiB, max_paths=3)
    no_graph = launch_overhead_ns(plan, compiled_plan=False)
    graph = launch_overhead_ns(plan, compiled_plan=True)
    assert graph < no_graph


def test_first_iteration_instantiation_dominates(planner):
    """Paper Fig. 13: first-iteration cost is dominated by instantiation
    and grows with node count."""
    small = planner.plan(0, 1, 4 * MiB, max_paths=2, num_chunks=2)
    big = planner.plan(0, 1, 256 * MiB, max_paths=3, num_chunks=8)
    first_small = launch_overhead_ns(small, compiled_plan=True,
                                     first_iteration=True)
    first_big = launch_overhead_ns(big, compiled_plan=True,
                                   first_iteration=True)
    steady_big = launch_overhead_ns(big, compiled_plan=True)
    assert first_big > first_small
    assert first_big > 10 * steady_big


def test_window_size_effect(planner, topo):
    """Paper §5.3 obs. 2/3: BW grows with window size, and compiled plans
    benefit more at larger windows."""
    plan = planner.plan(0, 1, 8 * MiB, max_paths=3)
    bw = {}
    for w in (1, 4, 16):
        bw[w] = windowed_bandwidth_gbps(plan, topo, window=w,
                                        compiled_plan=True)
    assert bw[1] < bw[4] <= bw[16]
    nog = windowed_bandwidth_gbps(plan, topo, window=16,
                                  compiled_plan=False)
    assert bw[16] >= nog


def test_small_message_graph_overhead_negates(planner, topo):
    """Paper §5.3 obs. 4: below ~8 MB the launch overhead negates the
    multipath gain — single-path no-graph beats small multipath graphs."""
    small = 256 * 1024
    single = PathPlanner(topo, multipath_threshold=2 * MiB).plan(
        0, 1, small)
    t_single = estimate_transfer_time_s(single, topo, compiled_plan=False)
    forced = PathPlanner(topo, multipath_threshold=0).plan(
        0, 1, small, max_paths=3, num_chunks=8)
    t_forced_first = estimate_transfer_time_s(
        forced, topo, compiled_plan=True, first_iteration=True)
    assert t_forced_first > t_single


def test_bandwidth_below_aggregate_limit(planner, topo):
    plan = planner.plan(0, 1, 256 * MiB, max_paths=3)
    bw = effective_bandwidth_gbps(plan, topo)
    agg = sum(p.route.bottleneck_gbps for p in plan.paths)
    assert bw < agg
    assert bw > 0.5 * agg
