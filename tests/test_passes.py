"""Graph-pass pipeline: scheduler semantics, the §2.2 pass contract,
post-pass cache keys, and the per-scheduler equal-graph acceptance.

Acceptance criteria exercised here (ISSUE 4):

* ``round_robin`` scheduled graph is node-for-node identical (same
  digest, same object) to today's lowering,
* ``depth_first`` and ``critical_path`` outputs pass every §4.5
  invariant while digesting apart from the baseline,
* ``auto`` never selects a schedule the model scores worse than
  ``round_robin``,
* ``GroupKey`` incorporates the POST-pass digest: two schedules of the
  same plan get distinct cache entries and never cross-serve
  executables,
* traced ``ppermute`` count == scheduled ``graph.num_nodes`` for every
  shipped scheduler.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommConfig, CommSession, PathPlanner,
                        SCHEDULE_NAMES, TransferPlanCache)
from repro.comm.graph import DepEdge, TransferGraph, lower
from repro.comm.passes import (AutoSchedule, CriticalPathSchedule,
                               DepthFirstSchedule, RoundRobinSchedule,
                               apply_schedule, check_pass, make_schedule,
                               reindex, run_pipeline)
from repro.core import Topology, scheduled_time_s

MiB = 1 << 20
CONCRETE = ("round_robin", "depth_first", "critical_path")


@pytest.fixture(scope="module")
def topo():
    return Topology.full_mesh(8, with_host=False, name="mesh8")


@pytest.fixture(scope="module")
def planner(topo):
    return PathPlanner(topo, multipath_threshold=256)


@pytest.fixture(scope="module")
def plan(planner):
    # Multi-path, multi-chunk, uneven size: orders genuinely differ and
    # the remainder chunk gives critical_path something to move.
    return planner.plan(0, 1, 8 * MiB + 12_288, max_paths=3, num_chunks=4,
                        granularity=4)


# ------------------------- scheduler semantics ------------------------------

def test_round_robin_is_todays_lowering(plan):
    """ACCEPTANCE: round_robin == today's lowering, node-for-node."""
    for window in (1, 3):
        graph = lower(plan, window)
        scheduled, chosen = apply_schedule(graph, "round_robin")
        assert chosen == "round_robin"
        assert scheduled is graph                  # identity, not a copy
        assert scheduled.digest() == graph.digest()


@pytest.mark.parametrize("name", ["depth_first", "critical_path"])
def test_reordering_passes_preserve_invariants(plan, topo, name):
    """ACCEPTANCE: depth_first / critical_path pass all §4.5 invariants
    on the scheduled graph and keep the node multiset intact."""
    graph = lower(plan, 2)
    scheduled, _ = apply_schedule(graph, name, topo)
    scheduled.validate({0: plan.nbytes})           # §4.5 on the output
    assert scheduled.num_nodes == graph.num_nodes
    assert scheduled.num_edges == graph.num_edges
    assert (sorted(map(dataclasses.astuple, scheduled.nodes))
            == sorted(map(dataclasses.astuple, graph.nodes)))
    # index order is a valid topological order (the emitter's walk)
    order = scheduled.topological_order()
    assert order == sorted(order)


def test_depth_first_drains_paths(plan):
    graph, _ = apply_schedule(lower(plan), "depth_first")
    seen_paths = [n.path_idx for n in graph.nodes]
    # once we leave a path we never return to it (within one window/msg)
    firsts = {p: seen_paths.index(p) for p in set(seen_paths)}
    lasts = {p: len(seen_paths) - 1 - seen_paths[::-1].index(p)
             for p in set(seen_paths)}
    spans = sorted((firsts[p], lasts[p]) for p in firsts)
    for (_, last_a), (first_b, _) in zip(spans, spans[1:]):
        assert last_a < first_b


def test_schedules_digest_apart(plan, topo):
    graph = lower(plan)
    digests = {apply_schedule(graph, n, topo)[0].digest()
               for n in CONCRETE}
    assert len(digests) == 3


def test_auto_never_worse_than_round_robin(planner, topo):
    """ACCEPTANCE: auto's pick is never modeled slower than round_robin."""
    for nbytes in (256, 1 * MiB, 8 * MiB + 12_288, 64 * MiB):
        for max_paths in (1, 2, 3):
            p = planner.plan(0, 1, nbytes, max_paths=max_paths)
            graph = lower(p)
            auto = make_schedule("auto", topo)
            name, scheduled, scores = auto.select(graph)
            assert scores[name] == min(scores.values())
            assert scores[name] <= scores["round_robin"]
            assert scheduled_time_s(scheduled, topo) <= scheduled_time_s(
                graph, topo)


def test_auto_requires_topology():
    with pytest.raises(ValueError, match="topology"):
        make_schedule("auto")
    with pytest.raises(ValueError, match="unknown schedule"):
        make_schedule("zigzag")


def test_group_scheduling(planner, topo):
    group = planner.plan_group([(0, 1, 4 * MiB), (1, 0, 4 * MiB),
                                (2, 3, 2 * MiB)])
    graph = lower(group, 2)
    for name in CONCRETE + ("auto",):
        scheduled, _ = apply_schedule(graph, name, topo)
        scheduled.validate({i: p.nbytes for i, p in enumerate(group.plans)},
                           cross_flow_exclusive=False)
        assert scheduled.num_nodes == graph.num_nodes


def test_run_pipeline_composes(plan, topo):
    graph = lower(plan)
    out = run_pipeline(graph, ["depth_first", "round_robin"], topo)
    # round_robin restores the canonical order whatever came before
    assert out.digest() == graph.digest()
    out2 = run_pipeline(graph, [DepthFirstSchedule()], topo)
    assert out2.digest() == apply_schedule(graph, "depth_first")[0].digest()


# --------------------------- the §2.2 contract ------------------------------

def test_reindex_rejects_non_permutation(plan):
    graph = lower(plan)
    with pytest.raises(ValueError, match="permutation"):
        reindex(graph, list(range(graph.num_nodes - 1)))


def test_reindex_rejects_anti_topological_order(plan):
    graph = lower(plan)
    order = list(range(graph.num_nodes))[::-1]     # hop chains reversed
    with pytest.raises(ValueError, match="topological"):
        reindex(graph, order)


def test_check_pass_catches_node_mutation(plan):
    graph = lower(plan)
    n0 = graph.nodes[0]
    bad = TransferGraph(
        (dataclasses.replace(n0, nbytes=n0.nbytes + 4),) + graph.nodes[1:],
        graph.edges, graph.window, graph.num_messages, graph.topology_name)
    with pytest.raises(ValueError, match="node multiset"):
        check_pass(graph, bad)


def test_check_pass_catches_dropped_edge(plan):
    graph = lower(plan)
    bad = TransferGraph(graph.nodes, graph.edges[1:], graph.window,
                        graph.num_messages, graph.topology_name)
    with pytest.raises(ValueError, match="edge set"):
        check_pass(graph, bad)


def test_check_pass_catches_backward_edge(plan):
    graph = lower(plan)
    e0 = graph.edges[0]
    bad = TransferGraph(graph.nodes,
                        (DepEdge(e0.dst, e0.src, e0.kind),)
                        + graph.edges[1:], graph.window,
                        graph.num_messages, graph.topology_name)
    with pytest.raises(ValueError, match="edge set|topological"):
        check_pass(graph, bad)


def test_check_pass_accepts_shipped_passes(plan, topo):
    graph = lower(plan, 2)
    for sched in (RoundRobinSchedule(), DepthFirstSchedule(),
                  CriticalPathSchedule(topo), AutoSchedule(topo)):
        check_pass(graph, sched(graph))


# ----------------- post-pass cache keys (GroupKey bugfix) -------------------

def test_group_key_uses_post_pass_digest(topo):
    """REGRESSION: two schedules of the same plan must get distinct cache
    entries (post-pass digest, not the pre-pass lowering digest) and never
    cross-serve executables."""
    cache = TransferPlanCache(capacity=8)
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo,
                       cache=cache)
    msg = jnp.asarray(np.random.RandomState(7).randn(3001), jnp.float32)
    out_rr = sess.send(msg, 0, 5, max_paths=3, num_chunks=4,
                       schedule="round_robin")
    out_df = sess.send(msg, 0, 5, max_paths=3, num_chunks=4,
                       schedule="depth_first")
    np.testing.assert_array_equal(np.asarray(out_rr), np.asarray(msg))
    np.testing.assert_array_equal(np.asarray(out_df), np.asarray(msg))
    keys = cache.keys()
    assert len(keys) == 2                          # no cross-serving
    assert keys[0].digest != keys[1].digest
    plan = sess.plan_for(0, 5, 3001, jnp.float32, max_paths=3,
                         num_chunks=4)
    pre_pass = lower(plan).digest()
    df_graph, _ = apply_schedule(lower(plan), "depth_first")
    assert pre_pass in {k.digest for k in keys}        # round_robin entry
    assert df_graph.digest() in {k.digest for k in keys}
    assert df_graph.digest() != pre_pass               # post-pass differs
    # re-sending under each schedule hits its own entry
    sess.send(msg, 0, 5, max_paths=3, num_chunks=4, schedule="round_robin")
    sess.send(msg, 0, 5, max_paths=3, num_chunks=4, schedule="depth_first")
    assert cache.stats()["misses"] == 2
    assert cache.stats()["hits"] == 2
    assert sess.stats()["schedules"] == {"round_robin": 2,
                                         "depth_first": 2}


def test_session_default_schedule_config(topo, monkeypatch):
    monkeypatch.setenv("REPRO_MP_SCHEDULE", "depth_first")
    assert CommConfig.from_env().schedule == "depth_first"
    with pytest.raises(ValueError, match="unknown schedule"):
        CommConfig(schedule="nope")
    sess = CommSession(schedule="auto", topology=topo)
    assert sess.config.schedule == "auto"
    assert sess.stats()["schedule"] == "auto"
    assert set(SCHEDULE_NAMES) == {"round_robin", "depth_first",
                                   "critical_path", "auto"}


def test_describe_reports_schedule(topo):
    sess = CommSession(CommConfig(multipath_threshold=256), topology=topo)
    d = sess.describe(0, 1, 8 * MiB + 12_288, max_paths=3, schedule="auto",
                      granularity=4, num_chunks=4)
    s = d["schedule"]
    assert s["requested"] == "auto"
    assert s["chosen"] in CONCRETE
    assert s["scheduled_time_s"] <= s["round_robin_time_s"]
    assert s["delta_vs_round_robin_s"] <= 0
    plan = sess.plan(0, 1, 8 * MiB + 12_288, max_paths=3, granularity=4,
                     num_chunks=4)
    scheduled, _ = apply_schedule(lower(plan), s["chosen"], topo)
    assert d["graph"]["digest"] == scheduled.digest()


# ------------------- equal-graph acceptance per scheduler -------------------

def _count_ppermutes(fn, *abstract_args):
    def count(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "ppermute":
                total += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        total += count(sub.jaxpr)
                    elif isinstance(sub, jax.core.Jaxpr):
                        total += count(sub)
        return total
    return count(jax.make_jaxpr(fn)(*abstract_args).jaxpr)


@pytest.mark.parametrize("name", CONCRETE + ("auto",))
def test_equal_graph_per_scheduler(topo, name):
    """ACCEPTANCE: traced ppermute count == scheduled graph.num_nodes for
    every shipped scheduler — the executable is a view of the scheduled
    graph, whatever the dispatch order."""
    sess = CommSession(CommConfig(multipath_threshold=256), topology=topo)
    eng = sess.engine
    plan = eng.plan_for(0, 1, 4096, max_paths=3, num_chunks=4)
    graph, _ = eng._group_graph((plan,), 2, name)
    fn = eng._build_group_fn(graph, (4,))
    traced = _count_ppermutes(fn, jax.ShapeDtypeStruct(
        (2, eng.num_devices, 4096), jnp.float32))
    assert traced == graph.num_nodes == 2 * plan.num_nodes


@pytest.mark.parametrize("name", CONCRETE)
def test_executed_transfer_per_scheduler(topo, name):
    """End-to-end: every scheduler's program still moves the bytes."""
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo)
    msg = jnp.asarray(np.random.RandomState(11).randn(1000), jnp.float32)
    out = sess.send(msg, 0, 5, max_paths=3, num_chunks=3, schedule=name)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


def test_exchange_with_schedule(topo):
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo,
                       schedule="critical_path")
    a = jnp.arange(512, dtype=jnp.float32)
    b = -jnp.arange(512, dtype=jnp.float32)
    fwd, rev = sess.exchange([(a, 0, 1), (b, 1, 0)], num_chunks=2)
    np.testing.assert_array_equal(np.asarray(fwd), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(rev), np.asarray(b))
    assert sum(sess.stats()["schedules"].values()) == 1
