"""Graph-pass pipeline: scheduler semantics, the §2.2 pass contract,
post-pass cache keys, and the per-scheduler equal-graph acceptance.

Acceptance criteria exercised here (ISSUE 4):

* ``round_robin`` scheduled graph is node-for-node identical (same
  digest, same object) to today's lowering,
* ``depth_first`` and ``critical_path`` outputs pass every §4.5
  invariant while digesting apart from the baseline,
* ``auto`` never selects a schedule the model scores worse than
  ``round_robin``,
* ``GroupKey`` incorporates the POST-pass digest: two schedules of the
  same plan get distinct cache entries and never cross-serve
  executables,
* traced ``ppermute`` count == scheduled ``graph.num_nodes`` for every
  shipped scheduler.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommConfig, CommSession, PathPlanner,
                        SCHEDULE_NAMES, TransferPlanCache)
from repro.comm.graph import DepEdge, TransferGraph, lower
from repro.comm.passes import (AutoSchedule, CriticalPathSchedule,
                               DepthFirstSchedule, OverlapSchedule,
                               RoundRobinSchedule, apply_schedule,
                               check_pass, make_schedule, reindex,
                               run_pipeline)
from repro.core import Topology, scheduled_time_s

MiB = 1 << 20
CONCRETE = ("round_robin", "depth_first", "critical_path")


@pytest.fixture(scope="module")
def topo(mesh8):
    # Alias of the shared conftest.py ``mesh8`` fixture; tests needing a
    # distinct identity (memoization) build their own topologies below.
    return mesh8


@pytest.fixture(scope="module")
def planner(topo):
    return PathPlanner(topo, multipath_threshold=256)


@pytest.fixture(scope="module")
def plan(planner):
    # Multi-path, multi-chunk, uneven size: orders genuinely differ and
    # the remainder chunk gives critical_path something to move.
    return planner.plan(0, 1, 8 * MiB + 12_288, max_paths=3, num_chunks=4,
                        granularity=4)


# ------------------------- scheduler semantics ------------------------------

def test_round_robin_is_todays_lowering(plan):
    """ACCEPTANCE: round_robin == today's lowering, node-for-node."""
    for window in (1, 3):
        graph = lower(plan, window)
        scheduled, chosen = apply_schedule(graph, "round_robin")
        assert chosen == "round_robin"
        assert scheduled is graph                  # identity, not a copy
        assert scheduled.digest() == graph.digest()


@pytest.mark.parametrize("name", ["depth_first", "critical_path"])
def test_reordering_passes_preserve_invariants(plan, topo, name):
    """ACCEPTANCE: depth_first / critical_path pass all §4.5 invariants
    on the scheduled graph and keep the node multiset intact."""
    graph = lower(plan, 2)
    scheduled, _ = apply_schedule(graph, name, topo)
    scheduled.validate({0: plan.nbytes})           # §4.5 on the output
    assert scheduled.num_nodes == graph.num_nodes
    assert scheduled.num_edges == graph.num_edges
    assert (sorted(map(dataclasses.astuple, scheduled.nodes))
            == sorted(map(dataclasses.astuple, graph.nodes)))
    # index order is a valid topological order (the emitter's walk)
    order = scheduled.topological_order()
    assert order == sorted(order)


def test_depth_first_drains_paths(plan):
    graph, _ = apply_schedule(lower(plan), "depth_first")
    seen_paths = [n.path_idx for n in graph.nodes]
    # once we leave a path we never return to it (within one window/msg)
    firsts = {p: seen_paths.index(p) for p in set(seen_paths)}
    lasts = {p: len(seen_paths) - 1 - seen_paths[::-1].index(p)
             for p in set(seen_paths)}
    spans = sorted((firsts[p], lasts[p]) for p in firsts)
    for (_, last_a), (first_b, _) in zip(spans, spans[1:]):
        assert last_a < first_b


def test_schedules_digest_apart(plan, topo):
    graph = lower(plan)
    digests = {apply_schedule(graph, n, topo)[0].digest()
               for n in CONCRETE}
    assert len(digests) == 3


def test_auto_never_worse_than_round_robin(planner, topo):
    """ACCEPTANCE: auto's pick is never modeled slower than round_robin."""
    for nbytes in (256, 1 * MiB, 8 * MiB + 12_288, 64 * MiB):
        for max_paths in (1, 2, 3):
            p = planner.plan(0, 1, nbytes, max_paths=max_paths)
            graph = lower(p)
            auto = make_schedule("auto", topo)
            name, scheduled, scores = auto.select(graph)
            assert scores[name] == min(scores.values())
            assert scores[name] <= scores["round_robin"]
            assert scheduled_time_s(scheduled, topo) <= scheduled_time_s(
                graph, topo)


def test_auto_requires_topology():
    with pytest.raises(ValueError, match="topology"):
        make_schedule("auto")
    with pytest.raises(ValueError, match="unknown schedule"):
        make_schedule("zigzag")


def test_group_scheduling(planner, topo):
    group = planner.plan_group([(0, 1, 4 * MiB), (1, 0, 4 * MiB),
                                (2, 3, 2 * MiB)])
    graph = lower(group, 2)
    for name in CONCRETE + ("auto",):
        scheduled, _ = apply_schedule(graph, name, topo)
        scheduled.validate({i: p.nbytes for i, p in enumerate(group.plans)},
                           cross_flow_exclusive=False)
        assert scheduled.num_nodes == graph.num_nodes


def test_run_pipeline_composes(plan, topo):
    graph = lower(plan)
    out = run_pipeline(graph, ["depth_first", "round_robin"], topo)
    # round_robin restores the canonical order whatever came before
    assert out.digest() == graph.digest()
    out2 = run_pipeline(graph, [DepthFirstSchedule()], topo)
    assert out2.digest() == apply_schedule(graph, "depth_first")[0].digest()


# --------------------------- the §2.2 contract ------------------------------

def test_reindex_rejects_non_permutation(plan):
    graph = lower(plan)
    with pytest.raises(ValueError, match="permutation"):
        reindex(graph, list(range(graph.num_nodes - 1)))


def test_reindex_rejects_anti_topological_order(plan):
    graph = lower(plan)
    order = list(range(graph.num_nodes))[::-1]     # hop chains reversed
    with pytest.raises(ValueError, match="topological"):
        reindex(graph, order)


def test_check_pass_catches_node_mutation(plan):
    graph = lower(plan)
    n0 = graph.nodes[0]
    bad = TransferGraph(
        (dataclasses.replace(n0, nbytes=n0.nbytes + 4),) + graph.nodes[1:],
        graph.edges, graph.window, graph.num_messages, graph.topology_name)
    with pytest.raises(ValueError, match="node multiset"):
        check_pass(graph, bad)


def test_check_pass_catches_dropped_edge(plan):
    graph = lower(plan)
    bad = TransferGraph(graph.nodes, graph.edges[1:], graph.window,
                        graph.num_messages, graph.topology_name)
    with pytest.raises(ValueError, match="edge set"):
        check_pass(graph, bad)


def test_check_pass_catches_backward_edge(plan):
    graph = lower(plan)
    e0 = graph.edges[0]
    bad = TransferGraph(graph.nodes,
                        (DepEdge(e0.dst, e0.src, e0.kind),)
                        + graph.edges[1:], graph.window,
                        graph.num_messages, graph.topology_name)
    with pytest.raises(ValueError, match="edge set|topological"):
        check_pass(graph, bad)


def test_check_pass_accepts_shipped_passes(plan, topo):
    graph = lower(plan, 2)
    for sched in (RoundRobinSchedule(), DepthFirstSchedule(),
                  CriticalPathSchedule(topo), OverlapSchedule(topo),
                  AutoSchedule(topo)):
        check_pass(graph, sched(graph))


# ----------------- post-pass cache keys (GroupKey bugfix) -------------------

def test_group_key_uses_post_pass_digest(topo):
    """REGRESSION: two schedules of the same plan must get distinct cache
    entries (post-pass digest, not the pre-pass lowering digest) and never
    cross-serve executables."""
    cache = TransferPlanCache(capacity=8)
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo,
                       cache=cache)
    msg = jnp.asarray(np.random.RandomState(7).randn(3001), jnp.float32)
    out_rr = sess.send(msg, 0, 5, max_paths=3, num_chunks=4,
                       schedule="round_robin")
    out_df = sess.send(msg, 0, 5, max_paths=3, num_chunks=4,
                       schedule="depth_first")
    np.testing.assert_array_equal(np.asarray(out_rr), np.asarray(msg))
    np.testing.assert_array_equal(np.asarray(out_df), np.asarray(msg))
    keys = cache.keys()
    assert len(keys) == 2                          # no cross-serving
    assert keys[0].digest != keys[1].digest
    plan = sess.plan_for(0, 5, 3001, jnp.float32, max_paths=3,
                         num_chunks=4)
    pre_pass = lower(plan).digest()
    df_graph, _ = apply_schedule(lower(plan), "depth_first")
    assert pre_pass in {k.digest for k in keys}        # round_robin entry
    assert df_graph.digest() in {k.digest for k in keys}
    assert df_graph.digest() != pre_pass               # post-pass differs
    # re-sending under each schedule hits its own entry
    sess.send(msg, 0, 5, max_paths=3, num_chunks=4, schedule="round_robin")
    sess.send(msg, 0, 5, max_paths=3, num_chunks=4, schedule="depth_first")
    assert cache.stats()["misses"] == 2
    assert cache.stats()["hits"] == 2
    assert sess.stats()["schedules"] == {"round_robin": 2,
                                         "depth_first": 2}


def test_session_default_schedule_config(topo, monkeypatch):
    monkeypatch.setenv("REPRO_MP_SCHEDULE", "depth_first")
    assert CommConfig.from_env().schedule == "depth_first"
    with pytest.raises(ValueError, match="unknown schedule"):
        CommConfig(schedule="nope")
    sess = CommSession(schedule="auto", topology=topo)
    assert sess.config.schedule == "auto"
    assert sess.stats()["schedule"] == "auto"
    assert set(SCHEDULE_NAMES) == {"round_robin", "depth_first",
                                   "critical_path", "overlap", "auto"}


def test_describe_reports_schedule(topo):
    sess = CommSession(CommConfig(multipath_threshold=256), topology=topo)
    d = sess.describe(0, 1, 8 * MiB + 12_288, max_paths=3, schedule="auto",
                      granularity=4, num_chunks=4)
    s = d["schedule"]
    assert s["requested"] == "auto"
    assert s["chosen"] in CONCRETE + ("overlap",)
    assert s["scheduled_time_s"] <= s["round_robin_time_s"]
    assert s["delta_vs_round_robin_s"] <= 0
    plan = sess.plan(0, 1, 8 * MiB + 12_288, max_paths=3, granularity=4,
                     num_chunks=4)
    scheduled, _ = apply_schedule(lower(plan), s["chosen"], topo)
    assert d["graph"]["digest"] == scheduled.digest()


# ------------------- equal-graph acceptance per scheduler -------------------

def _count_ppermutes(fn, *abstract_args):
    def count(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "ppermute":
                total += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        total += count(sub.jaxpr)
                    elif isinstance(sub, jax.core.Jaxpr):
                        total += count(sub)
        return total
    return count(jax.make_jaxpr(fn)(*abstract_args).jaxpr)


@pytest.mark.parametrize("name", CONCRETE + ("auto",))
def test_equal_graph_per_scheduler(topo, name):
    """ACCEPTANCE: traced ppermute count == scheduled graph.num_nodes for
    every shipped scheduler — the executable is a view of the scheduled
    graph, whatever the dispatch order."""
    sess = CommSession(CommConfig(multipath_threshold=256), topology=topo)
    eng = sess.engine
    plan = eng.plan_for(0, 1, 4096, max_paths=3, num_chunks=4)
    graph, _ = eng._group_graph((plan,), 2, name)
    fn = eng._build_group_fn(graph, (4,))
    traced = _count_ppermutes(fn, jax.ShapeDtypeStruct(
        (2, eng.num_devices, 4096), jnp.float32))
    assert traced == graph.num_nodes == 2 * plan.num_nodes


@pytest.mark.parametrize("name", CONCRETE)
def test_executed_transfer_per_scheduler(topo, name):
    """End-to-end: every scheduler's program still moves the bytes."""
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo)
    msg = jnp.asarray(np.random.RandomState(11).randn(1000), jnp.float32)
    out = sess.send(msg, 0, 5, max_paths=3, num_chunks=3, schedule=name)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


def test_exchange_with_schedule(topo):
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo,
                       schedule="critical_path")
    a = jnp.arange(512, dtype=jnp.float32)
    b = -jnp.arange(512, dtype=jnp.float32)
    fwd, rev = sess.exchange([(a, 0, 1), (b, 1, 0)], num_chunks=2)
    np.testing.assert_array_equal(np.asarray(fwd), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(rev), np.asarray(b))
    assert sum(sess.stats()["schedules"].values()) == 1


# ---------------- overlap scheduler + lane makespan model -------------------

def _lower_capture(build, topo, threshold=2 * MiB):
    """Lower a StepCapture build fn against ``topo`` without a session."""
    from repro.comm import PathPlanner, StepCapture, TransferRequest
    from repro.comm.capture import lower_step

    planner = PathPlanner(topo, multipath_threshold=threshold)

    def plan_group_fn(specs, *, max_paths=None, num_chunks=None):
        reqs = [TransferRequest(s, d, ne * 4, granularity=4)
                for (s, d, ne, _) in specs]
        return planner.plan_group(reqs, max_paths=max_paths,
                                  include_host=False,
                                  num_chunks=num_chunks)

    cap = StepCapture()
    build(cap)
    graph, _ = lower_step(cap, plan_group_fn, topo.name)
    return graph


def _head_of_line_build(cap, *, slow_flops=5_000_000):
    """Mixed graph with a head-of-line hazard on link (0, 1): a big copy
    gated behind a slow kernel is emitted BEFORE a ready small copy on
    the same link, so the lowering order stalls the ready copy — a
    lane-aware reorder must pull it ahead of the gated one."""
    big = cap.input((1 << 15,), jnp.float32)       # 128 KiB payload
    small = cap.input((1 << 13,), jnp.float32)     # 32 KiB payload
    gated = cap.kernel(lambda v: v + 1.0, big, name="slow_kernel",
                       flops=slow_flops)
    ready = cap.kernel(lambda v: v * 2.0, small, name="cheap_kernel",
                       flops=0)
    (r_big,) = cap.exchange([(gated, 0, 1)], num_chunks=1)
    (r_small,) = cap.exchange([(ready, 0, 1)], num_chunks=1)
    cap.kernel(lambda a, b: a[: b.shape[0]] + b, r_big, r_small,
               name="sink", flops=0)


def _overlap_wins_build(cap):
    """Mixed graph where ONLY the lane-aware ``overlap`` order wins.

    Two copies share link (0, 1): a big one ready at t=0 and a small one
    gated behind the fast kernel; an independent slow kernel provides
    compute to hide behind. ``round_robin``/``depth_first`` dispatch the
    slow kernel before the fast one (program order), stalling the gated
    copy. ``critical_path``'s earliest-finish simulation serializes
    copies per *(message, path)* slot — it can't see the two messages
    contending for one link — so it dispatches the gated small copy
    first (it finishes sooner) and head-of-line blocks the big one.
    ``overlap``'s earliest-start rule over the true link lane issues the
    big copy at t=0 behind both kernels."""
    small = cap.input((1 << 15,), jnp.float32)     # 128 KiB staged payload
    big = cap.input((1 << 16,), jnp.float32)       # 256 KiB, ready at 0
    slow = cap.kernel(lambda v: v * 0.5, big, name="k_slow",
                      flops=700_000)               # ~14 us of compute
    fast = cap.kernel(lambda v: v + 1.0, small, name="k_fast",
                      flops=50_000)                # ~1 us of compute
    (r_small,) = cap.exchange([(fast, 0, 1)], num_chunks=1)
    (r_big,) = cap.exchange([(big, 0, 1)], num_chunks=1)
    cap.kernel(lambda a, b, c: a + b[: a.shape[0]] + c[: a.shape[0]],
               r_small, r_big, slow, name="sink", flops=0)


def test_overlap_contract_and_lane_win_on_head_of_line(topo):
    """ACCEPTANCE: on a mixed graph with a head-of-line hazard the
    ``overlap`` schedule passes the §2.2 contract, strictly beats every
    other candidate's lane makespan, hides copy time behind compute,
    and ``auto`` selects it."""
    from repro.core.pipelining import hidden_copy_time_s

    graph = _lower_capture(_overlap_wins_build, topo)
    assert graph.num_compute_nodes and graph.num_copy_nodes
    overlap = OverlapSchedule(topo)
    out = overlap(graph)
    check_pass(graph, out)                        # §2.2 contract
    lanes = {}
    for name in CONCRETE + ("overlap",):
        sg, _ = apply_schedule(graph, name, topo)
        lanes[name] = scheduled_time_s(sg, topo, mode="lanes")
    for name in CONCRETE:
        assert lanes["overlap"] < lanes[name]     # strict lane win
    # the reordered ready copy runs behind the slow kernel
    sg, _ = apply_schedule(graph, "overlap", topo)
    assert hidden_copy_time_s(sg, topo) > 0.0
    # and auto picks it under the lane objective
    name, chosen_graph, scores = make_schedule("auto", topo).select(graph)
    assert name == "overlap"
    assert chosen_graph.digest() == sg.digest()
    assert scores["overlap"] == min(scores.values())


def test_overlap_never_worse_than_input_on_pure_comm(plan, topo):
    """The anomaly guard: when greedy lane scheduling finds nothing
    strictly faster, overlap returns the input graph unchanged — so it
    can never model worse than round_robin."""
    graph = lower(plan, 2)
    out = OverlapSchedule(topo)(graph)
    check_pass(graph, out)
    assert (scheduled_time_s(out, topo, mode="lanes")
            <= scheduled_time_s(graph, topo, mode="lanes"))


def test_auto_never_worse_than_round_robin_mixed(topo):
    """auto's never-worse guarantee holds under the lane objective on
    heterogeneous graphs too."""
    for flops in (0, 10_000, 5_000_000):
        graph = _lower_capture(
            lambda cap: _head_of_line_build(cap, slow_flops=flops), topo)
        name, scheduled, scores = make_schedule("auto", topo).select(graph)
        assert scores[name] == min(scores.values())
        assert scores[name] <= scores["round_robin"]


def test_lane_model_reduces_to_serialized_on_pure_comm(planner, topo):
    """SATELLITE: on pure-comm graphs the default objective IS the
    serialized chain — numerically identical scores (so PR 5/6 digests
    and arbitrations are unperturbed) — while explicit lane pricing
    differs only by charging issue cost into lane occupancy."""
    from repro.core.pipelining import launch_model_for

    for nbytes, max_paths in ((256, 1), (1 * MiB, 1), (8 * MiB, 3)):
        p = planner.plan(0, 1, nbytes, max_paths=max_paths)
        graph = lower(p)
        assert graph.num_compute_nodes == 0
        default_s = scheduled_time_s(graph, topo)
        serialized_s = scheduled_time_s(graph, topo, mode="serialized")
        assert default_s == serialized_s          # bit-identical
        if max_paths == 1:
            # single-path chain: lane FIFO == the serialized chain up to
            # the per-node issue charge (documented exact relationship)
            lane_s = scheduled_time_s(graph, topo, mode="lanes")
            per_node_s = launch_model_for(topo).graph_launch_per_node_ns / 1e9
            assert lane_s == pytest.approx(
                serialized_s + graph.num_nodes * per_node_s, rel=1e-9)


def test_scheduled_time_rejects_unknown_mode(planner, topo):
    graph = lower(planner.plan(0, 1, 4096))
    with pytest.raises(ValueError, match="unknown scheduling model"):
        scheduled_time_s(graph, topo, mode="warp")


def test_auto_memoizes_candidate_scores(planner, topo):
    """SATELLITE bugfix: repeat selects of the same (digest, epoch) are
    memo hits; a topology epoch bump (set_calibration) re-scores."""
    from repro.comm.calibration import CalibrationProfile

    AutoSchedule.score_stats(reset=True)
    local = Topology.full_mesh(4, with_host=False, name="memo4")
    lp = type(planner)(local, multipath_threshold=256)
    graph = lower(lp.plan(0, 1, 4 * MiB, max_paths=2))
    auto = make_schedule("auto", local)
    first = auto.select(graph)
    assert AutoSchedule.score_stats() == {"hits": 0, "misses": 1}
    second = auto.select(graph)
    assert AutoSchedule.score_stats() == {"hits": 1, "misses": 1}
    assert first[0] == second[0] and first[2] == second[2]
    # a fresh AutoSchedule over the same topology shares the memo
    assert make_schedule("auto", local).select(graph)[0] == first[0]
    assert AutoSchedule.score_stats()["hits"] == 2
    # epoch bump invalidates: the memo key includes topology.epoch
    local.set_calibration(
        CalibrationProfile(topology_digest=local.digest()))
    auto.select(graph)
    assert AutoSchedule.score_stats() == {"hits": 2, "misses": 2}
    stats = AutoSchedule.score_stats(reset=True)
    assert stats == {"hits": 2, "misses": 2}
    assert AutoSchedule.score_stats() == {"hits": 0, "misses": 0}


def test_fitted_kernel_cost_flips_auto_choice():
    """ACCEPTANCE: a fitted per-kernel compute term (§4.4d) flips a
    scheduling decision. Without calibration the ``k_fast`` kernel is
    priced by declared FLOPs (~1 us) and only ``overlap`` finds the
    order that hides the contended copies; a synthetic skewed profile
    measuring ``k_fast`` at 50 us makes every candidate's order collapse
    to the same copy-first dispatch, the scores tie, and
    strict-improvement arbitration keeps the earliest candidate —
    ``auto``'s pick changes."""
    from repro.comm.calibration import CalibrationProfile

    local = Topology.full_mesh(8, with_host=False, name="flip8")
    graph = _lower_capture(_overlap_wins_build, local)
    auto = make_schedule("auto", local)
    cold_name, _, cold_scores = auto.select(graph)
    assert cold_name == "overlap"
    local.set_calibration(CalibrationProfile(
        topology_digest=local.digest(),
        kernel_cost_ns={"k_fast": 50_000.0},
        kernel_samples={"k_fast": 16}))
    hot_name, _, hot_scores = auto.select(graph)
    assert hot_name != "overlap"            # the decision flipped
    assert hot_scores[hot_name] <= hot_scores["overlap"]
    assert hot_scores != cold_scores        # the fitted term repriced


def test_session_stats_report_schedule_scores(topo):
    AutoSchedule.score_stats(reset=True)
    sess = CommSession(CommConfig(multipath_threshold=256), topology=topo)
    sess.describe(0, 1, 4 * MiB, schedule="auto", max_paths=2)
    s = sess.stats()["schedule_scores"]
    assert s["misses"] >= 1


# ------------- hypothesis: overlap contract on random mixed graphs ----------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _mixed_params = st.tuples(
        st.integers(min_value=0, max_value=3),          # extra kernels
        st.integers(min_value=8, max_value=1 << 14),    # payload elems
        st.integers(min_value=1, max_value=3),          # messages
        st.integers(min_value=1, max_value=3),          # chunks
        st.integers(min_value=0, max_value=10_000_000), # kernel flops
        st.randoms(use_true_random=False),
    )

    @settings(max_examples=30, deadline=None)
    @given(_mixed_params)
    def test_overlap_contract_on_random_mixed_graphs(params):
        """SATELLITE property: ``overlap`` satisfies the §2.2 contract on
        randomized mixed graphs and its lane-model makespan is never
        worse than round_robin's (the lowering order)."""
        depth, nelems, n_msgs, chunks, flops, rnd = params
        topo = Topology.full_mesh(8, with_host=False, name="mesh8")

        def build(cap):
            x = cap.input((nelems,), jnp.float32)
            y = cap.kernel(lambda v: v + 1.0, x, name="k0", flops=flops)
            for i in range(depth):
                y = cap.kernel(lambda v: v * 2.0, y, name=f"k{i + 1}",
                               flops=rnd.randrange(0, 1_000_000))
            pairs = []
            while len(pairs) < n_msgs:
                s, d = rnd.randrange(8), rnd.randrange(8)
                if s != d:
                    pairs.append((s, d))
            recvs = cap.exchange([(y, s, d) for s, d in pairs],
                                 num_chunks=chunks)
            cap.kernel(lambda *rs: sum(rs), *recvs, name="sink", flops=0)

        graph = _lower_capture(build, topo)
        out = OverlapSchedule(topo)(graph)
        check_pass(graph, out)                           # §2.2 contract
        rr, _ = apply_schedule(graph, "round_robin", topo)
        assert (scheduled_time_s(out, topo, mode="lanes")
                <= scheduled_time_s(rr, topo, mode="lanes"))
