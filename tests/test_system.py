"""End-to-end behaviour tests: training convergence, serving, moe-dist
equivalence, roofline parser."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import set_mesh

from repro.configs import REGISTRY, load_all
from repro.data import DataConfig, SyntheticDataset
from repro.models import transformer as tfm
from repro.optim import OptimConfig
from repro.training import TrainStepConfig, init_state, make_train_step

load_all()


def test_training_loss_decreases():
    cfg = REGISTRY["smollm_360m"].reduced()
    opt = OptimConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(cfg, TrainStepConfig(), opt))
    state = init_state(cfg, opt)
    ds = SyntheticDataset(cfg, DataConfig(seq_len=32, global_batch=8))
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3
    assert all(np.isfinite(losses))


def test_microbatch_accumulation_equivalent():
    cfg = REGISTRY["smollm_360m"].reduced()
    opt = OptimConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    ds = SyntheticDataset(cfg, DataConfig(seq_len=16, global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    s1 = init_state(cfg, opt, seed=1)
    s2 = init_state(cfg, opt, seed=1)
    f1 = jax.jit(make_train_step(cfg, TrainStepConfig(), opt))
    f2 = jax.jit(make_train_step(cfg, TrainStepConfig(microbatches=4), opt))
    s1, _ = f1(s1, batch)
    s2, _ = f2(s2, batch)
    # losses agree to 1e-7; Adam's rsqrt amplifies fp32 summation-order
    # noise in near-zero second moments, so params get a looser budget.
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-4)


def test_serve_engine_generates():
    from repro.serving import Request, ServeEngine
    cfg = REGISTRY["smollm_360m"].reduced()
    params = tfm.init_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, max_len=48, kv_chunks=4)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5),
            Request(prompt=[7, 8, 9, 10], max_new_tokens=8)]
    done = engine.generate(reqs)
    assert len(done[0].out) == 5 and len(done[1].out) == 8
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)


def test_serve_greedy_deterministic():
    from repro.serving import Request, ServeEngine
    cfg = REGISTRY["smollm_360m"].reduced()
    params = tfm.init_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, max_len=32, kv_chunks=4)
    r1 = engine.generate([Request(prompt=[5, 6, 7], max_new_tokens=6)])
    r2 = engine.generate([Request(prompt=[5, 6, 7], max_new_tokens=6)])
    assert r1[0].out == r2[0].out


def test_moe_dist_matches_pure(dp_tp_mesh):
    from repro.models import moe as moe_lib
    from repro.models import moe_dist
    rng = jax.random.key(0)
    d, ff, e, k, T = 32, 64, 8, 2, 128
    params = moe_lib.moe_init(rng, d, ff, e, "swiglu", 0, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (T, d), jnp.float32)
    ref, _ = moe_lib.moe_apply(x, params, top_k=k, kind="swiglu",
                               dropless=True)
    with set_mesh(dp_tp_mesh):
        out, _ = jax.jit(lambda x, p: moe_dist.moe_apply_dist(
            x, p, top_k=k, kind="swiglu", dropless=True))(x, params)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4)


def test_moe_capacity_drops():
    from repro.models import moe as moe_lib
    rng = jax.random.key(2)
    params = moe_lib.moe_init(rng, 16, 32, 4, "swiglu", 0, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (64, 16), jnp.float32)
    out_tight, _ = moe_lib.moe_apply(x, params, top_k=2, kind="swiglu",
                                     capacity_factor=0.25)
    out_loose, _ = moe_lib.moe_apply(x, params, top_k=2, kind="swiglu",
                                     dropless=True)
    # tight capacity must zero out some token outputs
    assert not np.allclose(np.asarray(out_tight), np.asarray(out_loose))


def test_roofline_collective_parser():
    from repro.launch import roofline
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups=[2,16]<=[32]
  %ar = f32[256]{0} all-reduce(%y), replica_groups={{0,1,2,3}}
  %cp = bf16[64,64]{1,0} collective-permute(%z)
  %rs = f32[16]{0} reduce-scatter(%w), replica_groups=[2,4]<=[8]
  %done = f32[256]{0} all-reduce-done(%ar)
"""
    stats = roofline.collective_bytes(hlo, default_group=16)
    assert stats.by_op["all-gather"]["count"] == 1
    ag = 8 * 128 * 2 * (15 / 16)
    ar = 256 * 4 * 2 * (3 / 4)
    cp = 64 * 64 * 2
    rs = 16 * 4 * 3
    assert stats.total_wire_bytes == pytest.approx(ag + ar + cp + rs)


def test_roofline_bottleneck_pick():
    from repro.launch import roofline
    rep = roofline.analyze(
        "a", "s", "m", 256, {"flops": 1e12, "bytes accessed": 1e9},
        "", model_flops=2.56e14, memory_bytes=1e9, default_group=16)
    assert rep.bottleneck == "compute"
    assert rep.useful_flops_ratio == pytest.approx(1.0)
