"""Steady-state dispatch fast path (DESIGN.md §2.3).

Covers the FastPathCache front cache: repeat traffic must skip the
planner / lowering / scheduler pass / validation / digest entirely, any
planner or topology mutation must bump the epoch and force a re-plan (no
stale executable served), `REPRO_MP_VALIDATE=always` must re-validate on
hits, message identity must be canonical inside a group (permuted operand
order collides on one entry), and fast-path results must be numerically
identical to the slow path on bridge and full-mesh topologies.
"""

import jax.numpy as jnp
import numpy as np
import pytest

import repro.comm.engine as engine_mod
import repro.comm.graph as graph_mod
from repro.comm import CommConfig, CommSession, FastPathCache, make_policy
from repro.comm.cache import FastPathEntry
from repro.core import Link, PathPlanner, Topology

MiB = 1 << 20


@pytest.fixture()
def topo():
    return Topology.full_mesh(8, with_host=False, name="mesh8")


@pytest.fixture()
def session(topo):
    return CommSession(CommConfig(multipath_threshold=256), topology=topo)


def _bridge_topology():
    """3 devices where 0→1 has one executable route (direct); the other
    routes stage through the host and are not admitted."""
    from repro.core.topology import HOST
    gb = 25.0
    links = []
    for a, b in ((0, 1), (0, 2)):
        links += [Link(a, b, "nvlink", gb), Link(b, a, "nvlink", gb)]
    links += [Link(2, HOST, "pcie", 12.0), Link(HOST, 2, "pcie", 12.0),
              Link(HOST, 1, "pcie", 12.0), Link(1, HOST, "pcie", 12.0)]
    return Topology(3, links, name="bridge3")


def _count_plan_calls(sess):
    """Wrap the planner's plan/plan_group with call counters."""
    counts = {"plan": 0, "plan_group": 0}
    orig_plan, orig_group = sess.planner.plan, sess.planner.plan_group

    def plan(*a, **k):
        counts["plan"] += 1
        return orig_plan(*a, **k)

    def plan_group(*a, **k):
        counts["plan_group"] += 1
        return orig_group(*a, **k)

    # Neither name is an _EPOCH_ATTRS member, so instrumenting does not
    # itself invalidate the fast path.
    sess.planner.plan = plan
    sess.planner.plan_group = plan_group
    return counts


# ------------------------------ fast path ----------------------------------

def test_repeat_send_skips_planner_entirely(session):
    counts = _count_plan_calls(session)
    msg = jnp.arange(4096, dtype=jnp.float32)
    out1 = session.send(msg, 0, 1)
    assert counts["plan"] == 1
    out2 = session.send(msg * 2, 0, 1)
    out3 = session.send(msg - 1, 0, 1)
    assert counts["plan"] == 1               # hits never re-plan
    fp = session.stats()["fastpath"]
    assert fp["enabled"] and fp["hits"] == 2 and fp["misses"] == 1
    assert fp["invalidations"] == 0
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(msg))
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(msg * 2))
    np.testing.assert_array_equal(np.asarray(out3), np.asarray(msg - 1))


def test_fastpath_hit_still_counts_plan_cache_and_schedules(session):
    """The front cache must not make the plan-cache stats or the schedule
    counters lie: a hit still registers a plan-cache hit (recency
    refreshed) and counts under its concrete schedule name."""
    msg = jnp.arange(512, dtype=jnp.float32)
    session.send(msg, 3, 4)
    h0 = session.stats()["cache"]["hits"]
    session.send(msg, 3, 4)
    s = session.stats()
    assert s["cache"]["hits"] == h0 + 1
    assert s["schedules"]["round_robin"] == 2
    # per-executable attribution (PlanLifecycle)
    entry = next(iter(session.engine._fastpath._store.values()))[1]
    assert entry.compiled.lifecycle.fastpath_hits == 1
    assert entry.compiled.lifecycle.staging_ns > 0


def test_fastpath_distinguishes_request_knobs(session):
    msg = jnp.arange(2048, dtype=jnp.float32)
    session.send(msg, 0, 1)
    session.send(msg, 0, 1, window=2)               # window in signature
    session.send(msg, 0, 1, schedule="depth_first")  # schedule in signature
    session.send(msg, 0, 1, max_paths=2)            # planner knob override
    fp = session.stats()["fastpath"]
    assert fp["misses"] == 4 and fp["hits"] == 0
    # each variant now hits its own entry
    session.send(msg, 0, 1, window=2)
    session.send(msg, 0, 1, schedule="depth_first")
    assert session.stats()["fastpath"]["hits"] == 2


def test_single_and_group_mode_do_not_collide(session):
    """plan() and plan_group() may resolve one spec differently — the
    request signature separates the modes."""
    msg = jnp.arange(1024, dtype=jnp.float32)
    session.send(msg, 0, 1)
    session.exchange([(msg, 0, 1)])
    fp = session.stats()["fastpath"]
    assert fp["misses"] == 2 and fp["size"] == 2


def test_staging_pool_reused_across_launches(session):
    msg = jnp.arange(4096, dtype=jnp.float32)
    for i in range(4):
        session.send(msg + i, 0, 1)
    eng = session.engine
    assert len(eng._staging) == 1            # ONE pooled staging program
    assert eng.staging_ns > 0
    assert session.stats()["fastpath"]["staging_ns"] == eng.staging_ns


def test_fastpath_disabled_replans_every_dispatch(topo):
    sess = CommSession(CommConfig(multipath_threshold=256, fastpath=False),
                       topology=topo)
    counts = _count_plan_calls(sess)
    msg = jnp.arange(1024, dtype=jnp.float32)
    sess.send(msg, 0, 1)
    sess.send(msg, 0, 1)
    assert counts["plan"] == 2               # slow path every time
    fp = sess.stats()["fastpath"]
    assert not fp["enabled"]
    assert fp["hits"] == 0 and fp["misses"] == 0 and fp["size"] == 0
    assert sess.stats()["cache"]["hits"] == 1   # compiled program reused


# ------------------------- epoch invalidation -------------------------------

def test_planner_mutation_bumps_epoch_and_replans(session):
    counts = _count_plan_calls(session)
    msg = jnp.arange(1 * MiB // 4, dtype=jnp.float32)
    session.send(msg, 0, 1)
    assert counts["plan"] == 1
    epoch0 = session.planner.epoch
    session.planner.max_paths = 2
    assert session.planner.epoch != epoch0
    session.send(msg, 0, 1)
    assert counts["plan"] == 2               # stale entry NOT served
    fp = session.stats()["fastpath"]
    assert fp["invalidations"] == 1
    # the re-planned entry honors the new knob
    entry = next(iter(session.engine._fastpath._store.values()))[1]
    assert all(p.num_paths <= 2 for p in entry.plans)


def test_policy_swap_invalidates(session):
    msg = jnp.arange(2048, dtype=jnp.float32)
    session.send(msg, 0, 1)
    session.planner.policy = make_policy("round_robin")
    out = session.send(msg, 0, 1)
    assert session.stats()["fastpath"]["invalidations"] == 1
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


def test_topology_mutation_invalidates(topo):
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo)
    counts = _count_plan_calls(sess)
    msg = jnp.arange(64 * 1024, dtype=jnp.float32)
    sess.send(msg, 0, 1)                     # multipath: stages via peers
    entry0 = next(iter(sess.engine._fastpath._store.values()))[1]
    assert any((0, 2) in p.directional_links() for p in entry0.plans)
    topo.remove_link(0, 2)
    topo.remove_link(2, 0)
    out = sess.send(msg, 0, 1)
    assert counts["plan"] == 2
    assert sess.stats()["fastpath"]["invalidations"] == 1
    entry1 = next(iter(sess.engine._fastpath._store.values()))[1]
    assert all((0, 2) not in p.directional_links() for p in entry1.plans)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


def test_topology_add_link_invalidates(topo):
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo)
    msg = jnp.arange(32 * 1024, dtype=jnp.float32)
    sess.send(msg, 0, 1)
    topo.add_link(Link(0, 1, "nvlink", 25.0))    # aggregate more bandwidth
    sess.send(msg, 0, 1)
    assert sess.stats()["fastpath"]["invalidations"] == 1


def test_group_invalidation_replans_jointly(session):
    counts = _count_plan_calls(session)
    a = jnp.arange(1024, dtype=jnp.float32)
    b = jnp.arange(1024, dtype=jnp.float32) * -1
    session.exchange([(a, 0, 1), (b, 1, 0)])
    session.exchange([(a, 0, 1), (b, 1, 0)])
    assert counts["plan_group"] == 1
    session.planner.max_paths = 3
    fwd, rev = session.exchange([(a, 0, 1), (b, 1, 0)])
    assert counts["plan_group"] == 2
    assert session.stats()["fastpath"]["invalidations"] == 1
    np.testing.assert_array_equal(np.asarray(fwd), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(rev), np.asarray(b))


# ----------------------- canonical message identity -------------------------

def test_permuted_group_collides_on_one_entry(session):
    """ROADMAP graph-level cache dedup: operand order is not message
    identity — a permuted re-issue of the same traffic pattern must hit
    the same compiled program AND the fast path."""
    a = jnp.arange(1000, dtype=jnp.float32)
    b = jnp.arange(500, dtype=jnp.int32)
    o1 = session.exchange([(a, 0, 1), (b, 2, 3)])
    o2 = session.exchange([(b, 2, 3), (a, 0, 1)])   # permuted
    s = session.stats()
    assert s["cache"]["size"] == 1                   # ONE compiled program
    assert s["fastpath"]["misses"] == 1 and s["fastpath"]["hits"] == 1
    np.testing.assert_array_equal(np.asarray(o1[0]), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(o1[1]), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(o2[0]), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(o2[1]), np.asarray(a))


def test_canonicalization_keeps_duplicate_specs_aligned(session):
    """Messages with identical (src, dst, nelems, dtype) are
    interchangeable in the program; results must still align with the
    caller's operands."""
    m0 = jnp.arange(256, dtype=jnp.float32)
    m1 = m0 * -5.0
    o0, o1 = session.exchange([(m0, 0, 7), (m1, 0, 7)])
    np.testing.assert_array_equal(np.asarray(o0), np.asarray(m0))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(m1))


# --------------------------- validate modes ---------------------------------

def _count_validate_calls(monkeypatch):
    calls = {"n": 0}
    orig = engine_mod.validate_plan

    def spy(plan):
        calls["n"] += 1
        return orig(plan)

    monkeypatch.setattr(engine_mod, "validate_plan", spy)
    return calls


def test_validate_miss_only_by_default(session, monkeypatch):
    calls = _count_validate_calls(monkeypatch)
    msg = jnp.arange(512, dtype=jnp.float32)
    session.send(msg, 0, 1)
    n_miss = calls["n"]
    assert n_miss >= 1                       # validated when built
    session.send(msg, 0, 1)
    assert calls["n"] == n_miss              # hits trust the epoch stamp


def test_validate_always_revalidates_on_hits(topo, monkeypatch):
    sess = CommSession(CommConfig(multipath_threshold=256,
                                  validate="always"), topology=topo)
    calls = _count_validate_calls(monkeypatch)
    msg = jnp.arange(512, dtype=jnp.float32)
    sess.send(msg, 0, 1)
    n_miss = calls["n"]
    out = sess.send(msg, 0, 1)
    assert calls["n"] == n_miss + 1          # one plan re-validated on hit
    assert sess.stats()["fastpath"]["hits"] == 1
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


def test_validate_env_and_config_checked(monkeypatch):
    monkeypatch.setenv("REPRO_MP_VALIDATE", "always")
    monkeypatch.setenv("REPRO_MP_FASTPATH", "0")
    cfg = CommConfig.from_env()
    assert cfg.validate == "always" and cfg.fastpath is False
    with pytest.raises(ValueError, match="unknown validate mode"):
        CommConfig(validate="sometimes")


# ------------------------ numerics: fast == slow ----------------------------

@pytest.mark.parametrize("make_topo", [
    lambda: Topology.full_mesh(8, with_host=False, name="mesh8"),
    _bridge_topology,
], ids=["full_mesh", "bridge"])
def test_fastpath_matches_slowpath_numerics(make_topo):
    fast = CommSession(CommConfig(multipath_threshold=64, fastpath=True),
                       topology=make_topo())
    slow = CommSession(CommConfig(multipath_threshold=64, fastpath=False),
                       topology=make_topo())
    rng = np.random.RandomState(0)
    msg = jnp.asarray(rng.randn(3001), jnp.float32)
    for _ in range(2):   # second round exercises the hit path
        got_fast = fast.send(msg, 0, 1)
        got_slow = slow.send(msg, 0, 1)
        np.testing.assert_array_equal(np.asarray(got_fast),
                                      np.asarray(got_slow))
        np.testing.assert_array_equal(np.asarray(got_fast), np.asarray(msg))
    ex_fast = fast.exchange([(msg, 0, 1), (msg * 2, 1, 0)])
    ex_slow = slow.exchange([(msg, 0, 1), (msg * 2, 1, 0)])
    for f, s in zip(ex_fast, ex_slow):
        np.testing.assert_array_equal(np.asarray(f), np.asarray(s))
    assert fast.stats()["fastpath"]["hits"] >= 1
    assert slow.stats()["fastpath"]["hits"] == 0


# ------------------------- digest memoization -------------------------------

def test_graph_digest_computed_once_per_instance(session, monkeypatch):
    """Satellite regression: ``digest()`` used to re-hash the whole graph
    on every ``_group_key`` call; it must be computed once per (frozen)
    instance."""
    plan = session.plan_for(0, 1, 3331, jnp.float32, max_paths=3,
                            num_chunks=3)
    graph = graph_mod.lower(plan)
    calls = {"n": 0}
    orig = graph_mod.canonical_digest

    def spy(payload):
        calls["n"] += 1
        return orig(payload)

    monkeypatch.setattr(graph_mod, "canonical_digest", spy)
    d1 = graph.digest()
    d2 = graph.digest()
    d3 = graph.digest()
    assert d1 == d2 == d3
    assert calls["n"] <= 1   # 0 if another test already digested this memo


def test_fastpath_cache_unit():
    cache = FastPathCache(capacity=2)
    e = FastPathEntry(plans=(), graph=None, digest="d", key="k",
                      compiled=None, schedule="round_robin")
    cache.put("sig1", (0,), e)
    assert cache.get("sig1", (0,)) is e
    assert cache.get("sig1", (1,)) is None           # epoch mismatch
    assert cache.stats()["invalidations"] == 1
    assert "sig1" not in cache                        # stale entry dropped
    cache.put("sig1", (1,), e)
    cache.put("sig2", (1,), e)
    cache.put("sig3", (1,), e)                        # evicts LRU sig1
    assert cache.stats()["evictions"] == 1
    assert len(cache) == 2
    with pytest.raises(ValueError, match="positive"):
        FastPathCache(capacity=0)


def test_engine_stats_shape(session):
    session.send(jnp.arange(64, dtype=jnp.float32), 0, 1)
    s = session.engine.stats()
    assert set(s) == {"dispatches", "cache", "fastpath", "graph",
                      "schedules", "schedule_scores", "telemetry",
                      "health"}
    assert s["telemetry"]["enabled"] is False  # off by default (§4.4c)
    assert {"enabled", "validate", "staging_ns", "hits", "misses",
            "invalidations", "evictions", "size",
            "capacity"} <= set(s["fastpath"])
    # §4.6 health ledger schema — pinned so dashboards can rely on it.
    assert set(s["health"]) == {"enabled", "retries", "replans",
                                "faults_seen", "host_relays",
                                "ladder_level", "quarantined_links"}
    assert s["health"]["retries"] == 0 and s["health"]["ladder_level"] == 0


def test_session_stats_fastpath_without_engine(topo):
    sess = CommSession(CommConfig(), topology=topo)
    fp = sess.stats()["fastpath"]              # engine never materialized
    assert fp["enabled"] and fp["hits"] == 0 and fp["invalidations"] == 0


def test_staging_pool_is_bounded(session):
    """Each pooled staging program pins a device-resident zero template;
    the pool must evict LRU entries past the fast-path capacity instead
    of growing with every distinct message size."""
    eng = session.engine
    eng._fastpath.capacity = 4      # shrink the shared bound for the test
    for nelems in range(64, 64 + 8):
        session.send(jnp.arange(nelems, dtype=jnp.float32), 0, 1)
    assert len(eng._staging) == 4


def test_weighted_schedule_recomputed_after_topology_mutation(topo):
    """The schedule memo must not serve a model-weighted dispatch order
    computed from pre-mutation link bandwidths (Topology hashes by
    identity, so the epoch has to be part of the memo key)."""
    from repro.comm.engine import _scheduled_graph

    sess = CommSession(CommConfig(multipath_threshold=64,
                                  schedule="critical_path"), topology=topo)
    msg = jnp.arange(32 * 1024, dtype=jnp.float32)
    sess.send(msg, 0, 1)
    before = _scheduled_graph.cache_info().misses
    topo.add_link(Link(0, 1, "nvlink", 400.0))   # reweight the direct link
    sess.send(msg, 0, 1)
    assert _scheduled_graph.cache_info().misses > before


def test_planner_epoch_tracks_topology(topo):
    planner = PathPlanner(topo)
    e0 = planner.epoch
    topo.bump_epoch()
    assert planner.epoch != e0
    e1 = planner.epoch
    planner.include_host = True
    assert planner.epoch != e1
