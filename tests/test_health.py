"""Link-fault injection and degraded-mode multipath (DESIGN.md §4.6).

Covers the whole resilience stack: the Topology fault model (fail /
degrade / restore / flaky overlays and their epoch semantics), the
deterministic FaultInjector chaos harness, planner-level quarantine and
its route-exclusion invariant, HealthMonitor droop detection and
probe-based re-admission, the engine's degradation ladder (retry →
re-plan on surviving links → single path → host-staged relay), the
captured-step retry path, collective strategy fallback, and the
ResilientTrainLoop integration. The acceptance scenario: a mid-traffic
link failure must never surface to a caller while any rung of the ladder
can still deliver, no stale executable may be served across a fault
(fast-path invalidation), and recovery must restore the exact pre-fault
plan (digest equality).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommConfig, CommSession, FaultInjector,
                        HealthMonitor, HealthStats, LinkFaultError)
from repro.comm.health import FaultEvent, LADDER
from repro.core import HOST, PathPlanner, Topology
from repro.core.pipelining import validate_plan


@pytest.fixture()
def mesh4():
    return jax.sharding.Mesh(jax.devices()[:4], ("dev",))


def _session(topo, mesh, **cfg):
    cfg.setdefault("multipath_threshold", 1)
    cfg.setdefault("max_paths", 3)
    return CommSession(CommConfig(**cfg), mesh=mesh, topology=topo)


# ------------------------- topology fault model -----------------------------

def test_fail_link_removes_and_bumps_epoch(beluga4):
    epoch = beluga4.epoch
    digest = beluga4.digest()
    beluga4.fail_link(0, 1)
    assert (0, 1) not in beluga4.links
    assert (1, 0) in beluga4.links            # directional: reverse survives
    assert beluga4.link(0, 1) is None
    assert beluga4.link_state(0, 1) == "failed"
    assert (0, 1) in beluga4.failed_links
    assert beluga4.epoch != epoch
    assert beluga4.digest() != digest         # surviving shape differs
    # restore is exact: same Link object class/bandwidth, digest returns
    beluga4.restore_link(0, 1)
    assert beluga4.digest() == digest
    assert beluga4.link_state(0, 1) == "up"


def test_fail_link_rejects_absent_and_double(beluga4):
    with pytest.raises(KeyError):
        beluga4.fail_link(0, 99)
    beluga4.fail_link(0, 1)
    with pytest.raises(KeyError):
        beluga4.fail_link(0, 1)
    with pytest.raises(KeyError):
        beluga4.restore_link(2, 3)            # nothing to restore


def test_degrade_link_overlays_bandwidth_not_digest(beluga4):
    digest = beluga4.digest()
    nominal = beluga4.link(0, 1).bandwidth_gbps
    epoch = beluga4.epoch
    beluga4.degrade_link(0, 1, 0.25)
    assert beluga4.link(0, 1).bandwidth_gbps == pytest.approx(nominal / 4)
    assert beluga4.links[(0, 1)].bandwidth_gbps == nominal  # nominal kept
    assert beluga4.digest() == digest          # shape unchanged
    assert beluga4.epoch != epoch              # plans must re-price
    assert beluga4.link_state(0, 1) == "degraded"
    beluga4.degrade_link(0, 1, 1.0)            # ratio 1.0 clears
    assert beluga4.link_state(0, 1) == "up"
    with pytest.raises(ValueError):
        beluga4.degrade_link(0, 1, 0.0)
    with pytest.raises(ValueError):
        beluga4.degrade_link(0, 1, 1.5)


def test_degraded_bandwidth_feeds_planner_derate(beluga4):
    """A degraded link must price at its served (scaled) bandwidth so
    planning shifts load off it — the §4.4 model reads Topology.link."""
    planner = PathPlanner(beluga4)
    plan = planner.plan(0, 1, 8 << 20, max_paths=3)
    share_before = next(p.nbytes for p in plan.paths
                        if p.route.directional_links() == ((0, 1),))
    beluga4.degrade_link(0, 1, 0.1)
    plan2 = planner.plan(0, 1, 8 << 20, max_paths=3)
    share_after = sum(p.nbytes for p in plan2.paths
                      if p.route.directional_links() == ((0, 1),))
    assert share_after < share_before


def test_flaky_mark_is_advisory(beluga4):
    epoch = beluga4.epoch
    beluga4.mark_flaky(0, 1)
    assert (0, 1) in beluga4.flaky_links
    assert beluga4.link_state(0, 1) == "up"    # still routable
    assert beluga4.epoch != epoch
    beluga4.mark_flaky(0, 1, flaky=False)
    assert (0, 1) not in beluga4.flaky_links
    with pytest.raises(KeyError):
        beluga4.mark_flaky(7, 8)


# --------------------------- fault injector ---------------------------------

def test_injector_spec_grammar():
    inj = FaultInjector.from_spec(
        "fail@3:0-1; degrade@5x4:0-2*0.25, restore@9:0-1")
    acts = [(e.at, e.action, e.link) for e in inj._events]
    assert (3, "fail", (0, 1)) in acts
    assert (9, "restore", (0, 1)) in acts
    # degrade with a count carries a duration: its restore is scheduled
    # automatically when the event fires
    degrade = next(e for e in inj._events if e.action == "degrade")
    assert degrade.link == (0, 2) and degrade.duration == 4
    assert degrade.ratio == 0.25
    with pytest.raises(ValueError):
        FaultInjector.from_spec("explode@1:0-1")
    with pytest.raises(ValueError):
        FaultInjector.from_spec("fail:0-1")             # missing @AT
    with pytest.raises(ValueError):
        FaultInjector.from_spec("flap@2x2:0-1")         # flap needs ~PERIOD


def test_injector_flap_expands_to_cycles():
    inj = FaultInjector.from_spec("flap@2~3x2:0-1")
    assert [(e.at, e.action) for e in inj._events] == [
        (2, "fail"), (5, "restore"), (8, "fail"), (11, "restore")]


def test_injector_seeded_is_deterministic(beluga4):
    a = FaultInjector.seeded(beluga4, seed=7)
    b = FaultInjector.seeded(Topology.full_mesh(4), seed=7)
    assert [(e.at, e.action, e.link) for e in a._events] == \
        [(e.at, e.action, e.link) for e in b._events]
    assert a.active


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(at=-1, action="fail", link=(0, 1))
    with pytest.raises(ValueError):
        FaultEvent(at=0, action="nope", link=(0, 1))
    with pytest.raises(ValueError):
        FaultEvent(at=0, action="degrade", link=(0, 1), ratio=0.0)


# ------------------------ planner quarantine --------------------------------

def test_quarantine_excludes_links_and_bumps_epoch(beluga4):
    planner = PathPlanner(beluga4)
    epoch = planner.epoch
    planner.quarantine((0, 1))
    assert planner.epoch != epoch              # fast-path must invalidate
    plan = planner.plan(0, 1, 4 << 20, max_paths=3)
    for p in plan.paths:
        assert (0, 1) not in p.route.directional_links()
    validate_plan(plan)                        # §4.5 invariants preserved
    # probes bypass the quarantine explicitly
    admitted = planner.plan(0, 1, 1 << 10, max_paths=1,
                            admit_quarantined=True)
    assert admitted.paths[0].route.directional_links() == ((0, 1),)
    epoch2 = planner.epoch
    planner.quarantine((0, 1))                 # idempotent: no spurious bump
    assert planner.epoch == epoch2
    planner.readmit((0, 1))
    assert planner.quarantined == frozenset()
    assert planner.epoch != epoch2


def test_quarantine_all_routes_raises(mesh4):
    """With every admissible route quarantined the planner refuses (the
    engine's ladder catches this and escalates to the host relay)."""
    topo = Topology.full_mesh(4, with_host=False, name="mesh4")
    planner = PathPlanner(topo)
    planner.quarantine(*[key for key in topo.links if 0 in key or
                         1 in key])
    with pytest.raises(ValueError):
        planner.plan(0, 1, 1 << 20)


# --------------------------- health monitor ---------------------------------

def _sample(links, measured_ns, nbytes=1 << 20):
    from repro.comm.telemetry import DispatchSample, StageTimings
    routes = (tuple((tuple(sorted(links)), nbytes, 1) for _ in (0,)),)
    return DispatchSample(routes=routes, nbytes=nbytes, num_nodes=1,
                          window=1, schedule="round_robin",
                          stages=StageTimings(execute_ns=measured_ns),
                          fastpath_hit=True)


def test_monitor_droop_quarantines_after_m_consecutive(beluga4):
    planner = PathPlanner(beluga4)
    mon = HealthMonitor(beluga4, planner, droop_threshold=2.0,
                        droop_samples=3, require_calibration=False)
    link = (0, 1)
    slow = _sample([link], measured_ns=int(1e9))     # ~1 s for 1 MiB: droop
    fast = _sample([link], measured_ns=1000)
    assert mon.observe(slow) > 2.0
    mon.observe(slow)
    assert planner.quarantined == frozenset()        # 2 < droop_samples
    mon.observe(fast)                                # healthy resets streak
    mon.observe(slow)
    mon.observe(slow)
    assert planner.quarantined == frozenset()        # consecutive, not sum
    mon.observe(slow)
    assert link in planner.quarantined
    assert mon.quarantines == 1
    assert any(e["kind"] == "quarantine" for e in mon.events)


def test_monitor_requires_calibration_by_default(beluga4):
    mon = HealthMonitor(beluga4, PathPlanner(beluga4))
    assert beluga4.calibration is None
    assert mon.observe(_sample([(0, 1)], int(1e9))) is None
    assert mon.observed == 0


def test_monitor_probe_readmits_after_healthy_streak(beluga4):
    planner = PathPlanner(beluga4)
    mon = HealthMonitor(beluga4, planner, probe_healthy=2,
                        recovery_ratio=0.5, require_calibration=False)
    mon.quarantine_link((0, 1), reason="test")
    beluga4.fail_link(0, 1)
    assert mon.probe((0, 1)) is False          # failed link never readmits
    beluga4.restore_link(0, 1)
    beluga4.degrade_link(0, 1, 0.25)           # below recovery_ratio
    assert mon.probe((0, 1)) is False
    beluga4.degrade_link(0, 1, 1.0)
    assert mon.probe((0, 1)) is True
    assert (0, 1) in planner.quarantined       # one healthy probe < 2
    assert mon.probe((0, 1)) is True
    assert (0, 1) not in planner.quarantined
    assert mon.readmissions == 1


def test_monitor_flaky_links_need_longer_streak(beluga4):
    planner = PathPlanner(beluga4)
    mon = HealthMonitor(beluga4, planner, probe_healthy=1, flaky_factor=3,
                        require_calibration=False)
    beluga4.mark_flaky(0, 1)
    mon.quarantine_link((0, 1), reason="flap")
    mon.probe((0, 1)), mon.probe((0, 1))
    assert (0, 1) in planner.quarantined       # 2 < 1 × flaky_factor
    mon.probe((0, 1))
    assert (0, 1) not in planner.quarantined


# ------------------- end-to-end chaos (acceptance) --------------------------

def test_midtraffic_link_failure_recovers_and_readmits(mesh4):
    """The ISSUE acceptance scenario: mid-traffic NVLink failure on the
    4-GPU fixture → the in-flight exchange completes on re-planned
    routes excluding the failed link (fast path invalidated, no stale
    executable), restore + healthy probes re-admit the link, and the
    steady-state plan digest returns to its pre-fault value."""
    topo = Topology.full_mesh(4)
    sess = _session(topo, mesh4)
    x = jnp.arange(4096, dtype=jnp.float32)
    y = jnp.arange(4096, dtype=jnp.float32) * 2

    outs = sess.exchange([(x, 0, 1), (y, 2, 3)])
    np.testing.assert_array_equal(outs[0], x)
    pre_digest = sess.describe(0, 1, 4096 * 4)["graph"]["digest"]
    inval0 = sess.stats()["fastpath"]["invalidations"]

    topo.fail_link(0, 1)                       # mid-traffic failure
    outs = sess.exchange([(x, 0, 1), (y, 2, 3)])
    np.testing.assert_array_equal(outs[0], x)  # delivered regardless
    np.testing.assert_array_equal(outs[1], y)
    s = sess.stats()
    assert s["fastpath"]["invalidations"] > inval0   # no stale executable
    assert s["health"]["ladder_level"] == 1          # surviving multipath
    plan = sess.plan(0, 1, 4096 * 4)
    for p in plan.paths:
        assert (0, 1) not in p.route.directional_links()
    validate_plan(plan)

    topo.restore_link(0, 1)
    for _ in range(3):
        sess.probe_links()                     # healthy probes re-admit
    assert sess.planner.quarantined == frozenset()
    outs = sess.exchange([(x, 0, 1), (y, 2, 3)])
    np.testing.assert_array_equal(outs[0], x)
    assert sess.describe(0, 1, 4096 * 4)["graph"]["digest"] == pre_digest
    assert sess.stats()["health"]["ladder_level"] == 0


def test_injected_drop_retries_and_quarantines(mesh4):
    """A dispatch-window drop fault must be survived by bounded retry on
    a re-planned route, counted in the windowed health stats."""
    topo = Topology.full_mesh(4)
    sess = _session(topo, mesh4, faults="drop@1x1:0-1")
    x = jnp.arange(1024, dtype=jnp.float32)
    np.testing.assert_array_equal(sess.send(x, 0, 1), x)  # pre-fault
    np.testing.assert_array_equal(sess.send(x, 0, 1), x)  # drop fires
    s = sess.stats(reset=True)["health"]
    assert s["retries"] >= 1 and s["replans"] >= 1
    assert s["faults_seen"] == 1
    assert s["quarantined_links"] == 1          # blamed link quarantined
    # windowed counters zero on reset; quarantine state survives
    s2 = sess.stats()["health"]
    assert s2["retries"] == 0 and s2["quarantined_links"] == 1


def test_injected_fail_event_fires_at_dispatch(mesh4):
    topo = Topology.full_mesh(4)
    sess = _session(topo, mesh4, faults="fail@1:0-1; restore@3:0-1")
    x = jnp.arange(512, dtype=jnp.float32)
    sess.send(x, 0, 1)
    assert (0, 1) in topo.links
    sess.send(x, 0, 1)                          # dispatch 1: fail fires
    assert (0, 1) in topo.failed_links
    sess.send(x, 0, 1)
    sess.send(x, 0, 1)                          # dispatch 3: restore fires
    assert (0, 1) in topo.links
    assert sess.stats()["health"]["faults_seen"] == 2


def test_ladder_host_relay_when_no_device_route(mesh4):
    """All device routes gone → the staged host rung delivers; no host
    path either → CommFaultError with the attempt history."""
    from repro.comm import CommFaultError

    topo = Topology.full_mesh(2)
    sess = _session(topo, jax.sharding.Mesh(jax.devices()[:2], ("dev",)))
    x = jnp.arange(128, dtype=jnp.float32)
    np.testing.assert_array_equal(sess.send(x, 0, 1), x)
    topo.fail_link(0, 1)
    out = sess.send(x, 0, 1)                   # host-staged relay
    np.testing.assert_array_equal(out, x)
    s = sess.stats()["health"]
    assert s["host_relays"] == 1 and s["ladder_level"] == 3

    topo2 = Topology.full_mesh(2, with_host=False, name="mesh2")
    sess2 = _session(topo2,
                     jax.sharding.Mesh(jax.devices()[:2], ("dev",)))
    np.testing.assert_array_equal(sess2.send(x, 0, 1), x)
    topo2.fail_link(0, 1)
    with pytest.raises(CommFaultError):
        sess2.send(x, 0, 1)                    # ladder truly exhausted


def test_healthy_path_unchanged_and_exclusive_contract():
    """With health on but no fault state, dispatch takes the pristine
    path: exclusive=True starvation still raises ValueError (the ladder
    must not swallow healthy-path contract errors). Chain 2—0—1: flow
    (0,1) claims the only link into 1, starving flow (2,1)."""
    from repro.core import Link

    gb = 25.0
    links = [Link(a, b, "nvlink", gb)
             for (a, b) in ((0, 1), (1, 0), (2, 0), (0, 2))]
    topo = Topology(3, links, name="chain3")
    mesh3 = jax.sharding.Mesh(jax.devices()[:3], ("dev",))
    sess = _session(topo, mesh3, multipath_threshold=0)
    x = jnp.arange(256, dtype=jnp.float32)
    with pytest.raises(ValueError, match="link-exclusive"):
        sess.exchange([(x, 0, 1), (x, 2, 1)], exclusive=True)


def test_health_off_disables_monitor(mesh4):
    topo = Topology.full_mesh(4)
    sess = _session(topo, mesh4, health=False)
    assert sess.monitor is None
    x = jnp.arange(64, dtype=jnp.float32)
    np.testing.assert_array_equal(sess.send(x, 0, 1), x)
    s = sess.stats()["health"]
    assert s["enabled"] is False
    assert sess.describe(0, 1, 1 << 20)["health"]["enabled"] is False


# -------------------- captured-step traffic under faults --------------------

def test_captured_decode_step_survives_link_failure(mesh4):
    """The serving acceptance scenario: a captured decode step keeps
    serving through a mid-traffic failure of a link its KV migration
    rides — re-resolved on surviving routes, numerics intact."""
    from repro.serving.engine import make_captured_decode_step

    topo = Topology.full_mesh(4)
    sess = _session(topo, mesh4)
    n, kv_chunk = 4, 4096
    step = make_captured_decode_step(
        sess, batch=1, heads=2, kv_len=16, head_dim=8,
        kv_chunk=kv_chunk, src=0, dst=2)
    rng = np.random.default_rng(0)
    shp = (n, 1, 2, 16, 8)
    q, k, v = (rng.random(shp).astype(np.float32) for _ in range(3))
    kv = rng.random((n, kv_chunk)).astype(np.float32)

    def check(attn, new_kv):
        expect = kv.copy()
        expect[2] = kv[0]
        np.testing.assert_allclose(np.asarray(new_kv), expect, rtol=1e-6)

    check(*step(q, k, v, kv))
    topo.fail_link(0, 2)                       # the migration's direct link
    check(*step(q, k, v, kv))                  # re-planned, still serves
    plans = step.resolve().plans
    for p in plans:
        assert (0, 2) not in p.directional_links()
    topo.restore_link(0, 2)
    check(*step(q, k, v, kv))


def test_serve_engine_surfaces_health_events(mesh4):
    """ServeEngine drains comm health events after KV migration, so the
    serving layer sees the degradation that happened under its traffic."""
    from repro.configs import REGISTRY, load_all
    from repro.serving.engine import ServeEngine
    from repro.models import transformer as tfm

    load_all()
    cfg = REGISTRY["smollm_360m"].reduced()
    topo = Topology.full_mesh(4)
    sess = _session(topo, mesh4)
    params = tfm.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, max_len=32, kv_chunks=2, comm=sess)
    _, cache = eng.prefill(jnp.ones((1, 4), jnp.int32))
    topo.fail_link(0, 1)
    eng.migrate_kv(cache, 0, 1)                # degraded but delivered
    kinds = {e["kind"] for e in eng.health_events}
    assert "ladder" in kinds                   # degradation was surfaced


# ----------------------- collectives degradation ----------------------------

def test_forced_two_level_falls_back_to_flat_when_egress_dead(two_island):
    from repro.comm import select_all_reduce_strategy

    chosen, _ = select_all_reduce_strategy(two_island, 1 << 20,
                                           "two_level")
    assert chosen == "two_level"
    for (a, b) in list(two_island.links):
        if two_island.is_inter_island(a, b):
            two_island.fail_link(a, b)
    chosen, times = select_all_reduce_strategy(two_island, 1 << 20,
                                               "two_level")
    assert chosen == "flat"                    # §4.6 egress fallback
    assert times["two_level"] == float("inf")


# ----------------------- ResilientTrainLoop ---------------------------------

def _fake_build(num_devices, ckpt):
    state = {"opt": {"step": jnp.asarray(0, jnp.int32)}}

    def step_fn(st, batch):
        st = {"opt": {"step": st["opt"]["step"] + 1}}
        return st, {"loss": jnp.asarray(1.0)}

    return step_fn, state, lambda s: {}


def test_loop_exhaustion_flushes_and_records_before_raise(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.fault_tolerance import (ResilientLoopConfig,
                                               ResilientTrainLoop)

    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    loop = ResilientTrainLoop(ckpt, ResilientLoopConfig(max_restarts=0))
    with pytest.raises(RuntimeError, match="restart budget exhausted"):
        loop.run(_fake_build, total_steps=8, fail_at={2: 4})
    terminal = [e for e in loop.events if e["kind"] == "exhausted"]
    assert terminal and terminal[0]["step"] == 2
    assert terminal[0]["budget"] == 0


def test_loop_drains_comm_health_events(tmp_path, mesh4):
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.fault_tolerance import (ResilientLoopConfig,
                                               ResilientTrainLoop)

    topo = Topology.full_mesh(4)
    sess = _session(topo, mesh4)
    sess.monitor.quarantine_link((0, 1), reason="droop")  # pending event
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    loop = ResilientTrainLoop(ckpt, ResilientLoopConfig(), comm=sess)
    loop.run(_fake_build, total_steps=2)
    comm_events = [e for e in loop.events if e["kind"] == "comm_health"]
    assert comm_events and comm_events[0]["event"]["link"] == (0, 1)
    assert sess.drain_health_events() == []    # drained, not duplicated


# ----------------------------- stats surface --------------------------------

def test_health_stats_schema_and_reset():
    hs = HealthStats()
    hs.retries, hs.replans, hs.ladder_level = 2, 1, 1
    snap = hs.snapshot(quarantined=1, enabled=True)
    assert snap == {"enabled": True, "retries": 2, "replans": 1,
                    "faults_seen": 0, "host_relays": 0,
                    "ladder_level": 1, "quarantined_links": 1}
    hs.reset_window()
    assert hs.retries == 0 and hs.ladder_level == 1   # state survives


def test_link_fault_error_carries_links():
    err = LinkFaultError([(0, 1)], "injected")
    assert err.links == ((0, 1),) and "injected" in str(err)
    assert LADDER[0] == "multipath" and LADDER[-1] == "staged_host"
