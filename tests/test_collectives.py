"""Multipath (bidirectional-ring) collectives vs jax.lax references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core.collectives import (bidir_ring_all_gather,
                                    bidir_ring_reduce_scatter,
                                    multipath_all_reduce,
                                    multipath_all_to_all,
                                    psum_via_multipath)


def _run(fn, x, mesh, in_spec, out_spec):
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_spec,
                             out_specs=out_spec, check_vma=False))(x)


@pytest.mark.parametrize("shape", [(8, 4), (8, 16), (16, 7), (8, 1)])
def test_all_gather(dev_mesh, shape):
    x = jnp.asarray(np.random.RandomState(0).randn(*shape), jnp.float32)
    got = _run(lambda v: bidir_ring_all_gather(v, "dev"), x, dev_mesh,
               P("dev"), P(None))
    ref = _run(lambda v: jax.lax.all_gather(v, "dev", tiled=True), x,
               dev_mesh, P("dev"), P(None))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("shape", [(8, 4), (16, 8), (64, 6), (8, 1)])
def test_reduce_scatter(dev_mesh, shape):
    x = jnp.asarray(np.random.RandomState(1).randn(*shape), jnp.float32)
    got = _run(lambda v: bidir_ring_reduce_scatter(v, "dev"), x, dev_mesh,
               P(None), P("dev"))
    ref = _run(lambda v: jax.lax.psum_scatter(v, "dev", tiled=True), x,
               dev_mesh, P(None), P("dev"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


@pytest.mark.parametrize("shape", [(8, 4), (32, 8)])
def test_all_reduce(dev_mesh, shape):
    x = jnp.asarray(np.random.RandomState(2).randn(*shape), jnp.float32)
    got = _run(lambda v: multipath_all_reduce(v, "dev"), x, dev_mesh,
               P(None), P(None))
    ref = _run(lambda v: jax.lax.psum(v, "dev"), x, dev_mesh,
               P(None), P(None))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


def test_all_to_all(dev_mesh):
    n = 8
    x = jnp.asarray(np.random.RandomState(3).randn(n * n, 4), jnp.float32)
    got = _run(lambda v: multipath_all_to_all(v.reshape(n, 1, 4), "dev"
                                              ).reshape(n, 4),
               x, dev_mesh, P("dev"), P("dev"))
    ref = _run(lambda v: jax.lax.all_to_all(v.reshape(n, 1, 4), "dev", 0, 0
                                            ).reshape(n, 4),
               x, dev_mesh, P("dev"), P("dev"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("shape", [(5, 3), (16,), (3, 3, 3)])
def test_psum_arbitrary_shapes(dev_mesh, shape):
    x = jnp.asarray(np.random.RandomState(4).randn(*shape), jnp.float32)
    got = _run(lambda v: psum_via_multipath(v, "dev"), x, dev_mesh,
               P(*([None] * len(shape))), P(*([None] * len(shape))))
    ref = x * 8.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)


def test_collective_uses_both_directions(dev_mesh):
    """Structural check: the bidirectional AG emits ppermutes in both ring
    directions (this is the multipath property — 2 links per step)."""
    x = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    lowered = jax.jit(shard_map(
        lambda v: bidir_ring_all_gather(v, "dev"), mesh=dev_mesh,
        in_specs=P("dev"), out_specs=P(None), check_vma=False)).lower(x)
    txt = lowered.as_text().replace(" ", "")
    perm_lines = [l for l in txt.splitlines() if "collective_permute" in l
                  or "collective-permute" in l]
    assert perm_lines, "no collective-permutes found"
    # at least one cw (0->1) and one ccw (1->0) permutation must appear
    has_cw = any("[0,1]" in l or "{0,1}" in l for l in perm_lines)
    has_ccw = any("[0,7]" in l or "[1,0]" in l or "{1,0}" in l
                  for l in perm_lines)
    assert has_cw and has_ccw


def test_psum_uses_both_directions(dev_mesh):
    """Regression: a single-column operand silently degraded psum to the
    one-directional ring; the (N*s, 2) packing must engage both."""
    x = jax.ShapeDtypeStruct((5, 3), jnp.float32)
    lowered = jax.jit(shard_map(
        lambda v: psum_via_multipath(v, "dev"), mesh=dev_mesh,
        in_specs=P(None, None), out_specs=P(None, None),
        check_vma=False)).lower(x)
    txt = lowered.as_text().replace(" ", "")
    perm_lines = [l for l in txt.splitlines() if "collective_permute" in l
                  or "collective-permute" in l]
    assert perm_lines, "no collective-permutes found"
    has_cw = any("[0,1]" in l or "{0,1}" in l for l in perm_lines)
    has_ccw = any("[1,0]" in l or "{1,0}" in l for l in perm_lines)
    assert has_cw and has_ccw
