"""Optimizer + gradient compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.optim import (OptimConfig, apply_updates, compressed_psum,
                         compressed_psum_with_feedback, global_norm,
                         init_opt_state, lr_schedule)


def _train_quadratic(moment_dtype, steps=120):
    cfg = OptimConfig(learning_rate=0.1, warmup_steps=5, total_steps=steps,
                      weight_decay=0.0, moment_dtype=moment_dtype)
    target = jnp.asarray(np.random.RandomState(0).randn(32), jnp.float32)
    params = {"w": jnp.zeros((32,), jnp.float32)}
    state = init_opt_state(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    for _ in range(steps):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, grads, state, cfg)
    return float(loss(params))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_all_moment_dtypes(dtype):
    assert _train_quadratic(dtype) < 1e-2


def test_lr_schedule_shape():
    cfg = OptimConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0        # warmup
    assert abs(lrs[10] - 1.0) < 0.02     # peak
    assert abs(lrs[100] - 0.1) < 0.02    # cosine floor


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)


def test_grad_clipping_applied():
    cfg = OptimConfig(learning_rate=1e-3, clip_norm=1.0, warmup_steps=0,
                      total_steps=10, weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = init_opt_state(params, cfg)
    huge = {"w": jnp.full((4,), 1e6, jnp.float32)}
    new_params, _, metrics = apply_updates(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(new_params["w"]))) < 1e-2


def test_int8_moments_zero_size_leaf():
    cfg = OptimConfig(moment_dtype="int8")
    params = {"w": jnp.zeros((0, 4), jnp.float32)}
    state = init_opt_state(params, cfg)
    new_p, state, _ = apply_updates(params, params, state, cfg)
    assert new_p["w"].shape == (0, 4)


def test_compressed_psum_error_bound(dev_mesh):
    x = jnp.asarray(np.random.RandomState(1).randn(8, 256), jnp.float32)

    def body(v):
        return compressed_psum(v[0], "dev")[None]

    got = jax.jit(shard_map(body, mesh=dev_mesh, in_specs=P("dev"),
                            out_specs=P("dev"),
                            check_vma=False))(x)
    ref = np.mean(np.asarray(x), axis=0)
    rel = np.max(np.abs(np.asarray(got)[0] - ref)) / (
        np.max(np.abs(ref)) + 1e-9)
    assert rel < 0.02   # int8 quantization error bound


def test_error_feedback_reduces_bias(dev_mesh):
    """Residual carrying keeps the multi-step mean error near zero."""
    rng = np.random.RandomState(2)
    steps = 30
    g = jnp.asarray(rng.randn(8, 128), jnp.float32) * 0.1

    def run(with_feedback):
        res = jnp.zeros((8, 128), jnp.float32)
        acc = jnp.zeros((128,), jnp.float32)
        for _ in range(steps):
            if with_feedback:
                def body(v, r):
                    out, nr = compressed_psum_with_feedback(
                        v[0], r[0], "dev")
                    return out[None], nr[None]
                out, res = jax.jit(shard_map(
                    body, mesh=dev_mesh, in_specs=(P("dev"), P("dev")),
                    out_specs=(P("dev"), P("dev")),
                    check_vma=False))(g, res)
                acc = acc + out[0]
            else:
                def body(v):
                    return compressed_psum(v[0], "dev")[None]
                out = jax.jit(shard_map(
                    body, mesh=dev_mesh, in_specs=P("dev"),
                    out_specs=P("dev"), check_vma=False))(g)
                acc = acc + out[0]
        true = np.mean(np.asarray(g), 0) * steps
        return np.max(np.abs(np.asarray(acc) - true))

    assert run(True) <= run(False) + 1e-5
