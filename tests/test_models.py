"""Per-architecture smoke tests (reduced configs) + decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, load_all
from repro.configs.shapes import SHAPES, cells, skip_reason
from repro.models import transformer as tfm

load_all()
ALL = sorted(REGISTRY)


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.RandomState(seed)
    if cfg.frontend == "audio":
        batch = {"features": jnp.asarray(
            rng.randn(b, s, cfg.frontend_dim).astype(np.float32))}
    else:
        batch = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32)}
    batch["labels"] = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("name", ALL)
def test_smoke_forward_and_loss(name):
    cfg = REGISTRY[name].reduced()
    params = tfm.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    logits, aux = tfm.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss = tfm.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("name", ALL)
def test_smoke_train_step(name):
    from repro.optim import OptimConfig
    from repro.training import TrainStepConfig, init_state, make_train_step
    cfg = REGISTRY[name].reduced()
    opt = OptimConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(make_train_step(cfg, TrainStepConfig(), opt))
    state = init_state(cfg, opt)
    state, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params changed and stayed finite
    leaf = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("name", [
    "llama3_8b", "mixtral_8x22b", "rwkv6_1_6b", "hymba_1_5b",
    "gemma3_27b", "kimi_k2_1t_a32b",
])
def test_decode_matches_forward(name):
    cfg = dataclasses.replace(REGISTRY[name].reduced(),
                              capacity_factor=8.0)
    params = tfm.init_params(jax.random.key(1), cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.key(2), (b, s), 0,
                              cfg.vocab_size)
    full, _ = tfm.forward(params, cfg, {"tokens": toks})
    spec = tfm.cache_spec(cfg, max_len=s, kv_chunks=4)
    cache = tfm.init_cache(cfg, b, spec)
    errs = []
    step = jax.jit(lambda c, t, i: tfm.decode_step(
        params, cfg, c, t, i, spec))
    for t in range(s):
        lg, cache = step(cache, toks[:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t, :]))))
    assert max(errs) < 2e-3


@pytest.mark.parametrize("name", ["llama3_8b", "mixtral_8x22b",
                                  "rwkv6_1_6b", "hymba_1_5b"])
def test_prefill_then_decode_matches_forward(name):
    cfg = dataclasses.replace(REGISTRY[name].reduced(),
                              capacity_factor=8.0)
    params = tfm.init_params(jax.random.key(1), cfg)
    b, sp, s = 2, 8, 14
    toks = jax.random.randint(jax.random.key(3), (b, s), 0,
                              cfg.vocab_size)
    full, _ = tfm.forward(params, cfg, {"tokens": toks})
    spec = tfm.cache_spec(cfg, max_len=s + 2, kv_chunks=4)
    pl, cache = tfm.prefill_forward(params, cfg,
                                    {"tokens": toks[:, :sp]}, spec)
    errs = [float(jnp.max(jnp.abs(pl - full[:, :sp])))]
    for t in range(sp, s):
        lg, cache = tfm.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                    jnp.int32(t), spec)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t, :]))))
    assert max(errs) < 2e-3


def test_gemma_local_global_pattern():
    cfg = REGISTRY["gemma3_27b"]
    w = np.asarray(tfm.layer_windows(cfg))
    assert len(w) == 62
    assert (w == -1).sum() == 10          # every 6th layer global
    assert (w == cfg.window).sum() == 52


def test_param_counts_match_published():
    expect = {"llama3_8b": 8.0e9, "gemma3_27b": 28e9,
              "nemotron_4_340b": 341e9, "chameleon_34b": 34e9,
              "kimi_k2_1t_a32b": 1.04e12, "mixtral_8x22b": 141e9}
    for name, n in expect.items():
        got = REGISTRY[name].param_count()
        assert abs(got - n) / n < 0.08, (name, got, n)


def test_shape_cell_skip_table():
    """40 cells; 7 skips per DESIGN.md §4."""
    table = cells([REGISTRY[k] for k in ALL])
    assert len(table) == 40
    skips = {(a.name, s.name) for a, s, r in table if r}
    assert skips == {
        ("hubert_xlarge", "decode_32k"), ("hubert_xlarge", "long_500k"),
        ("nemotron_4_340b", "long_500k"), ("llama3_8b", "long_500k"),
        ("smollm_360m", "long_500k"), ("chameleon_34b", "long_500k"),
        ("kimi_k2_1t_a32b", "long_500k"),
    }


def test_ring_cache_wraparound():
    """SWA ring cache correctness past the wrap point."""
    cfg = REGISTRY["mixtral_8x22b"].reduced()  # window 8
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = tfm.init_params(jax.random.key(4), cfg)
    b, s = 1, 20                                # > 2x window
    toks = jax.random.randint(jax.random.key(5), (b, s), 0,
                              cfg.vocab_size)
    full, _ = tfm.forward(params, cfg, {"tokens": toks})
    spec = tfm.cache_spec(cfg, max_len=s, kv_chunks=4)
    assert spec.kind == "ring" and spec.max_len == cfg.window
    cache = tfm.init_cache(cfg, b, spec)
    errs = []
    for t in range(s):
        lg, cache = tfm.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                    jnp.int32(t), spec)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t, :]))))
    assert max(errs) < 2e-3
