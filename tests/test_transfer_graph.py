"""TransferGraph IR: lowering round-trips, digests, invariants, and the
equal-graph acceptance criterion (model node count == traced ``ppermute``
count for the identical plan — the executor, the cost model, and the
validator all consume ONE lowering, so they cannot silently diverge)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommConfig, CommSession, PathPlanner,
                        TransferPlan, TransferPlanCache)
from repro.comm.engine import GroupKey
from repro.comm.graph import (HOP_EDGE, WINDOW_EDGE, CopyNode, DepEdge,
                              TransferGraph, canonical_digest, lower)
from repro.comm.plan import PathAssignment
from repro.core import Topology, build_schedule, validate_plan

MiB = 1 << 20


@pytest.fixture(scope="module")
def topo():
    return Topology.full_mesh(8, with_host=False, name="mesh8")


@pytest.fixture(scope="module")
def planner(topo):
    return PathPlanner(topo, multipath_threshold=256)


def _expected_counts(plans, window):
    nodes = window * sum(len(pa.chunk_bounds()) * pa.route.num_hops
                         for p in plans for pa in p.paths)
    chunks = sum(len(pa.chunk_bounds()) for p in plans for pa in p.paths)
    hop_edges = window * sum(
        len(pa.chunk_bounds()) * (pa.route.num_hops - 1)
        for p in plans for pa in p.paths)
    return nodes, hop_edges + (window - 1) * chunks


# ------------------------------ lowering ------------------------------------

@pytest.mark.parametrize("window", [1, 3])
@pytest.mark.parametrize("max_paths,chunks", [(1, 1), (3, 4), (4, 2)])
def test_lower_counts(planner, max_paths, chunks, window):
    plan = planner.plan(0, 1, 8 * MiB, max_paths=max_paths,
                        num_chunks=chunks)
    graph = lower(plan, window)
    nodes, edges = _expected_counts([plan], window)
    assert graph.num_nodes == nodes
    assert graph.num_edges == edges
    assert graph.window == window and graph.num_messages == 1


def test_lower_roundtrip_chunk_bounds(planner):
    """Node byte ranges reproduce ``chunk_bounds()`` exactly (the lowering
    loses no information about which bytes each copy node moves)."""
    plan = planner.plan(2, 5, 8 * MiB + 12_288, max_paths=3, granularity=4)
    graph = lower(plan)
    for p_idx, pa in enumerate(plan.paths):
        got = sorted({(n.offset, n.nbytes) for n in graph.nodes
                      if n.path_idx == p_idx})
        assert got == sorted(pa.chunk_bounds())
    # every node knows its flow and link chain position
    assert {n.flow for n in graph.nodes} == {(2, 5)}


def test_lower_group_roundtrip(planner):
    group = planner.plan_group([(0, 1, 4 * MiB), (1, 0, 4 * MiB),
                                (2, 3, 2 * MiB)])
    graph = lower(group, 2)
    nodes, edges = _expected_counts(group.plans, 2)
    assert graph.num_nodes == nodes and graph.num_edges == edges
    assert graph.num_messages == 3
    assert graph.flows() == tuple((p.src, p.dst) for p in group.plans)
    for m_idx, plan in enumerate(group.plans):
        for p_idx, pa in enumerate(plan.paths):
            got = sorted({(n.offset, n.nbytes) for n in graph.nodes
                          if n.msg_idx == m_idx and n.path_idx == p_idx
                          and n.window == 0})
            assert got == sorted(pa.chunk_bounds())


def test_lower_is_memoized(planner):
    plan = planner.plan(0, 1, 8 * MiB)
    assert lower(plan, 1) is lower(plan, 1)  # frozen plans → cached graph


def test_lower_rejects_bad_window(planner):
    with pytest.raises(ValueError, match="window"):
        lower(planner.plan(0, 1, MiB), 0)


def test_topological_order_and_edge_kinds(planner):
    plan = planner.plan(0, 1, 8 * MiB, max_paths=3, num_chunks=2)
    graph = lower(plan, 2)
    order = graph.topological_order()
    assert sorted(order) == list(range(graph.num_nodes))
    pos = {n: i for i, n in enumerate(order)}
    for e in graph.edges:
        assert pos[e.src] < pos[e.dst]
        assert e.kind in (HOP_EDGE, WINDOW_EDGE)
    # hop edges keep offset/bytes constant along the chain
    for e in graph.edges:
        if e.kind == HOP_EDGE:
            a, b = graph.nodes[e.src], graph.nodes[e.dst]
            assert (a.offset, a.nbytes) == (b.offset, b.nbytes)
            assert a.link[1] == b.link[0]          # chained hops
            assert b.hop_idx == a.hop_idx + 1


def test_critical_path_nodes(planner):
    direct = planner.plan(0, 1, 8 * MiB, max_paths=1, num_chunks=4)
    assert lower(direct).critical_path_nodes() == 4   # chunk serialization
    staged = planner.plan(0, 1, 8 * MiB, max_paths=3, num_chunks=4)
    hops = max(pa.route.num_hops for pa in staged.paths)
    assert lower(staged).critical_path_nodes() == hops + 3
    # window rounds chain through the window edges
    assert lower(direct, 2).critical_path_nodes() == 5


# ------------------------------ digests -------------------------------------

def test_digest_stable_across_lowerings(topo):
    p1 = PathPlanner(topo, multipath_threshold=256).plan(0, 1, 8 * MiB)
    p2 = PathPlanner(topo, multipath_threshold=256).plan(0, 1, 8 * MiB)
    assert p1 is not p2
    assert lower(p1).digest() == lower(p2).digest()


def test_digest_sensitive_to_structure(planner):
    base = lower(planner.plan(0, 1, 8 * MiB)).digest()
    assert lower(planner.plan(0, 1, 8 * MiB), 2).digest() != base  # window
    assert lower(planner.plan(0, 1, 8 * MiB, num_chunks=7)
                 ).digest() != base                                # chunking
    assert lower(planner.plan(0, 1, 4 * MiB)).digest() != base     # size
    assert lower(planner.plan(1, 0, 8 * MiB)).digest() != base     # flow


def test_group_digest_carries_every_message(planner):
    """The digest subsumes the old cache-key regression: two groups sharing
    a forward plan but differing in the second message digest apart."""
    g1 = planner.plan_group([(0, 1, 4 * MiB), (1, 0, 4 * MiB)])
    g2 = planner.plan_group([(0, 1, 4 * MiB), (1, 0, 2 * MiB)])
    g3 = planner.plan_group([(0, 1, 4 * MiB), (2, 0, 4 * MiB)])
    digests = {lower(g).digest() for g in (g1, g2, g3)}
    assert len(digests) == 3


def test_canonical_digest_deterministic():
    assert canonical_digest(("a", 1)) == canonical_digest(("a", 1))
    assert canonical_digest(("a", 1)) != canonical_digest(("a", 2))


# ------------------------- invariants on the graph --------------------------

def _hand_plan(topo, paths):
    return TransferPlan(0, 1, sum(pa.nbytes for pa in paths), tuple(paths),
                        topo.name)


def test_validate_catches_gap(topo):
    route = PathPlanner(topo).enumerate_routes(0, 1)[0]
    plan = _hand_plan(topo, [PathAssignment(route, 4096, 4096, 1, 1)])
    with pytest.raises(ValueError, match="gap/overlap"):
        validate_plan(plan)


def test_validate_catches_shared_link(topo):
    route = PathPlanner(topo).enumerate_routes(0, 1)[0]
    plan = _hand_plan(topo, [PathAssignment(route, 0, 4096, 1, 1),
                             PathAssignment(route, 4096, 4096, 1, 1)])
    with pytest.raises(ValueError, match="shared by paths"):
        validate_plan(plan)


def test_validate_catches_short_coverage(topo):
    route = PathPlanner(topo).enumerate_routes(0, 1)[0]
    plan = TransferPlan(0, 1, 8192,
                        (PathAssignment(route, 0, 4096, 1, 1),), topo.name)
    with pytest.raises(ValueError, match="coverage ends"):
        validate_plan(plan)


def test_validate_catches_wrong_endpoints(topo):
    route = PathPlanner(topo).enumerate_routes(2, 3)[0]  # not flow (0, 1)
    plan = _hand_plan(topo, [PathAssignment(route, 0, 4096, 1, 1)])
    with pytest.raises(ValueError, match="endpoints"):
        validate_plan(plan)


def test_graph_validate_cross_flow(planner):
    """Graph-level validate flags cross-flow link sharing (the §4.5 group
    invariant) directly on nodes — same check `validate_group` applies."""
    nodes = (CopyNode((0, 1), 0, 0, 0, 0, 0, (0, 2), 0, 64),
             CopyNode((0, 1), 0, 0, 0, 1, 0, (2, 1), 0, 64),
             CopyNode((4, 1), 1, 0, 0, 0, 0, (4, 2), 0, 64),
             CopyNode((4, 1), 1, 0, 0, 1, 0, (2, 1), 0, 64))
    edges = (DepEdge(0, 1, HOP_EDGE), DepEdge(2, 3, HOP_EDGE))
    graph = TransferGraph(nodes, edges, 1, 2, "t")
    with pytest.raises(ValueError, match="exclusivity"):
        graph.validate()
    graph.validate(cross_flow_exclusive=False)  # shared fallback: allowed


def test_graph_rejects_cycle():
    n = CopyNode((0, 1), 0, 0, 0, 0, 0, (0, 1), 0, 64)
    graph = TransferGraph((n, n), (DepEdge(0, 1, HOP_EDGE),
                                   DepEdge(1, 0, HOP_EDGE)), 1, 1, "t")
    with pytest.raises(ValueError, match="cycle"):
        graph.topological_order()


# --------------------------- views over the graph ---------------------------

def test_build_schedule_is_graph_view(planner):
    plan = planner.plan(0, 1, 8 * MiB, max_paths=3, num_chunks=4)
    graph = lower(plan)
    tasks = build_schedule(plan)
    assert len(tasks) == sum(len(pa.chunk_bounds()) for pa in plan.paths)
    chains = {}
    for n in graph.nodes:
        chains.setdefault((n.path_idx, n.chunk_idx), []).append(n)
    for t in tasks:
        nodes = sorted(chains[(t.path_idx, t.chunk_idx)],
                       key=lambda n: n.hop_idx)
        assert t.hops == tuple(n.link for n in nodes)
        assert (t.offset, t.nbytes) == (nodes[0].offset, nodes[0].nbytes)


# ----------------------- equal-graph acceptance test ------------------------

def _sub_jaxprs(v):
    if isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _sub_jaxprs(item)


def _count_primitive(jaxpr, name):
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                count += _count_primitive(sub, name)
    return count


def _count_ppermutes(fn, *abstract_args):
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return _count_primitive(closed.jaxpr, "ppermute")


@pytest.mark.parametrize("window", [1, 2])
def test_equal_graph_invariant_single(topo, window):
    """ACCEPTANCE: the model's node count equals the number of ``ppermute``
    ops actually traced for the identical plan — the cost model and the
    executable are views of ONE graph."""
    sess = CommSession(CommConfig(multipath_threshold=256), topology=topo)
    eng = sess.engine
    plan = eng.plan_for(0, 1, 4096, max_paths=3, num_chunks=4)
    graph, _ = eng._group_graph((plan,), window)
    fn = eng._build_group_fn(graph, (4,))
    traced = _count_ppermutes(fn, jax.ShapeDtypeStruct(
        (window, eng.num_devices, 4096), jnp.float32))
    assert traced == graph.num_nodes
    assert graph.num_nodes == window * plan.num_nodes


def test_equal_graph_invariant_group(topo):
    sess = CommSession(CommConfig(multipath_threshold=256), topology=topo)
    eng = sess.engine
    group = eng.plan_group_for([(0, 1, 1024, jnp.float32),
                                (1, 0, 2048, jnp.float32),
                                (2, 3, 512, jnp.int32)])
    graph, _ = eng._group_graph(group.plans, 1)
    fn = eng._build_group_fn(graph, (4, 4, 4))
    abstracts = [jax.ShapeDtypeStruct((1, eng.num_devices, n), dt)
                 for n, dt in ((1024, jnp.float32), (2048, jnp.float32),
                               (512, jnp.int32))]
    assert _count_ppermutes(fn, *abstracts) == graph.num_nodes
    assert graph.num_nodes == sum(p.num_nodes for p in group.plans)


def test_compiled_lifecycle_reports_graph_nodes(topo):
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo,
                       cache=TransferPlanCache(capacity=8))
    compiled, plan = sess.compiled_for(0, 1, 1024, num_chunks=2)
    assert compiled.lifecycle.num_nodes == lower(plan).num_nodes
    assert isinstance(compiled.key, GroupKey)
    assert compiled.key.digest == sess.engine._group_graph(
        (plan,), 1)[0].digest()
    s = sess.stats()
    assert s["graph"]["nodes_compiled"] == lower(plan).num_nodes
    assert s["graph"]["edges_compiled"] == lower(plan).num_edges


def test_shared_cache_across_mesh_sizes(topo):
    """Regression: 0→1 on a 4-mesh and an 8-mesh can lower to graphs with
    IDENTICAL digests (the digest covers routes, not the device axis), but
    the compiled operands are (window, num_devices, nelems) — the shared
    cache must keep the two meshes' executables apart via
    ``GroupKey.num_devices``."""
    cache = TransferPlanCache(capacity=8)
    cfg = CommConfig(multipath_threshold=1 << 30)     # direct route only
    mesh4 = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("dev",))
    sess4 = CommSession(cfg, mesh=mesh4,
                        topology=Topology.full_mesh(4, with_host=False),
                        cache=cache)
    sess8 = CommSession(cfg, topology=topo, cache=cache)
    msg = jnp.arange(256, dtype=jnp.float32)
    out4 = sess4.send(msg, 0, 1)
    out8 = sess8.send(msg, 0, 1)                      # must NOT hit 4-mesh
    np.testing.assert_array_equal(np.asarray(out4), np.asarray(msg))
    np.testing.assert_array_equal(np.asarray(out8), np.asarray(msg))
    keys = cache.keys()
    assert len(keys) == 2
    assert keys[0].digest == keys[1].digest           # same graph...
    assert {k.num_devices for k in keys} == {4, 8}    # ...distinct meshes


def test_executed_transfer_still_correct(topo):
    """End-to-end: the graph-walked program moves the bytes."""
    sess = CommSession(CommConfig(multipath_threshold=64), topology=topo)
    msg = jnp.asarray(np.random.RandomState(3).randn(1000), jnp.float32)
    out = sess.send(msg, 0, 5, max_paths=3, num_chunks=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


def test_describe_matches_lowering(topo):
    sess = CommSession(CommConfig(), topology=topo)
    d = sess.describe(0, 1, 8 * MiB, window=2, max_paths=3)
    plan = sess.plan(0, 1, 8 * MiB, max_paths=3)
    graph = lower(plan, 2)
    assert d["graph"]["nodes"] == graph.num_nodes
    assert d["graph"]["edges"] == graph.num_edges
    assert d["graph"]["digest"] == graph.digest()
    assert d["graph"]["critical_path_nodes"] == graph.critical_path_nodes()
    assert d["model"]["time_s"] > d["model"]["wire_time_s"] > 0
