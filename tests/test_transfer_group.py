"""Transfer groups: joint planning, fused execution, and the concurrency
bugfixes the group rework flushed out.

* ``plan_group`` — contention-aware joint planning: link-exclusive flows
  when the topology permits, contention-derated sharing when it doesn't,
  arbitrated by the §4.4 analytic model,
* ``session.exchange`` — one compiled launch for N concurrent messages,
  numerics identical to sequential sends,
* group cache key carries EVERY plan's signature (subsumes the old
  bidirectional key bug that dropped the reverse plan),
* regression: 3-hop detours can no longer stage through the host when
  ``include_host=False``, and ``_check_executable`` rejects a host on ANY
  hop (not just ``route.via``),
* regression: ``bidirectional`` returns both receptions; ``send_pytree``
  no-ops zero-size and same-device leaves.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommConfig, CommSession, PathPlanner,
                        TransferPlanCache, TransferRequest)
from repro.comm.engine import _check_executable
from repro.comm.plan import PathAssignment, TransferPlan
from repro.core import (HOST, Link, Topology, estimate_group_time_s,
                        estimate_transfer_time_s, validate_group,
                        validate_plan)

MiB = 1 << 20

# mesh8 / beluga4 / mesh4 / bridge3 topologies come from the shared
# fixture library in conftest.py.


@pytest.fixture(scope="module")
def session(mesh8):
    return CommSession(CommConfig(multipath_threshold=256), topology=mesh8)


# ------------------------- detour host regressions --------------------------

def test_detour_never_stages_through_host_without_include_host(bridge3):
    """Regression: neighbors() includes HOST, so the 3-hop detour search
    could route through the host even with include_host=False."""
    planner = PathPlanner(bridge3, multipath_threshold=0)
    routes = planner.enumerate_routes(0, 1, include_host=False)
    for r in routes:
        for (a, b) in r.directional_links():
            assert HOST not in (a, b), f"host leaked into {r}"
    plan = planner.plan(0, 1, 8 * MiB)
    assert all(HOST not in (a, b) for pa in plan.paths
               for (a, b) in pa.route.directional_links())


def test_detour_through_host_allowed_when_requested(bridge3):
    planner = PathPlanner(bridge3, multipath_threshold=0)
    routes = planner.enumerate_routes(0, 1, include_host=True)
    hosted = [r for r in routes
              if any(HOST in link for link in r.directional_links())]
    assert hosted, "host detour should be admitted with include_host=True"


def test_check_executable_rejects_mid_route_host(bridge3):
    """Regression: the detour (0,2),(2,HOST),(HOST,1) has via=2, so the
    old via-only check would hand device id -1 to ppermute."""
    topo = bridge3
    planner = PathPlanner(topo, multipath_threshold=0)
    routes = planner.enumerate_routes(0, 1, include_host=True)
    hosted = [r for r in routes if r.via != HOST
              and any(HOST in link for link in r.directional_links())]
    assert hosted, "need a mid-route-host / device-via route to regress"
    plan = TransferPlan(0, 1, 4096,
                        (PathAssignment(hosted[0], 0, 4096, 1, 4),),
                        topo.name)
    with pytest.raises(ValueError, match="host-staged"):
        _check_executable(plan)


def test_two_gpu_host_topology_plans_clean():
    """2-GPU + host: include_host=False plans must never touch the host
    anywhere (detour search runs because only the direct route exists)."""
    topo = Topology.full_mesh(2, with_host=True, name="pair")
    planner = PathPlanner(topo, multipath_threshold=0)
    plan = planner.plan(0, 1, 8 * MiB, max_paths=4, include_host=False)
    validate_plan(plan)
    assert all(HOST not in (a, b) for pa in plan.paths
               for (a, b) in pa.route.directional_links())


# ------------------------------ plan_group ----------------------------------

def test_plan_group_empty(mesh8):
    g = PathPlanner(mesh8).plan_group([])
    assert g.num_messages == 0 and g.exclusive


def test_plan_group_rejects_degenerate(mesh8):
    planner = PathPlanner(mesh8)
    with pytest.raises(ValueError, match="src == dst"):
        planner.plan_group([(2, 2, 1024)])
    with pytest.raises(ValueError, match="positive"):
        planner.plan_group([(0, 1, 0)])
    with pytest.raises(ValueError, match="granularity"):
        planner.plan_group([TransferRequest(0, 1, 10, 4)])


def test_plan_group_bidirectional_exclusive(mesh8):
    """Opposite directions use disjoint directional links — the exclusive
    candidate wins and matches the group-level §4.5 invariant."""
    g = PathPlanner(mesh8, multipath_threshold=0).plan_group(
        [(0, 1, 8 * MiB), (1, 0, 8 * MiB)], exclusive=True)
    validate_group(g)
    assert g.exclusive


def test_plan_group_halo_ring_exclusive(beluga4):
    """The paper's 4-rank halo pattern rides a 4-transfer group with fully
    disjoint links on the Beluga mesh."""
    g = PathPlanner(beluga4, multipath_threshold=0).plan_group(
        [(0, 1, 2 * MiB), (1, 2, 2 * MiB), (2, 3, 2 * MiB), (3, 0, 2 * MiB)])
    validate_group(g)
    assert g.exclusive and g.num_messages == 4


def test_plan_group_fan_in_falls_back_to_sharing(mesh4):
    """Flows converging on one device can't be link-disjoint without
    starving someone; the model must pick contention-derated sharing and
    still beat the sequential dispatch loop."""
    topo = mesh4
    planner = PathPlanner(topo, multipath_threshold=256)
    reqs = [(0, 1, 4 * MiB), (2, 1, 4 * MiB)]
    g = planner.plan_group(reqs)
    for p in g.plans:
        validate_plan(p)
    with pytest.raises(ValueError, match="exclusivity"):
        validate_group(g)            # sharing is real — and detected
    indep = [planner.plan(s, d, n) for s, d, n in reqs]
    t_group = estimate_group_time_s(g, topo, fused=True)
    t_loop = estimate_group_time_s(indep, topo, fused=False)
    assert t_group <= t_loop
    forced = planner.plan_group(reqs, exclusive=True)
    validate_group(forced)          # a (suboptimal) partition does exist
    assert estimate_group_time_s(forced, topo) >= t_group


def test_plan_group_exclusive_raises_when_starved():
    """Chain 2—0—1: flow (0,1) claims the only link into 1, so a
    link-exclusive plan for flow (2,1) cannot exist."""
    gb = 25.0
    links = [Link(a, b, "nvlink", gb)
             for (a, b) in ((0, 1), (1, 0), (2, 0), (0, 2))]
    topo = Topology(3, links, name="chain3")
    planner = PathPlanner(topo, multipath_threshold=0)
    reqs = [(0, 1, MiB), (2, 1, MiB)]
    with pytest.raises(ValueError, match="link-exclusive"):
        planner.plan_group(reqs, exclusive=True)
    g = planner.plan_group(reqs)    # default: contention-aware sharing
    for p in g.plans:
        validate_plan(p)
    assert not g.exclusive and (0, 1) in g.shared_links()


def test_plan_group_same_flow_messages_share_routes(mesh8):
    """Pytree-migration shape: N messages of ONE flow share the flow's
    routes (allowed by the group invariant) and each plan stays valid."""
    planner = PathPlanner(mesh8, multipath_threshold=0)
    g = planner.plan_group([TransferRequest(0, 3, 64 * 1024, 4)] * 4)
    validate_group(g)               # same-flow sharing is exempt
    assert g.num_messages == 4


def test_exchange_model_beats_sequential_sends(beluga4):
    """Acceptance: analytic exchange() time ≤ the max completion of
    independently-planned sequential sends on a contended topology."""
    topo = beluga4
    planner = PathPlanner(topo, multipath_threshold=256)
    for reqs in (
            [(0, 1, 8 * MiB), (1, 0, 8 * MiB)],                 # BIBW
            [(0, 1, 4 * MiB), (2, 1, 4 * MiB)],                 # fan-in
            [(0, 1, 2 * MiB), (1, 2, 2 * MiB),
             (2, 3, 2 * MiB), (3, 0, 2 * MiB)],                 # halo ring
            [(0, 1, 16 * MiB), (1, 0, 4 * MiB), (2, 3, 1 * MiB)]):
        group = planner.plan_group(reqs)
        indep = [planner.plan(s, d, n) for s, d, n in reqs]
        t_group = estimate_group_time_s(group, topo, fused=True)
        t_loop = estimate_group_time_s(indep, topo, fused=False)
        assert t_group <= t_loop, (reqs, t_group, t_loop)


# --------------------------- fused execution --------------------------------

def test_exchange_matches_sequential_sends(session):
    rng = np.random.RandomState(0)
    items = [(jnp.asarray(rng.randn(501), jnp.float32), 0, 3),
             (jnp.asarray(rng.randn(1024), jnp.float32), 3, 0),
             (jnp.asarray(rng.randn(77), jnp.float32), 5, 2)]
    got = session.exchange(items)
    for (x, src, dst), out in zip(items, got):
        ref = session.send(x, src, dst)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_exchange_noops_and_shapes(session):
    """src == dst and zero-size items no-op per item; shapes restored."""
    x = jnp.arange(24, dtype=jnp.float32).reshape(2, 3, 4)
    e = jnp.zeros((0, 5), jnp.int32)
    y = jnp.arange(640, dtype=jnp.float32)
    got = session.exchange([(x, 2, 2), (e, 0, 1), (y, 1, 4)])
    assert got[0].shape == x.shape
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(x))
    assert got[1].shape == e.shape and got[1].dtype == e.dtype
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(y))


def test_exchange_all_noops_skips_engine(mesh8):
    sess = CommSession(CommConfig(multipath_threshold=256), topology=mesh8)
    out = sess.exchange([(jnp.ones((3,)), 1, 1)])
    assert sess.stats()["dispatches"] == 0    # engine never materialized
    np.testing.assert_array_equal(np.asarray(out[0]), 1.0)


def test_bidirectional_returns_both_receptions(session):
    """Regression: the docstring always claimed both receptions were
    validated; now they are actually returned and checked."""
    msg = jnp.arange(4096, dtype=jnp.float32)
    fwd, rev = session.bidirectional(msg, 1, 6)
    np.testing.assert_array_equal(np.asarray(fwd), np.asarray(msg))
    np.testing.assert_array_equal(np.asarray(rev), np.asarray(msg))


def test_group_cache_key_carries_every_plan(session):
    """Regression (old engine.py:190): the bidirectional cache key dropped
    the reverse plan's signature. Two groups with an identical forward
    message but different second messages must be distinct entries."""
    cache = session.cache
    x = jnp.arange(512, dtype=jnp.float32)
    c0 = len(cache)
    session.exchange([(x, 6, 7), (x, 7, 6)])
    session.exchange([(x, 6, 7), (jnp.arange(100, dtype=jnp.float32), 7, 6)])
    session.exchange([(x, 6, 7), (x, 5, 6)])
    assert len(cache) == c0 + 3


def test_send_pytree_fused_one_entry_one_dispatch(mesh8):
    """Acceptance: a multi-leaf pytree migration is ONE plan-cache entry
    and ONE dispatch (was one compiled program + dispatch per leaf)."""
    sess = CommSession(CommConfig(multipath_threshold=256), topology=mesh8)
    tree = {"layer0": {"k": jnp.arange(2 * 3 * 8, dtype=jnp.bfloat16
                                       ).reshape(2, 3, 8),
                       "v": jnp.ones((2, 3, 8), jnp.bfloat16)},
            "layer1": {"k": jnp.zeros((2, 3, 8), jnp.bfloat16),
                       "v": jnp.full((2, 3, 8), 2.0, jnp.bfloat16)},
            "lengths": jnp.arange(2, dtype=jnp.int32)}
    moved = sess.send_pytree(tree, 0, 5)
    stats = sess.stats()
    assert stats["cache"]["size"] == 1
    assert stats["dispatches"] == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(moved)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    sess.send_pytree(tree, 0, 5)             # steady state: hit + 1 dispatch
    stats = sess.stats()
    assert stats["cache"]["size"] == 1 and stats["cache"]["hits"] >= 1
    assert stats["dispatches"] == 2


def test_send_pytree_zero_size_and_same_device(session):
    """Regression: zero-size leaves crashed with 'nbytes must be positive'
    and src == dst crashed in route enumeration; both are per-leaf no-ops."""
    tree = {"kv": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "empty": jnp.zeros((4, 0), jnp.float32)}
    moved = session.send_pytree(tree, 0, 2)
    np.testing.assert_array_equal(np.asarray(moved["kv"]),
                                  np.asarray(tree["kv"]))
    assert moved["empty"].shape == (4, 0)
    same = session.send_pytree(tree, 3, 3)    # same-device: identity
    np.testing.assert_array_equal(np.asarray(same["kv"]),
                                  np.asarray(tree["kv"]))
    empty = session.send_pytree({}, 0, 1)     # empty cache entry: no-op
    assert empty == {}


def test_exchange_respects_window(session):
    msg = jnp.arange(256, dtype=jnp.float32)
    (out,) = session.exchange([(msg, 2, 4)], window=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))
