"""CommSession integration with training and serving.

* ``make_dp_train_step`` (manual multipath gradient collectives) matches
  the auto-sharded ``make_train_step`` numerically,
* ``ServeEngine.migrate_kv`` moves a populated KV cache between devices
  through the session's compiled plans, with cache hits on repeat.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommSession
from repro.configs import get_config
from repro.data import DataConfig, SyntheticDataset
from repro.models import transformer as tfm
from repro.optim import OptimConfig
from repro.serving import ServeEngine
from repro.training import (TrainStepConfig, init_state, make_dp_train_step,
                            make_train_step)


@pytest.fixture(scope="module")
def mini_cfg():
    return dataclasses.replace(
        get_config("smollm_360m").reduced(), name="mini", num_layers=2,
        d_model=64, d_ff=128, vocab_size=512)


@pytest.mark.parametrize("microbatches", [1, 2])
def test_dp_train_step_matches_auto(mini_cfg, microbatches):
    opt = OptimConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    ts = TrainStepConfig(microbatches=microbatches)
    auto = jax.jit(make_train_step(mini_cfg, ts, opt))
    dp = jax.jit(make_dp_train_step(mini_cfg, ts, opt, CommSession()))

    state_a = init_state(mini_cfg, opt)
    state_b = jax.tree.map(lambda x: x, state_a)
    # local (per-device) batch must cover the microbatch split: 8 devices
    ds = SyntheticDataset(mini_cfg, DataConfig(
        seq_len=16, global_batch=8 * microbatches))
    for step in range(2):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        state_a, ma = auto(state_a, batch)
        state_b, mb = dp(state_b, batch)
        np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]),
                                   rtol=1e-5)
    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-5, rtol=1e-4)


def test_serve_engine_kv_migration(mini_cfg):
    params = tfm.init_params(jax.random.key(0), mini_cfg)
    comm = CommSession()
    engine = ServeEngine(mini_cfg, params, max_len=32, kv_chunks=1,
                         comm=comm)
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    _, cache = engine.prefill(toks)
    assert len(jax.tree.leaves(cache)) > 1   # multi-leaf KV pytree

    moved = engine.migrate_kv(cache, src=0, dst=5)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(moved)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # Acceptance: the whole multi-leaf migration is ONE fused transfer
    # group — exactly one plan-cache entry and one dispatch.
    stats = comm.stats()
    assert stats["cache"]["size"] == 1
    assert stats["dispatches"] == 1

    before = comm.stats()["cache"]
    engine.migrate_kv(cache, src=0, dst=5)   # same shapes → pure hits
    after = comm.stats()["cache"]
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]
    assert comm.stats()["dispatches"] == 2   # steady state: 1 launch/round


def test_serve_engine_kv_migration_degenerate(mini_cfg):
    """Regression: empty and same-device cache migrations must no-op."""
    params = tfm.init_params(jax.random.key(0), mini_cfg)
    comm = CommSession()
    engine = ServeEngine(mini_cfg, params, max_len=32, kv_chunks=1,
                         comm=comm)
    assert engine.migrate_kv({}, 0, 1) == {}
    _, cache = engine.prefill(jnp.asarray([[1, 2]], jnp.int32))
    same = engine.migrate_kv(cache, src=3, dst=3)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(same)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_engine_without_comm_rejects_migration(mini_cfg):
    params = tfm.init_params(jax.random.key(0), mini_cfg)
    engine = ServeEngine(mini_cfg, params, max_len=32, kv_chunks=1)
    with pytest.raises(ValueError, match="CommSession"):
        engine.migrate_kv({}, 0, 1)
