"""Executable multi-path transfer engine (shard_map/ppermute backend)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MultiPathTransfer, PathPlanner, Topology,
                        TransferPlanCache, plan_signature)


@pytest.fixture(scope="module")
def engine():
    topo = Topology.full_mesh(8, with_host=True)
    return MultiPathTransfer(topology=topo,
                             planner=PathPlanner(topo, multipath_threshold=256))


@pytest.mark.parametrize("nelems", [64, 1024, 100_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_transfer_roundtrip(engine, nelems, dtype):
    msg = jnp.arange(nelems).astype(dtype)
    got = engine.transfer(msg, 0, 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(msg))


def test_bidirectional_group(engine):
    """Opposite-direction traffic is a 2-transfer group (the old
    ``bidirectional=True`` flag); BOTH receptions are returned."""
    fwd_msg = jnp.arange(4096, dtype=jnp.float32)
    rev_msg = jnp.arange(4096, dtype=jnp.float32) * -3.0
    fwd, rev = engine.transfer_group([fwd_msg, rev_msg], [(2, 5), (5, 2)])
    np.testing.assert_array_equal(np.asarray(fwd), np.asarray(fwd_msg))
    np.testing.assert_array_equal(np.asarray(rev), np.asarray(rev_msg))


def test_transfer_group_mixed_sizes_dtypes(engine):
    msgs = [jnp.arange(1000, dtype=jnp.float32),
            jnp.arange(64, dtype=jnp.int32),
            jnp.arange(4096, dtype=jnp.bfloat16)]
    outs = engine.transfer_group(msgs, [(0, 1), (2, 3), (6, 4)])
    for m, o in zip(msgs, outs):
        assert o.dtype == m.dtype
        np.testing.assert_array_equal(np.asarray(o), np.asarray(m))


def test_transfer_group_one_cache_entry_one_dispatch(engine):
    c0, d0 = len(engine.cache), engine.dispatches
    msgs = [jnp.arange(256, dtype=jnp.float32) * i for i in range(3)]
    engine.transfer_group(msgs, [(0, 7), (0, 7), (0, 7)])
    assert len(engine.cache) == c0 + 1      # ONE fused program
    assert engine.dispatches == d0 + 1      # ONE launch
    engine.transfer_group(msgs, [(0, 7), (0, 7), (0, 7)])
    assert len(engine.cache) == c0 + 1      # steady state: pure cache hit


def test_window(engine):
    msg = jnp.arange(2048, dtype=jnp.float32)
    got = engine.transfer(msg, 1, 6, window=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(msg))


def test_cache_hit_on_repeat(engine):
    cache = engine.cache
    msg = jnp.arange(512, dtype=jnp.float32)
    engine.transfer(msg, 3, 4)
    h0 = cache.stats()["hits"]
    engine.transfer(msg * 2, 3, 4)   # same key (src,dst,size,config)
    assert cache.stats()["hits"] == h0 + 1


def test_distinct_keys_for_distinct_sizes(engine):
    msg = jnp.arange(512, dtype=jnp.float32)
    c0 = len(engine.cache)
    engine.transfer(msg, 4, 5)
    engine.transfer(jnp.arange(513, dtype=jnp.float32), 4, 5)
    assert len(engine.cache) == c0 + 2


def test_host_route_rejected_on_device_mesh(engine):
    # host sorts last, so ask for every route to force it into the plan
    plan = engine.planner.plan(0, 1, 4096 * 4, include_host=True,
                               granularity=4, max_paths=16)
    assert any(p.route.kind == "staged_host" for p in plan.paths)
    from repro.core.multipath import _check_executable
    with pytest.raises(ValueError, match="host-staged"):
        _check_executable(plan)


def test_plan_signature_stable(engine):
    p1 = engine.plan_for(0, 1, 4096)
    p2 = engine.plan_for(0, 1, 4096)
    assert plan_signature(p1) == plan_signature(p2)


def test_torus_topology_transfer():
    topo = Topology.torus2d(2, 4)
    eng = MultiPathTransfer(topology=topo,
                            planner=PathPlanner(topo,
                                                multipath_threshold=64))
    msg = jnp.arange(8192, dtype=jnp.float32)
    got = eng.transfer(msg, 0, 5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(msg))
