"""Measured-feedback calibration: profile persistence, fitter gating,
and fitted-term consumption by the model / ``auto`` / planner
(DESIGN.md §4.4c).

Acceptance criteria exercised here (ISSUE 6):

* ``CalibrationProfile`` round-trips through its versioned JSON payload
  and refuses payloads with a mismatched version,
* profiles are keyed by topology digest: ``load_for`` and
  ``Topology.set_calibration`` both refuse a digest mismatch, and a
  structural topology mutation (``remove_link``) drops an attached
  profile,
* the fitter is warmup-robust and sample-gated: too few samples fit
  nothing,
* a session that records real traffic fits a profile whose modeled
  times are STRICTLY closer to measured than the cold §4.4 constants,
* attaching a skewed synthetic profile flips ``auto``'s arbitration —
  proof the scheduler scores through fitted terms, not the constants.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommConfig, CommSession, PathPlanner,
                        PROFILE_VERSION, CalibrationFitter,
                        CalibrationProfile, modeled_sample_time_s,
                        modeled_vs_measured)
from repro.comm.graph import lower
from repro.comm.passes import make_schedule
from repro.comm.telemetry import DispatchSample, StageTimings
from repro.core import Topology, estimate_transfer_time_s
from repro.core.pipelining import DEFAULT_LAUNCH_MODEL, LaunchModel

MiB = 1 << 20


@pytest.fixture()
def topo():
    return Topology.full_mesh(4, with_host=False, name="m4")


def _profile(topo, bw=None, launch=None):
    return CalibrationProfile(
        topology_digest=topo.digest(),
        link_bandwidth_gbps=bw or {}, launch=launch,
        link_samples={k: 5 for k in (bw or {})}, launch_samples=5)


def _sample(routes, *, window=1, schedule="round_robin", launch_ns=20_000,
            execute_ns=100_000, compile_ns=0, num_nodes=4):
    stages = StageTimings(launch_ns=launch_ns, execute_ns=execute_ns,
                          compile_ns=compile_ns)
    nbytes = sum(r[1] for plan in routes for r in plan)
    return DispatchSample(routes=routes, nbytes=nbytes,
                          num_nodes=num_nodes, window=window,
                          schedule=schedule, stages=stages,
                          fastpath_hit=compile_ns == 0)


def _direct_routes(nbytes=4 * MiB, chunks=4):
    return (((((0, 1),), nbytes, chunks),),)


# ------------------------- profile persistence ------------------------------

def test_profile_payload_round_trip(topo):
    launch = dataclasses.replace(DEFAULT_LAUNCH_MODEL,
                                 graph_launch_base_ns=12345)
    prof = _profile(topo, bw={(0, 1): 17.5, (2, 3): 40.0}, launch=launch)
    clone = CalibrationProfile.from_payload(prof.to_payload())
    assert clone.topology_digest == prof.topology_digest
    assert clone.link_bandwidth_gbps == prof.link_bandwidth_gbps
    assert clone.launch == prof.launch
    assert clone.version == PROFILE_VERSION
    assert clone.link_samples == prof.link_samples


def test_profile_version_mismatch_rejected(topo):
    payload = _profile(topo).to_payload()
    payload["version"] = PROFILE_VERSION + 1
    with pytest.raises(ValueError, match="version"):
        CalibrationProfile.from_payload(payload)


def test_profile_save_load_for(tmp_path, topo):
    prof = _profile(topo, bw={(0, 1): 21.0})
    path = prof.save(tmp_path)
    assert os.path.basename(path) == prof.filename()
    loaded = CalibrationProfile.load_for(topo, tmp_path)
    assert loaded is not None
    assert loaded.link_bandwidth_gbps == {(0, 1): 21.0}
    # no profile on disk for a different topology → None, not an error
    other = Topology.full_mesh(8, with_host=False)
    assert CalibrationProfile.load_for(other, tmp_path) is None


def test_load_for_refuses_digest_mismatch(tmp_path, topo):
    """A profile file renamed to another topology's slot must not load."""
    other = Topology.full_mesh(8, with_host=False)
    payload = _profile(other).to_payload()
    target = tmp_path / CalibrationProfile(
        topology_digest=topo.digest()).filename()
    target.write_text(json.dumps(payload))
    with pytest.raises(ValueError, match="digest"):
        CalibrationProfile.load_for(topo, tmp_path)


def test_set_calibration_refuses_digest_mismatch(topo):
    other = Topology.full_mesh(8, with_host=False)
    with pytest.raises(ValueError, match="digest"):
        topo.set_calibration(_profile(other))


def test_structural_mutation_drops_profile(topo):
    prof = _profile(topo, bw={(0, 1): 9.0})
    topo.set_calibration(prof)
    assert topo.calibration is prof
    assert topo.link(0, 1).bandwidth_gbps == 9.0   # calibrated shadow
    topo.remove_link(2, 3)                         # digest changes
    assert topo.calibration is None
    assert topo.link(0, 1).bandwidth_gbps != 9.0   # back to nominal


def test_detach_restores_nominal(topo):
    nominal = topo.link(0, 1).bandwidth_gbps
    topo.set_calibration(_profile(topo, bw={(0, 1): 3.0}))
    epoch = topo.epoch
    assert topo.link(0, 1).bandwidth_gbps == 3.0
    topo.set_calibration(None)
    assert topo.link(0, 1).bandwidth_gbps == nominal
    assert topo.epoch != epoch                     # caches must re-key


# ------------------------- fitter gating ------------------------------------

def test_fitter_min_sample_gate(topo):
    fitter = CalibrationFitter(topo, min_samples=5, warmup=1)
    samples = [_sample(_direct_routes()) for _ in range(3)]
    prof = fitter.fit(samples)
    # 3 samples - 1 warmup = 2 < min_samples: nothing is trusted
    assert prof.link_bandwidth_gbps == {}
    assert prof.launch is None
    assert prof.topology_digest == topo.digest()


def test_fitter_drops_warmup(topo):
    # warmup sample is wildly slow (compile/jit noise); the fit must not
    # let it drag the bandwidth estimate down
    warm = _sample(_direct_routes(), execute_ns=500_000_000)
    rest = [_sample(_direct_routes()) for _ in range(6)]
    fitted = CalibrationFitter(topo, min_samples=3, warmup=1).fit(
        [warm] + rest)
    with_warm = CalibrationFitter(topo, min_samples=3, warmup=0).fit(
        [warm] + rest)
    key = (0, 1)
    assert fitted.link_bandwidth_gbps[key] > \
        with_warm.link_bandwidth_gbps[key]


def test_fitter_validation(topo):
    with pytest.raises(ValueError, match="min_samples"):
        CalibrationFitter(topo, min_samples=0)
    with pytest.raises(ValueError, match="warmup"):
        CalibrationFitter(topo, warmup=-1)
    with pytest.raises(ValueError, match="decay"):
        CalibrationFitter(topo, decay=1.5)
    with pytest.raises(ValueError, match="max_ratio"):
        CalibrationFitter(topo, max_ratio=0.5)


def test_fitted_profile_strictly_closer_on_synthetic_slowdown(topo):
    """The machine is 10x slower than the constants assume; the fitted
    profile must model measured times strictly better."""
    nominal = topo.link(0, 1).bandwidth_gbps
    true_bw = nominal / 10
    nbytes = 4 * MiB
    wire_ns = nbytes / (true_bw * 1e9) * 1e9
    samples = [_sample(_direct_routes(nbytes), launch_ns=30_000,
                       execute_ns=int(wire_ns)) for _ in range(8)]
    prof = CalibrationFitter(topo, min_samples=3, warmup=1).fit(samples)
    assert prof.link_bandwidth_gbps[(0, 1)] < nominal
    res = modeled_vs_measured(samples, topo, profile=prof)
    assert res["fitted"]["mean_rel_err"] < res["constant"]["mean_rel_err"]
    # and per-sample: the fitted model lands near the measured time
    fitted_t = modeled_sample_time_s(samples[-1], topo, profile=prof)
    cold_t = modeled_sample_time_s(samples[-1], topo)
    measured = samples[-1].measured_s
    assert abs(fitted_t - measured) < abs(cold_t - measured)


# ------------------------- fitted-term consumption --------------------------

def _skewed_profile(topo):
    """Direct link 25x slower than nominal + µs-scale per-node launch:
    under these terms front-loading the direct path (critical_path) is a
    strict loss and round_robin wins."""
    bw = {k: 50.0 for k in topo.links}
    bw[(0, 1)] = 2.0
    launch = dataclasses.replace(DEFAULT_LAUNCH_MODEL,
                                 graph_launch_per_node_ns=100_000)
    return _profile(topo, bw=bw, launch=launch)


def test_auto_arbitration_flips_on_fitted_terms(topo):
    """ACCEPTANCE: auto's pick provably consumes the fitted terms."""
    planner = PathPlanner(topo, multipath_threshold=256)
    plan = planner.plan(0, 1, 8 * MiB + 12_288, max_paths=3, num_chunks=4,
                       granularity=4)
    graph = lower(plan)
    auto = make_schedule("auto", topo)
    cold_name, _, cold_scores = auto.select(graph)
    assert cold_name == "critical_path"

    topo.set_calibration(_skewed_profile(topo))
    fit_name, _, fit_scores = auto.select(graph)
    assert fit_name == "round_robin"               # the flip
    assert fit_scores[fit_name] < fit_scores["critical_path"]
    assert fit_scores != cold_scores


def test_estimates_consume_fitted_bandwidth(topo):
    planner = PathPlanner(topo, multipath_threshold=256)
    plan = planner.plan(0, 1, 8 * MiB, max_paths=3)
    cold = estimate_transfer_time_s(plan, topo)
    topo.set_calibration(_skewed_profile(topo))
    fitted = estimate_transfer_time_s(plan, topo)
    assert fitted > cold                           # slower fitted links


def test_launch_model_for_prefers_fitted(topo):
    from repro.core import launch_model_for

    assert launch_model_for(topo) is DEFAULT_LAUNCH_MODEL
    custom = dataclasses.replace(DEFAULT_LAUNCH_MODEL,
                                 graph_launch_base_ns=1)
    topo.set_calibration(_profile(topo, launch=custom))
    assert launch_model_for(topo) == custom
    assert isinstance(launch_model_for(topo), LaunchModel)


# ------------------------- session integration ------------------------------

def _session(**cfg):
    topo = Topology.full_mesh(4, with_host=False)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("dev",))
    return CommSession(CommConfig(multipath_threshold=64, **cfg),
                       mesh=mesh, topology=topo)


def test_session_calibrate_requires_samples():
    sess = _session()
    with pytest.raises(ValueError, match="telemetry"):
        sess.calibrate()


def test_session_calibrate_end_to_end(tmp_path):
    """Real CPU traffic → fitted profile → strictly closer model, auto
    arbitration live on fitted terms, residuals in describe()."""
    sess = _session(telemetry=True)
    msg = jnp.arange(1 << 14, dtype=jnp.float32)
    for _ in range(6):
        jax.block_until_ready(sess.send(msg, 0, 1, max_paths=3,
                                        num_chunks=2))
    prof = sess.calibrate(min_samples=2, warmup=1,
                          persist=str(tmp_path))
    assert sess.topology.calibration is prof
    assert sess.stats()["calibration"]["active"] is True
    res = modeled_vs_measured(sess.telemetry.samples(), sess.topology,
                              profile=prof)
    assert (res["fitted"]["mean_rel_err"]
            < res["constant"]["mean_rel_err"])     # THE acceptance bar
    info = sess.describe(0, 1, msg.nbytes)["calibration"]
    assert info["active"] is True
    assert info["residuals"]["fitted"]["mean_rel_err"] == pytest.approx(
        res["fitted"]["mean_rel_err"])
    # persisted profile loads back for an identically-shaped topology
    reloaded = CalibrationProfile.load_for(sess.topology, str(tmp_path))
    assert reloaded is not None
    assert reloaded.link_bandwidth_gbps == prof.link_bandwidth_gbps


def test_session_loads_profile_on_init(tmp_path):
    topo = Topology.full_mesh(4, with_host=False)
    _profile_for = CalibrationProfile(
        topology_digest=topo.digest(),
        link_bandwidth_gbps={(0, 1): 4.0}, launch=None,
        link_samples={(0, 1): 9}, launch_samples=0)
    _profile_for.save(str(tmp_path))
    sess = _session(profile_dir=str(tmp_path))
    assert sess.topology.calibration is not None
    assert sess.topology.link(0, 1).bandwidth_gbps == 4.0
    assert sess.stats()["calibration"]["active"] is True


def test_session_warns_and_runs_on_corrupt_profile(tmp_path):
    topo = Topology.full_mesh(4, with_host=False)
    bad = tmp_path / CalibrationProfile(
        topology_digest=topo.digest()).filename()
    bad.write_text("{not json")
    with pytest.warns(UserWarning, match="calibration"):
        sess = _session(profile_dir=str(tmp_path))
    assert sess.topology.calibration is None       # degraded, not dead
    jax.block_until_ready(
        sess.send(jnp.arange(256, dtype=jnp.float32), 0, 1))


# ------------------- per-kernel compute term (§4.4d) ------------------------

def test_fitter_kernel_channel_gates_and_fits(topo):
    """The kernel channel is warmup-robust and sample-gated exactly like
    the link channel; the fitted term is the post-warmup median."""
    fitter = CalibrationFitter(topo, min_samples=3, warmup=1)
    samples = [_sample(_direct_routes()) for _ in range(6)]
    kernels = {"attn": (999_999.0, 100.0, 300.0, 200.0),  # warmup dropped
               "sparse": (10.0, 20.0),                    # gated: too few
               "zeros": (5.0, 0.0, -1.0, 0.0)}            # gated: unusable
    prof = fitter.fit(samples, kernels=kernels)
    assert prof.kernel_cost_ns == {"attn": 200.0}
    assert prof.kernel_samples == {"attn": 3}
    assert prof.summary()["kernels_fitted"] == 1


def test_profile_payload_round_trips_kernels(topo):
    prof = CalibrationProfile(
        topology_digest=topo.digest(),
        kernel_cost_ns={"attn": 123.5}, kernel_samples={"attn": 7})
    clone = CalibrationProfile.from_payload(prof.to_payload())
    assert clone.kernel_cost_ns == {"attn": 123.5}
    assert clone.kernel_samples == {"attn": 7}
    # payloads written before the kernel channel existed still load
    payload = prof.to_payload()
    del payload["kernels"]
    legacy = CalibrationProfile.from_payload(payload)
    assert legacy.kernel_cost_ns == {} and legacy.kernel_samples == {}


def test_compute_time_precedence(topo):
    """Fitted per-kernel cost > measured ``cost_ns`` > declared FLOPs —
    the §4.4d pricing ladder the lane model consumes."""
    from repro.comm.graph import ComputeNode
    from repro.core.pipelining import COMPUTE_GFLOPS, compute_time_s

    by_flops = ComputeNode(kernel="attn", window=0, operands=(0,),
                           results=(1,), flops=5_000_000, cost_ns=0)
    stamped = dataclasses.replace(by_flops, cost_ns=2_000)
    assert compute_time_s(by_flops, topo) == pytest.approx(
        5_000_000 / (COMPUTE_GFLOPS * 1e9))
    assert compute_time_s(stamped, topo) == pytest.approx(2e-6)
    topo.set_calibration(CalibrationProfile(
        topology_digest=topo.digest(),
        kernel_cost_ns={"attn": 7_000.0}, kernel_samples={"attn": 4}))
    # the fitted term overrides both declared pricings
    assert compute_time_s(by_flops, topo) == pytest.approx(7e-6)
    assert compute_time_s(stamped, topo) == pytest.approx(7e-6)
    # …but only for kernels the profile actually measured
    other = dataclasses.replace(stamped, kernel="sweep")
    assert compute_time_s(other, topo) == pytest.approx(2e-6)


def test_session_calibrate_forwards_kernel_channel():
    """session.calibrate() feeds the recorder's per-kernel execute
    samples into the fitter alongside the dispatch samples."""
    sess = _session(telemetry=True)
    msg = jnp.arange(1 << 12, dtype=jnp.float32)
    for _ in range(4):
        jax.block_until_ready(sess.send(msg, 0, 1))
    for ns in (900.0, 100.0, 200.0, 300.0):
        sess.telemetry.record_kernel("attn", ns)
    prof = sess.calibrate(min_samples=2, warmup=1)
    assert prof.kernel_cost_ns == {"attn": 200.0}
    assert sess.topology.calibration is prof
