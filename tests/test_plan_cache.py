"""TransferPlanCache: LRU behaviour + lifecycle instrumentation — including
eviction under the digest-derived ``GroupKey``s real sessions use."""

import jax.numpy as jnp
import pytest

from repro.comm import CommConfig, CommSession
from repro.comm.engine import GroupKey
from repro.core import Topology, TransferPlanCache, compile_plan


def _dummy_plan(key, n=4):
    return compile_plan(key, lambda x: x * 2.0,
                        (jnp.zeros((n,), jnp.float32),), num_nodes=n)


def test_get_or_build_builds_once():
    cache = TransferPlanCache(capacity=4)
    calls = []

    def builder():
        calls.append(1)
        return _dummy_plan("k")

    a = cache.get_or_build("k", builder)
    b = cache.get_or_build("k", builder)
    assert a is b and len(calls) == 1
    assert cache.stats()["hits"] == 1
    assert cache.stats()["misses"] == 1


def test_lru_eviction_order():
    cache = TransferPlanCache(capacity=2)
    cache.put("a", _dummy_plan("a"))
    cache.put("b", _dummy_plan("b"))
    cache.get("a")                  # refresh a
    cache.put("c", _dummy_plan("c"))  # evicts b (least recently used)
    assert "a" in cache and "c" in cache and "b" not in cache
    assert cache.evictions == 1


def test_eviction_forces_reinstantiation():
    cache = TransferPlanCache(capacity=1)
    builds = []

    def builder(k):
        def b():
            builds.append(k)
            return _dummy_plan(k)
        return b

    cache.get_or_build("a", builder("a"))
    cache.get_or_build("b", builder("b"))   # evicts a
    cache.get_or_build("a", builder("a"))   # must rebuild
    assert builds == ["a", "b", "a"]


def test_capacity_env(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_SIZE", "3")
    assert TransferPlanCache().capacity == 3


def test_lifecycle_stages_recorded():
    plan = _dummy_plan("x", n=8)
    life = plan.lifecycle
    assert life.trace_ns > 0 and life.lower_ns > 0 and life.compile_ns > 0
    assert life.num_nodes == 8
    assert life.launches == 0
    out = plan(jnp.ones((8,), jnp.float32))
    assert out[0] == 2.0
    assert plan.lifecycle.launches == 1
    assert plan.lifecycle.mean_launch_ns > 0


def test_lru_eviction_under_group_keys():
    """End-to-end LRU behaviour with the keys real sessions produce: a
    capacity hit evicts the least-recently-used fused program, a re-send
    bumps recency, a re-compile after eviction is a fresh miss, and the
    ``stats()`` counters stay consistent throughout."""
    cache = TransferPlanCache(capacity=2)
    sess = CommSession(CommConfig(multipath_threshold=64),
                       topology=Topology.full_mesh(8, with_host=False),
                       cache=cache)

    def send(n):
        sess.send(jnp.arange(n, dtype=jnp.float32), 0, 1)

    send(128)                                   # miss → compile key A
    send(256)                                   # miss → compile key B
    keys = cache.keys()
    assert len(keys) == 2 and all(isinstance(k, GroupKey) for k in keys)
    assert len({k.digest for k in keys}) == 2   # digest-distinct entries
    key_a, key_b = keys

    send(128)                                   # hit A → bumps recency
    assert cache.keys() == [key_b, key_a]       # B is now the LRU entry
    send(512)                                   # miss → evicts B, not A
    assert key_a in cache and key_b not in cache
    assert cache.evictions == 1

    h0, m0 = cache.hits, cache.misses
    send(128)                                   # A retained: pure hit
    assert (cache.hits, cache.misses) == (h0 + 1, m0)
    send(256)                                   # B was evicted: re-compile
    assert (cache.hits, cache.misses) == (h0 + 1, m0 + 1)
    assert cache.keys()[-1].digest == key_b.digest  # same graph, new entry

    s = cache.stats()
    assert s["size"] == s["capacity"] == 2
    assert s["hits"] + s["misses"] == 6         # one lookup per send
    assert (s["hits"], s["misses"], s["evictions"]) == (2, 4, 2)


def test_compile_dominates_build():
    """Paper Fig. 13: instantiation (compile) is the dominant one-time
    cost for any realistic graph."""
    plan = _dummy_plan("y", n=64)
    life = plan.lifecycle
    assert life.compile_ns > life.trace_ns * 0.1   # robust, not flaky
    assert life.build_ns == life.trace_ns + life.lower_ns + life.compile_ns
