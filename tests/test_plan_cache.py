"""TransferPlanCache: LRU behaviour + lifecycle instrumentation."""

import jax.numpy as jnp
import pytest

from repro.core import TransferPlanCache, compile_plan


def _dummy_plan(key, n=4):
    return compile_plan(key, lambda x: x * 2.0,
                        (jnp.zeros((n,), jnp.float32),), num_nodes=n)


def test_get_or_build_builds_once():
    cache = TransferPlanCache(capacity=4)
    calls = []

    def builder():
        calls.append(1)
        return _dummy_plan("k")

    a = cache.get_or_build("k", builder)
    b = cache.get_or_build("k", builder)
    assert a is b and len(calls) == 1
    assert cache.stats()["hits"] == 1
    assert cache.stats()["misses"] == 1


def test_lru_eviction_order():
    cache = TransferPlanCache(capacity=2)
    cache.put("a", _dummy_plan("a"))
    cache.put("b", _dummy_plan("b"))
    cache.get("a")                  # refresh a
    cache.put("c", _dummy_plan("c"))  # evicts b (least recently used)
    assert "a" in cache and "c" in cache and "b" not in cache
    assert cache.evictions == 1


def test_eviction_forces_reinstantiation():
    cache = TransferPlanCache(capacity=1)
    builds = []

    def builder(k):
        def b():
            builds.append(k)
            return _dummy_plan(k)
        return b

    cache.get_or_build("a", builder("a"))
    cache.get_or_build("b", builder("b"))   # evicts a
    cache.get_or_build("a", builder("a"))   # must rebuild
    assert builds == ["a", "b", "a"]


def test_capacity_env(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_SIZE", "3")
    assert TransferPlanCache().capacity == 3


def test_lifecycle_stages_recorded():
    plan = _dummy_plan("x", n=8)
    life = plan.lifecycle
    assert life.trace_ns > 0 and life.lower_ns > 0 and life.compile_ns > 0
    assert life.num_nodes == 8
    assert life.launches == 0
    out = plan(jnp.ones((8,), jnp.float32))
    assert out[0] == 2.0
    assert plan.lifecycle.launches == 1
    assert plan.lifecycle.mean_launch_ns > 0


def test_compile_dominates_build():
    """Paper Fig. 13: instantiation (compile) is the dominant one-time
    cost for any realistic graph."""
    plan = _dummy_plan("y", n=64)
    life = plan.lifecycle
    assert life.compile_ns > life.trace_ns * 0.1   # robust, not flaky
    assert life.build_ns == life.trace_ns + life.lower_ns + life.compile_ns
