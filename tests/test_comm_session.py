"""Unified comm session API: config round-trip, policies, cache accounting.

Covers the acceptance criteria of the ``repro.comm`` redesign:

* ``CommConfig.from_env`` reproduces the legacy ``REPRO_MP_*`` parsing,
* the greedy ``PathPolicy`` builds plans identical (byte-for-byte) to the
  pre-refactor ``PathPlanner.plan`` algorithm on the seed topologies,
* ``CommSession`` shares one plan cache across send / bidirectional /
  collective calls, with correct hit/miss accounting,
* the deprecated ``repro.core.*`` shims still work and warn.
"""

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommConfig, CommSession, GreedyBandwidthPolicy,
                        PathPlanner, RoundRobinPolicy, TransferPlanCache,
                        TunerPolicy, make_policy)
from repro.core import HOST, Topology, validate_plan

MiB = 1 << 20


# --------------------------- CommConfig ------------------------------------

def test_from_env_defaults_match_dataclass():
    assert CommConfig.from_env() == CommConfig()


def test_from_env_reads_legacy_vars(monkeypatch):
    monkeypatch.setenv("REPRO_MP_MAX_PATHS", "2")
    monkeypatch.setenv("REPRO_MP_CHUNK_BYTES", str(2 * MiB))
    monkeypatch.setenv("REPRO_MP_MAX_CHUNKS", "5")
    monkeypatch.setenv("REPRO_MP_HOST_PATH", "1")
    monkeypatch.setenv("REPRO_PLAN_CACHE_SIZE", "7")
    cfg = CommConfig.from_env()
    assert cfg.max_paths == 2
    assert cfg.chunk_bytes == 2 * MiB
    assert cfg.max_chunks == 5
    assert cfg.include_host is True
    assert cfg.cache_capacity == 7


def test_from_env_overrides_beat_env(monkeypatch):
    monkeypatch.setenv("REPRO_MP_MAX_PATHS", "2")
    assert CommConfig.from_env(max_paths=3).max_paths == 3


def test_planner_defaults_honor_env(monkeypatch):
    """Legacy behavior: a bare PathPlanner picks up REPRO_MP_* knobs."""
    monkeypatch.setenv("REPRO_MP_MAX_PATHS", "2")
    monkeypatch.setenv("REPRO_MP_CHUNK_BYTES", str(2 * MiB))
    planner = PathPlanner(Topology.full_mesh(4))
    assert planner.max_paths == 2
    assert planner.chunk_bytes == 2 * MiB
    plan = planner.plan(0, 1, 64 * MiB)
    assert plan.num_paths == 2


@pytest.mark.parametrize("field,value", [
    ("max_paths", 0), ("chunk_bytes", 0), ("max_chunks", 0),
    ("window", 0), ("cache_capacity", 0), ("policy", "nope"),
    ("multipath_threshold", -1), ("axis_name", ""),
])
def test_config_validation(field, value):
    with pytest.raises(ValueError):
        CommConfig(**{field: value})


# --------------------------- PathPolicy ------------------------------------

def _legacy_plan(planner, src, dst, nbytes, *, max_paths=None,
                 include_host=None, num_chunks=None, granularity=1):
    """The pre-refactor ``PathPlanner.plan`` algorithm, frozen verbatim as
    the equivalence oracle for the greedy policy."""
    from repro.comm.plan import PathAssignment, TransferPlan
    max_paths = max_paths or planner.max_paths
    routes = planner.enumerate_routes(src, dst, include_host=include_host)
    if nbytes < planner.multipath_threshold:
        routes = routes[:1]
    else:
        routes = routes[:max_paths]
    total_bw = sum(r.bottleneck_gbps for r in routes)
    paths = []
    offset = 0
    for i, route in enumerate(routes):
        if i == len(routes) - 1:
            share = nbytes - offset
        else:
            share = (int(nbytes * route.bottleneck_gbps / total_bw)
                     // granularity * granularity)
        if share <= 0:
            continue
        if num_chunks is not None:
            chunks = num_chunks
        else:
            chunks = max(1, min(planner.max_chunks,
                                -(-share // planner.chunk_bytes)))
        chunks = min(chunks, max(1, share // granularity))
        paths.append(PathAssignment(route, offset, share, chunks,
                                    granularity))
        offset += share
    return TransferPlan(src, dst, nbytes, tuple(paths),
                        planner.topology.name)


SEED_TOPOLOGIES = [
    Topology.full_mesh(4),                                # beluga
    Topology.full_mesh(4, sublinks_per_pair=4, name="narval4"),
    Topology.full_mesh(8, with_host=False, name="mesh8"),
    Topology.torus2d(4, 4),
]


@pytest.mark.parametrize("topo", SEED_TOPOLOGIES, ids=lambda t: t.name)
def test_greedy_policy_matches_legacy_planner(topo):
    """Acceptance: greedy plans identical to the pre-refactor planner."""
    planner = PathPlanner(topo, policy=GreedyBandwidthPolicy())
    host_opts = ([False, True] if any(
        HOST in k for k in topo.links) else [False])
    for nbytes in (4096, 1 * MiB, 2 * MiB, 64 * MiB, 512 * MiB + 4096):
        for max_paths in (1, 2, 3, 4, 16):
            for host in host_opts:
                for gran in (1, 4):
                    if nbytes % gran:
                        continue
                    got = planner.plan(0, 1, nbytes, max_paths=max_paths,
                                       include_host=host, granularity=gran)
                    ref = _legacy_plan(planner, 0, 1, nbytes,
                                       max_paths=max_paths,
                                       include_host=host, granularity=gran)
                    assert got == ref


def test_max_paths_zero_raises():
    planner = PathPlanner(Topology.full_mesh(4))
    with pytest.raises(ValueError, match="max_paths"):
        planner.plan(0, 1, 64 * MiB, max_paths=0)
    with pytest.raises(ValueError, match="max_paths"):
        planner.plan(0, 1, 64 * MiB, max_paths=-1)


def test_round_robin_equal_shares():
    planner = PathPlanner(Topology.full_mesh(4),
                          policy=RoundRobinPolicy())
    plan = planner.plan(0, 1, 64 * MiB, max_paths=3)
    validate_plan(plan)
    assert plan.num_paths == 3
    shares = [p.nbytes for p in plan.paths]
    assert max(shares) - min(shares) <= 4  # equal up to remainder
    # greedy on the same topology is NOT uniform (direct link is 50 GB/s
    # among equals here, but host-inclusive plans diverge)
    hostp = PathPlanner(Topology.full_mesh(4),
                        policy=GreedyBandwidthPolicy()).plan(
        0, 1, 64 * MiB, max_paths=4, include_host=True)
    hostshares = [p.nbytes for p in hostp.paths]
    assert max(hostshares) - min(hostshares) > 4


def test_tuner_policy_memoizes_and_matches_tune():
    topo = Topology.full_mesh(4)
    tuner = TunerPolicy()
    planner = PathPlanner(topo, policy=tuner)
    plan1 = planner.plan(0, 1, 128 * MiB)
    # plan() inherits the planner's include_host=False default, so it must
    # match a tune constrained the same way (NOT the unconstrained search,
    # which may pick a host-staged — unexecutable — configuration).
    assert plan1 == planner.tune(0, 1, 128 * MiB,
                                 include_host_options=(False,))
    assert all(p.route.via != HOST for p in plan1.paths)
    assert len(tuner._memo) == 1
    plan2 = planner.plan(0, 1, 128 * MiB)
    assert plan2 is plan1          # memo hit
    validate_plan(plan1)
    assert plan1.num_paths >= 2    # large message goes multipath


def test_tuner_policy_memo_keyed_on_max_paths():
    """Regression: a 1-path tune must not be served for a 4-path request."""
    planner = PathPlanner(Topology.full_mesh(4), policy=TunerPolicy())
    p1 = planner.plan(0, 1, 64 * MiB, max_paths=1)
    assert p1.num_paths == 1
    p4 = planner.plan(0, 1, 64 * MiB, max_paths=4)
    assert p4.num_paths >= 2


def test_tuner_policy_respects_include_host():
    """Regression: tuner plans for the engine must honor include_host=False
    (a host-staged plan would be rejected as unexecutable)."""
    planner = PathPlanner(Topology.full_mesh(4), policy=TunerPolicy())
    plan = planner.plan(0, 1, 64 * MiB, include_host=False)
    assert all(p.route.via != HOST for p in plan.paths)
    hosted = planner.plan(0, 1, 64 * MiB, include_host=True)
    assert any(p.route.via == HOST for p in hosted.paths)


def test_tuner_policy_session_send_executes():
    """End-to-end regression: tuner-policy sessions can actually send."""
    import jax.numpy as jnp
    sess = CommSession(CommConfig(policy="tuner"),
                       topology=Topology.full_mesh(4))
    msg = jnp.arange((4 * MiB) // 4, dtype=jnp.float32)
    got = sess.send(msg, 0, 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(msg))


def test_tuner_policy_memo_distinguishes_topologies():
    """Regression: topology NAMES are non-unique defaults (full_mesh() is
    always 'beluga4'); a shared policy must key on the object."""
    tuner = TunerPolicy()
    p8 = PathPlanner(Topology.full_mesh(8, with_host=False), policy=tuner)
    plan8 = p8.plan(0, 1, 64 * MiB)
    p4 = PathPlanner(Topology.full_mesh(4, with_host=False), policy=tuner)
    plan4 = p4.plan(0, 1, 64 * MiB)
    used4 = {d for pa in plan4.paths for link in pa.route.hops
             for d in (link.src, link.dst)}
    assert used4 <= set(range(4)), f"8-device routes leaked: {used4}"
    assert plan8 is not plan4


def test_make_policy_registry():
    assert make_policy("greedy").name == "greedy"
    assert make_policy("round_robin").name == "round_robin"
    assert make_policy("tuner").name == "tuner"
    with pytest.raises(ValueError):
        make_policy("best_effort")


# --------------------------- CommSession -----------------------------------

@pytest.fixture(scope="module")
def session():
    return CommSession(CommConfig(multipath_threshold=256),
                       topology=Topology.full_mesh(8, with_host=False,
                                                   name="mesh8"))


def test_session_send_roundtrip(session):
    msg = jnp.arange(4096, dtype=jnp.float32)
    got = session.send(msg, 0, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(msg))


def test_session_cache_accounting_across_ops(session):
    """send / bidirectional / collective all hit the SAME plan cache."""
    cache = session.cache
    msg = jnp.arange(512, dtype=jnp.float32)
    base = cache.stats()

    session.send(msg, 1, 2)                      # miss (new key)
    session.send(msg * 2, 1, 2)                  # hit (same key)
    session.bidirectional(msg, 1, 2)             # miss (distinct key)
    session.bidirectional(msg, 1, 2)             # hit
    x = jnp.asarray(np.random.RandomState(0).randn(8, 4), jnp.float32)
    session.all_gather(x)                        # miss
    session.all_gather(x)                        # hit
    session.psum(jnp.ones((3, 3)))               # miss
    session.psum(jnp.ones((3, 3)))               # hit

    s = cache.stats()
    assert s["misses"] == base["misses"] + 4
    assert s["hits"] == base["hits"] + 4
    assert s["size"] == base["size"] + 4


def test_session_collectives_match_references(session):
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    x = jnp.asarray(np.random.RandomState(1).randn(16, 6), jnp.float32)
    got = session.all_gather(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6)

    rs = session.reduce_scatter(x)
    ref = jax.jit(shard_map(
        lambda v: jax.lax.psum_scatter(v, "dev", tiled=True),
        mesh=session.mesh, in_specs=P(None), out_specs=P("dev"),
        check_vma=False))(x)
    np.testing.assert_allclose(np.asarray(rs), np.asarray(ref), rtol=1e-5)

    ar = session.all_reduce(x)
    np.testing.assert_allclose(np.asarray(ar), np.asarray(x) * 8, rtol=1e-5)

    pm = session.psum(jnp.ones((5, 2)))
    np.testing.assert_allclose(np.asarray(pm), 8.0, rtol=1e-6)


def test_session_all_to_all_roundtrip(session):
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    n = 8
    x = jnp.asarray(np.random.RandomState(2).randn(n * n, 4), jnp.float32)
    got = session.all_to_all(x)

    # reference via lax inside shard_map on block-indexed local operand
    def local_ref(v):  # v local: (n, 4) — one block per destination
        return jax.lax.all_to_all(v.reshape(n, 1, 4), "dev", 0, 0
                                  ).reshape(n, 4)
    ref = jax.jit(shard_map(local_ref, mesh=session.mesh, in_specs=P("dev"),
                            out_specs=P("dev"), check_vma=False))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


def test_session_all_to_all_rejects_wrong_block_count(session):
    """Regression: dim 0 merely divisible by n silently dropped blocks."""
    with pytest.raises(ValueError, match="n²"):
        session.all_to_all(jnp.ones((8, 4), jnp.float32))     # local dim 1
    with pytest.raises(ValueError, match="n²"):
        session.all_to_all(jnp.ones((128, 4), jnp.float32))   # local dim 16


def test_session_ring_collectives_reject_indivisible(session):
    with pytest.raises(ValueError, match="divisible"):
        session.all_reduce(jnp.ones((6, 4), jnp.float32))
    with pytest.raises(ValueError, match="divisible"):
        session.reduce_scatter(jnp.ones((6, 4), jnp.float32))


def test_session_tune_delegates(session):
    best = session.tune(0, 1, 128 * MiB)
    validate_plan(best)
    assert best.num_paths >= 2


def test_session_send_pytree(session):
    tree = {"k": jnp.arange(24, dtype=jnp.bfloat16).reshape(2, 3, 4),
            "idx": jnp.arange(7, dtype=jnp.int32)}
    moved = session.send_pytree(tree, 0, 5)
    import jax
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(moved)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_session_stats_shape(session):
    s = session.stats()
    assert s["policy"] == "greedy"
    assert s["topology"] == "mesh8"
    assert set(s["cache"]) == {"hits", "misses", "evictions", "size",
                               "capacity"}


def test_session_respects_explicit_cache():
    cache = TransferPlanCache(capacity=2)
    sess = CommSession(CommConfig(multipath_threshold=64),
                       topology=Topology.full_mesh(8, with_host=False),
                       cache=cache)
    sess.send(jnp.arange(128, dtype=jnp.float32), 0, 1)
    assert len(cache) == 1         # engine really used OUR cache


# --------------------------- deprecated shims ------------------------------

def test_core_shims_warn_and_delegate():
    import importlib
    import repro.core.paths as legacy_paths
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        importlib.reload(legacy_paths)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    from repro.comm.planner import PathPlanner as NewPlanner
    assert legacy_paths.PathPlanner is NewPlanner


def test_core_lazy_reexports():
    from repro.core import (MultiPathTransfer, PathPlanner,
                            TransferPlanCache as TPC)
    from repro.comm import MultiPathTransfer as M2, PathPlanner as P2
    assert MultiPathTransfer is M2 and PathPlanner is P2
    assert TPC().capacity == 64


@pytest.mark.parametrize("module", ["repro.core.paths",
                                    "repro.core.multipath",
                                    "repro.core.plan_cache",
                                    "repro.core.collectives"])
def test_every_core_shim_warns_on_import(module):
    """Each deprecated ``repro.core.*`` shim fires a DeprecationWarning on
    (re)import and still resolves its legacy surface."""
    import importlib
    import sys
    sys.modules.pop(module, None)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        importlib.import_module(module)


def test_transfer_key_alias_warns():
    """The dead ``TransferKey`` is gone from the engine; the alias lives on
    ``repro.core`` only and warns on access."""
    import repro.comm
    import repro.comm.engine
    import repro.core
    assert not hasattr(repro.comm.engine, "TransferKey")
    assert not hasattr(repro.comm, "TransferKey")
    with pytest.warns(DeprecationWarning, match="TransferKey"):
        key_cls = repro.core.TransferKey
    # still constructible for any straggler pickles/tests downstream
    k = key_cls(0, 1, 64, "float32", ())
    assert (k.src, k.dst) == (0, 1)

    import repro.core.multipath as legacy_multipath
    with pytest.warns(DeprecationWarning, match="TransferKey"):
        assert legacy_multipath.TransferKey is key_cls
