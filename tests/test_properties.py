"""Hypothesis property tests (optional dependency, pyproject ``[test]``).

Collected only when ``hypothesis`` is installed — the deterministic sweeps
covering the same code live in ``test_topology_paths.py``,
``test_multipath_engine.py``, and ``test_kernels.py``.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comm import (CommConfig, CommSession, PathPlanner,  # noqa: E402
                        TransferPlanCache, TransferRequest)
from repro.comm.capture import StepCapture, lower_step  # noqa: E402
from repro.comm.graph import lower  # noqa: E402
from repro.comm.passes import apply_schedule, check_pass  # noqa: E402
from repro.core import (Topology, build_schedule,  # noqa: E402
                        validate_group, validate_plan)

_ALL_SCHEDULES = ("round_robin", "depth_first", "critical_path", "overlap",
                  "auto")

MiB = 1 << 20


def _expected_edges(plans, window):
    """window · Σ_chunks (hops−1)  +  (window−1) · Σ chunks."""
    chunks = sum(len(pa.chunk_bounds()) for p in plans for pa in p.paths)
    hop_edges = sum(len(pa.chunk_bounds()) * (pa.route.num_hops - 1)
                    for p in plans for pa in p.paths)
    return window * hop_edges + (window - 1) * chunks


@settings(max_examples=60, deadline=None)
@given(
    nbytes=st.integers(1, 512 * MiB),
    max_paths=st.integers(1, 4),
    chunks=st.one_of(st.none(), st.integers(1, 16)),
    gran_pow=st.integers(0, 3),
    host=st.booleans(),
    src=st.integers(0, 3), dst=st.integers(0, 3),
)
def test_plan_invariants_property(nbytes, max_paths, chunks, gran_pow,
                                  host, src, dst):
    """§4.5 integrity invariants hold for arbitrary plans (hypothesis)."""
    if src == dst:
        return
    gran = 2 ** gran_pow
    nbytes = max(gran, nbytes // gran * gran)
    topo = Topology.full_mesh(4)
    planner = PathPlanner(topo)
    plan = planner.plan(src, dst, nbytes, max_paths=max_paths,
                        include_host=host, num_chunks=chunks,
                        granularity=gran)
    validate_plan(plan)   # disjoint cover + link exclusivity + connectivity
    sched = build_schedule(plan)
    assert sum(t.nbytes for t in sched) == nbytes
    # alignment: every chunk boundary is granularity-aligned except the tail
    for t in sched:
        assert t.offset % gran == 0


@settings(max_examples=60, deadline=None)
@given(
    nbytes=st.integers(1, 256 * MiB),
    max_paths=st.integers(1, 4),
    chunks=st.one_of(st.none(), st.integers(1, 16)),
    gran_pow=st.integers(0, 3),
    host=st.booleans(),
    src=st.integers(0, 3), dst=st.integers(0, 3),
    window=st.integers(1, 4),
)
def test_lower_roundtrip_property(nbytes, max_paths, chunks, gran_pow,
                                  host, src, dst, window):
    """The lowering round-trips: for arbitrary plans, node byte ranges
    reproduce ``chunk_bounds()`` exactly, node count is chunks × hops ×
    window, and edge count is ``window·Σ(hops−1 per chunk) + window
    links`` ((window−1) per chunk)."""
    if src == dst:
        return
    gran = 2 ** gran_pow
    nbytes = max(gran, nbytes // gran * gran)
    planner = PathPlanner(Topology.full_mesh(4))
    plan = planner.plan(src, dst, nbytes, max_paths=max_paths,
                        include_host=host, num_chunks=chunks,
                        granularity=gran)
    graph = lower(plan, window)
    assert graph.num_nodes == window * sum(
        len(pa.chunk_bounds()) * pa.route.num_hops for pa in plan.paths)
    assert graph.num_edges == _expected_edges([plan], window)
    for p_idx, pa in enumerate(plan.paths):
        got = sorted({(n.offset, n.nbytes) for n in graph.nodes
                      if n.path_idx == p_idx and n.window == 0})
        assert got == sorted(pa.chunk_bounds())
    assert lower(plan, window).digest() == graph.digest()


@settings(max_examples=40, deadline=None)
@given(
    nbytes=st.integers(1, 128 * MiB),
    max_paths=st.integers(1, 4),
    chunks=st.one_of(st.none(), st.integers(1, 16)),
    gran_pow=st.integers(0, 3),
    host=st.booleans(),
    src=st.integers(0, 3), dst=st.integers(0, 3),
    window=st.integers(1, 3),
)
def test_pass_invariants_property(nbytes, max_paths, chunks, gran_pow,
                                  host, src, dst, window):
    """Every shipped scheduler pass preserves ``graph.validate()`` and
    the exact ``chunk_bounds()`` round-trip on arbitrary plans — the
    §2.2 contract property (byte cover and hop chains fixed, dispatch
    order free), plus digest identity for the round_robin baseline."""
    if src == dst:
        return
    gran = 2 ** gran_pow
    nbytes = max(gran, nbytes // gran * gran)
    topo = Topology.full_mesh(4)
    planner = PathPlanner(topo)
    plan = planner.plan(src, dst, nbytes, max_paths=max_paths,
                        include_host=host, num_chunks=chunks,
                        granularity=gran)
    graph = lower(plan, window)
    for name in _ALL_SCHEDULES:
        scheduled, chosen = apply_schedule(graph, name, topo)
        check_pass(graph, scheduled)            # full §2.2 contract
        scheduled.validate({0: plan.nbytes})    # §4.5 with coverage totals
        assert scheduled.num_nodes == graph.num_nodes
        assert scheduled.num_edges == graph.num_edges
        for p_idx, pa in enumerate(plan.paths):
            got = sorted({(n.offset, n.nbytes) for n in scheduled.nodes
                          if n.path_idx == p_idx and n.window == 0})
            assert got == sorted(pa.chunk_bounds())
        if name == "round_robin":
            assert chosen == "round_robin"
            assert scheduled.digest() == graph.digest()


@settings(max_examples=20, deadline=None)
@given(
    pairs=st.lists(st.tuples(st.integers(0, 7), st.integers(0, 7)).filter(
        lambda p: p[0] != p[1]), min_size=1, max_size=4, unique=True),
    sizes=st.lists(st.integers(64, 4 * MiB), min_size=4, max_size=4),
    window=st.integers(1, 2),
)
def test_group_pass_invariants_property(pairs, sizes, window):
    """The §2.2 pass contract holds on randomized fused GROUPS too: every
    message's byte cover survives every scheduler, per-message §4.5
    invariants re-validate, and node/edge counts are preserved."""
    topo = Topology.full_mesh(8, with_host=False)
    planner = PathPlanner(topo, multipath_threshold=256)
    reqs = [(s, d, n) for (s, d), n in zip(pairs, sizes)]
    group = planner.plan_group(reqs)
    graph = lower(group, window)
    totals = {i: p.nbytes for i, p in enumerate(group.plans)}
    for name in _ALL_SCHEDULES:
        scheduled, _ = apply_schedule(graph, name, topo)
        check_pass(graph, scheduled)
        scheduled.validate(totals, cross_flow_exclusive=False)
        assert scheduled.num_nodes == graph.num_nodes
        for m_idx, plan in enumerate(group.plans):
            per_msg = sorted((n.offset, n.nbytes) for n in scheduled.nodes
                             if n.msg_idx == m_idx and n.hop_idx == 0
                             and n.window == 0)
            assert per_msg == sorted(
                b for pa in plan.paths for b in pa.chunk_bounds())


_pairs8 = st.tuples(st.integers(0, 7), st.integers(0, 7)).filter(
    lambda p: p[0] != p[1])


@settings(max_examples=25, deadline=None)
@given(
    nelems=st.integers(16, 65536),
    chunks=st.one_of(st.none(), st.integers(1, 8)),
    max_paths=st.integers(1, 3),
    pairs=st.lists(_pairs8, min_size=1, max_size=3, unique=True),
)
def test_capture_count_laws_property(nelems, chunks, max_paths, pairs):
    """Heterogeneous count laws (ISSUE 7): ``lower_step`` emits exactly
    one ComputeNode per kernel invocation plus Σ chunks×hops copy nodes,
    §4.5 validation (including buffer def-use edges) holds on the
    lowering, and every shipped scheduler preserves the node multiset,
    the copy/compute split, and every message's byte cover (§2.2)."""
    topo = Topology.full_mesh(8, with_host=False)
    planner = PathPlanner(topo, multipath_threshold=256)

    def plan_group_fn(specs, *, max_paths=None, num_chunks=None):
        reqs = [TransferRequest(s, d, ne * 4, granularity=4)
                for (s, d, ne, _) in specs]
        return planner.plan_group(reqs, max_paths=max_paths,
                                  include_host=False,
                                  num_chunks=num_chunks)

    cap = StepCapture()
    x = cap.input((nelems,), jnp.float32)
    y = cap.kernel(lambda v: v * 2, x, name="k0")
    recvs = cap.exchange([(y, s, d) for (s, d) in pairs],
                         max_paths=max_paths, num_chunks=chunks)
    cap.kernel(lambda *vs: sum(vs[1:], vs[0]), y, *recvs, name="k1")
    graph, plans = lower_step(cap, plan_group_fn, topo.name)
    assert graph.num_compute_nodes == 2
    assert graph.num_copy_nodes == sum(
        len(pa.chunk_bounds()) * pa.route.num_hops
        for p in plans for pa in p.paths)
    assert graph.num_nodes == graph.num_copy_nodes + graph.num_compute_nodes
    assert len(graph.messages) == len(pairs)
    totals = {i: p.nbytes for i, p in enumerate(plans)}
    for name in _ALL_SCHEDULES:
        scheduled, _ = apply_schedule(graph, name, topo)
        check_pass(graph, scheduled)
        scheduled.validate(totals, cross_flow_exclusive=False)
        assert scheduled.num_nodes == graph.num_nodes
        assert scheduled.num_copy_nodes == graph.num_copy_nodes
        assert scheduled.num_compute_nodes == graph.num_compute_nodes
        for m_idx, plan in enumerate(plans):
            per_msg = sorted((n.offset, n.nbytes) for n in scheduled.nodes
                             if hasattr(n, "msg_idx")
                             and n.msg_idx == m_idx and n.hop_idx == 0)
            assert per_msg == sorted(
                b for pa in plan.paths for b in pa.chunk_bounds())


@settings(max_examples=40, deadline=None)
@given(
    pairs=st.lists(_pairs8, min_size=1, max_size=5, unique=True),
    sizes=st.lists(st.integers(64, 8 * MiB), min_size=5, max_size=5),
    max_paths=st.integers(1, 4),
)
def test_group_invariants_property(pairs, sizes, max_paths):
    """Group-level §4.5 invariants hold for arbitrary distinct-flow groups:

    * every plan of the group covers its own message disjointly,
    * an *exclusive* group shares no directional link across flows
      (``validate_group``), and ``exclusive`` is reported faithfully.

    (The fused-vs-sequential time comparison is deterministic — see
    ``test_transfer_group.py`` — because for pathological size mixes a
    tiny message's launch nodes legitimately land on the fused critical
    path while the dispatch loop hides them behind a long wire.)
    """
    topo = Topology.full_mesh(8, with_host=False)
    planner = PathPlanner(topo, multipath_threshold=256)
    reqs = [(s, d, n) for (s, d), n in zip(pairs, sizes)]
    group = planner.plan_group(reqs, max_paths=max_paths)
    assert group.num_messages == len(reqs)
    for plan, (s, d, n) in zip(group.plans, reqs):
        validate_plan(plan)            # per-plan disjoint cover + links
        assert (plan.src, plan.dst, plan.nbytes) == (s, d, n)
    # the fused lowering round-trips the whole group
    graph = lower(group)
    assert graph.num_messages == len(reqs)
    assert graph.num_nodes == sum(
        len(pa.chunk_bounds()) * pa.route.num_hops
        for p in group.plans for pa in p.paths)
    assert graph.num_edges == _expected_edges(group.plans, 1)
    for m_idx, plan in enumerate(group.plans):
        per_msg = sorted((n.offset, n.nbytes) for n in graph.nodes
                         if n.msg_idx == m_idx and n.hop_idx == 0)
        assert per_msg == sorted(
            b for pa in plan.paths for b in pa.chunk_bounds())
    if group.exclusive:
        validate_group(group)          # cross-flow link exclusivity
    else:
        with pytest.raises(ValueError, match="exclusivity"):
            validate_group(group)


@settings(max_examples=20, deadline=None)
@given(
    pairs=st.lists(_pairs8, min_size=1, max_size=4, unique=True),
    sizes=st.lists(st.integers(64, 4 * MiB), min_size=4, max_size=4),
)
def test_group_exclusive_property(pairs, sizes):
    """Whenever exclusive=True succeeds, the result passes the strict
    cross-flow validator and reports itself exclusive."""
    topo = Topology.full_mesh(8, with_host=False)
    planner = PathPlanner(topo, multipath_threshold=256)
    reqs = [(s, d, n) for (s, d), n in zip(pairs, sizes)]
    try:
        group = planner.plan_group(reqs, exclusive=True)
    except ValueError:
        hypothesis.reject()
    validate_group(group)
    assert group.exclusive


@settings(max_examples=30, deadline=None)
@given(
    islands=st.integers(2, 3),
    per=st.integers(2, 4),
    egress=st.integers(1, 2),
    nbytes=st.integers(1024, 32 * MiB),
    max_paths=st.integers(1, 4),
    data=st.data(),
)
def test_hierarchical_routing_property(islands, per, egress, nbytes,
                                       max_paths, data):
    """SATELLITE property (§3.1 island-routing invariants): on randomized
    hierarchical topologies no plan routes intra-island traffic over an
    inter-node link, and every cross-island plan crosses exactly ONE
    inter-node hop per route — and every shipped scheduler preserves
    those hop sets per (path, chunk)."""
    topo = Topology.hierarchical(islands, per,
                                 egress_per_island=min(egress, per))
    n = islands * per
    src = data.draw(st.integers(0, n - 1), label="src")
    dst = data.draw(st.integers(0, n - 1).filter(lambda d: d != src),
                    label="dst")
    inter = {(a, b) for (a, b) in topo.links if topo.is_inter_island(a, b)}
    assert inter                       # the topology really is hierarchical
    planner = PathPlanner(topo, multipath_threshold=256)
    plan = planner.plan(src, dst, nbytes, max_paths=max_paths)
    validate_plan(plan)
    cross = topo.node_of(src) != topo.node_of(dst)
    want_inter_hops = 1 if cross else 0
    for pa in plan.paths:
        hops = pa.route.directional_links()
        assert sum(h in inter for h in hops) == want_inter_hops, (
            src, dst, hops)
    graph = lower(plan, 1)
    for name in _ALL_SCHEDULES:
        scheduled, _ = apply_schedule(graph, name, topo)
        check_pass(graph, scheduled)
        per_chunk = {}
        for node in scheduled.nodes:
            per_chunk.setdefault((node.path_idx, node.offset),
                                 []).append(node.link)
        for links in per_chunk.values():
            assert sum(lk in inter for lk in links) == want_inter_hops


@settings(max_examples=12, deadline=None)
@given(src=st.integers(0, 7), dst=st.integers(0, 7),
       nelems=st.integers(8, 5000),
       max_paths=st.integers(1, 4),
       chunks=st.integers(1, 4))
def test_transfer_property(src, dst, nelems, max_paths, chunks):
    if src == dst:
        return
    topo = Topology.full_mesh(8, with_host=False)
    sess = CommSession(CommConfig(multipath_threshold=16),
                       topology=topo,
                       cache=TransferPlanCache(capacity=256))
    msg = jnp.asarray(np.random.RandomState(0).randn(nelems), jnp.float32)
    got = sess.send(msg, src, dst, max_paths=max_paths,
                    num_chunks=chunks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(msg))


@settings(max_examples=8, deadline=None)
@given(nelems=st.integers(64, 4096), paths=st.integers(1, 3),
       chunks=st.integers(1, 5))
def test_dma_schedule_replay_property(nelems, paths, chunks):
    from repro.kernels.multipath_dma import ref as dma_ref

    topo = Topology.full_mesh(4)
    planner = PathPlanner(topo, multipath_threshold=4)
    plan = planner.plan(2, 3, nelems * 4, granularity=4,
                        max_paths=paths, num_chunks=chunks)
    x = np.random.RandomState(1).randn(4, nelems).astype(np.float32)
    rep = dma_ref.replay_schedule(x, plan, 4)
    ref = dma_ref.multipath_transfer_ref(x, plan)
    np.testing.assert_array_equal(rep, ref)


@settings(max_examples=6, deadline=None)
@given(s=st.integers(16, 160), chunk=st.sampled_from([16, 32, 64]),
       decay_lo=st.floats(0.7, 0.95))
def test_rwkv6_property(s, chunk, decay_lo):
    from repro.kernels.rwkv6_scan import ops as r_ops
    from repro.kernels.rwkv6_scan import ref as r_ref

    rng = np.random.RandomState(4)
    bh, dk, dv = 2, 16, 16
    r = jnp.asarray(rng.randn(bh, s, dk).astype(np.float32)) * 0.4
    k = jnp.asarray(rng.randn(bh, s, dk).astype(np.float32)) * 0.4
    v = jnp.asarray(rng.randn(bh, s, dv).astype(np.float32))
    w = jnp.asarray(rng.uniform(decay_lo, 0.999,
                                (bh, s, dk)).astype(np.float32))
    u = jnp.asarray(rng.randn(bh, dk).astype(np.float32)) * 0.2
    got = r_ops.rwkv6_scan(r, k, v, w, u, chunk=chunk)
    ref = r_ref.rwkv6_scan_ref(r, k, v, w, u)
    scale = np.max(np.abs(np.asarray(ref))) + 1e-9
    assert np.max(np.abs(np.asarray(got) - np.asarray(ref))) / scale < 3e-4


# ----------------- §4.6 chaos: single-link-failure re-planning --------------

_CHAOS_TOPOLOGIES = ("beluga4", "mesh8", "two_island")


def _chaos_topology(name):
    """Fresh fault-model fixtures (mutating tests must not share)."""
    if name == "beluga4":
        return Topology.full_mesh(4)
    if name == "mesh8":
        return Topology.full_mesh(8, with_host=False, name="mesh8")
    return Topology.hierarchical(2, 4, name="two_island")


@settings(max_examples=60, deadline=None)
@given(
    fixture=st.sampled_from(_CHAOS_TOPOLOGIES),
    mode=st.sampled_from(["fail", "quarantine", "degrade"]),
    nbytes=st.integers(1024, 32 * MiB),
    max_paths=st.integers(1, 4),
    data=st.data(),
)
def test_single_link_fault_replan_property(fixture, mode, nbytes,
                                           max_paths, data):
    """SATELLITE chaos property (§4.6 degradation invariants): under any
    single device-link failure / quarantine / droop, on every shared
    topology fixture shape, every plan the planner still produces

    * satisfies the §4.5 integrity invariants (disjoint cover, link
      exclusivity, connectivity),
    * routes over ZERO failed or quarantined links, and
    * preserves the §3.1 one-inter-hop invariant on the hierarchical
      fixture (exactly one inter-island hop per cross-island route,
      none intra) — degradation must not bend island routing.
    """
    from repro.core.topology import HOST

    topo = _chaos_topology(fixture)
    planner = PathPlanner(topo, multipath_threshold=256)
    dev_links = sorted(k for k in topo.links if HOST not in k)
    bad = data.draw(st.sampled_from(dev_links), label="faulted_link")
    if mode == "fail":
        topo.fail_link(*bad)
        excluded = {bad}
    elif mode == "quarantine":
        planner.quarantine(bad)
        excluded = {bad}
    else:
        topo.degrade_link(*bad, ratio=0.05)
        excluded = set()               # degraded links stay routable
    n = topo.num_devices
    src = data.draw(st.integers(0, n - 1), label="src")
    dst = data.draw(st.integers(0, n - 1).filter(lambda d: d != src),
                    label="dst")
    inter = {(a, b) for (a, b) in topo.links
             if topo.is_inter_island(a, b)}
    try:
        plan = planner.plan(src, dst, nbytes, max_paths=max_paths)
    except ValueError:
        # The fault genuinely disconnected src from dst (e.g. the only
        # egress pair of the hierarchical fixture) — there is no plan to
        # validate; the engine's ladder handles this rung.
        hypothesis.reject()
    validate_plan(plan)
    cross = topo.num_islands > 1 and topo.node_of(src) != topo.node_of(dst)
    want_inter = 1 if cross else 0
    for pa in plan.paths:
        hops = pa.route.directional_links()
        assert not (set(hops) & excluded), (mode, bad, hops)
        if topo.num_islands > 1:
            assert sum(h in inter for h in hops) == want_inter, (
                src, dst, hops)
