"""docs/api.md is auto-checked: every public symbol of the pass-facing
modules (``repro.comm.passes``, ``repro.comm.graph``), the cache layer
(``repro.comm.cache`` — plan cache, lifecycle, dispatch fast path), the
measured-feedback layer (``repro.comm.telemetry``,
``repro.comm.calibration`` — §4.4c), and the hierarchy-bearing layers
(``repro.core.topology``, ``repro.comm.planner``,
``repro.comm.collectives`` — DESIGN §3.1) must

* appear in the reference page,
* carry a docstring that names its invariant obligations (the §2.2 /
  §4.5 vocabulary — a symbol whose docs don't say what a pass may rely
  on or must preserve is a contract gap),
* and every public method/property of the public classes must be
  documented at all.

This is the satellite guard for the DESIGN §2.2 pass-author contract:
the prose contract cannot silently drift from the code surface.
"""

import inspect
import pathlib
import re

import pytest

import repro.comm.cache as cache_mod
import repro.comm.calibration as calibration_mod
import repro.comm.capture as capture_mod
import repro.comm.collectives as collectives_mod
import repro.comm.graph as graph_mod
import repro.comm.health as health_mod
import repro.comm.passes as passes_mod
import repro.comm.planner as planner_mod
import repro.comm.telemetry as telemetry_mod
import repro.core.topology as topology_mod

GATED = [graph_mod, passes_mod, capture_mod, cache_mod, telemetry_mod,
         calibration_mod, topology_mod, planner_mod, collectives_mod,
         health_mod]

DOCS = pathlib.Path(__file__).resolve().parents[1] / "docs" / "api.md"

#: A docstring "mentions its invariant obligations" when it uses the
#: contract vocabulary: what §4.5/§2.2 property it preserves, validates,
#: digests, or may rely on.
_OBLIGATION = re.compile(
    r"invariant|validate|digest|§4\.5|§2\.2|contract|preserve",
    re.IGNORECASE)


def _public_symbols(module):
    out = []
    for name, obj in vars(module).items():
        if name.startswith("_"):
            continue
        # Any public callable counts — functools wrappers included
        # (``lower`` is lru_cache-wrapped; functools.wraps preserves
        # __module__ and __doc__, so the gate still applies to it).
        if not (inspect.isclass(obj) or callable(obj)):
            continue
        if getattr(obj, "__module__", None) != module.__name__:
            continue  # re-exports (e.g. typing.Protocol machinery)
        out.append((name, obj))
    assert out, f"no public symbols found in {module.__name__}"
    return out


def test_gate_covers_wrapped_entry_points():
    """The main lowering entry point must not slip through the gate
    because of its lru_cache wrapper (regression for the checker)."""
    assert "lower" in dict(_public_symbols(graph_mod))
    assert "apply_schedule" in dict(_public_symbols(passes_mod))


@pytest.mark.parametrize("module", GATED,
                         ids=lambda m: m.__name__)
def test_public_symbols_state_their_obligations(module):
    missing, undocumented = [], []
    for name, obj in _public_symbols(module):
        doc = inspect.getdoc(obj)
        if not doc:
            undocumented.append(name)
        elif not _OBLIGATION.search(doc):
            missing.append(name)
    assert not undocumented, (
        f"{module.__name__}: public symbols without docstrings: "
        f"{undocumented}")
    assert not missing, (
        f"{module.__name__}: docstrings that never mention their "
        f"invariant obligations (§2.2 contract vocabulary): {missing}")


@pytest.mark.parametrize("module", GATED,
                         ids=lambda m: m.__name__)
def test_public_class_members_are_documented(module):
    gaps = []
    for cls_name, cls in _public_symbols(module):
        if not inspect.isclass(cls):
            continue
        for name, member in vars(cls).items():
            if name.startswith("_"):
                continue
            target = member.fget if isinstance(member, property) else (
                getattr(member, "func", member))  # cached_property
            if not callable(target):
                continue  # plain class attributes (e.g. ``name = "..."``)
            if not inspect.getdoc(target):
                gaps.append(f"{cls_name}.{name}")
    assert not gaps, (
        f"{module.__name__}: public class members without docstrings: "
        f"{gaps}")


@pytest.mark.parametrize("module", GATED,
                         ids=lambda m: m.__name__)
def test_reference_page_lists_every_symbol(module):
    text = DOCS.read_text()
    absent = [name for name, _ in _public_symbols(module)
              if f"`{name}" not in text]
    assert not absent, (
        f"docs/api.md does not list {module.__name__} symbols: {absent}")


def test_module_docstrings_carry_the_contract():
    for module in GATED:
        doc = inspect.getdoc(module)
        assert doc and _OBLIGATION.search(doc)
    assert "§2.2" in inspect.getdoc(passes_mod)
